"""Observability plane: histograms, spans, wire telemetry, merges.

Gated invariants:

  * histogram bucket math is exact (bisect on precomputed bounds, not
    floating logs): boundary values, overflow, count conservation,
    snapshot/delta arithmetic, text exposition
  * disabled tracing is a true no-op: the shared noop span object, zero
    recorded events, ring capacity bounded when enabled
  * Chrome trace-event export is valid and merging is deterministic —
    same snapshots in, byte-identical JSON out, distinct synthetic pids
    even for same-OS-process sources
  * all three TCP server types (embed shard, fedsvc coordinator,
    gnnserve frontend) answer the shared OP_METRICS/OP_TRACE opcodes on
    their existing data ports, as does the worker's telemetry-only
    listener; obs_dump merges the scrapes into one timeline + table
  * TcpTransport RPC samples feed the registry histograms through one
    bookkeeping point while preserving the deque API calibration uses
  * gnnserve OP_SSTATS is registry-backed (cache hit-rate, per-depth
    exits, gnnserve.* metrics section)
"""

import json
import os
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.exchange import wire
from repro.exchange.socket_transport import TcpTransport
from repro.launch import obs_dump
from repro.launch.embed_server import serve_in_thread as embed_serve
from repro.obsv import teleserve, trace
from repro.obsv.metrics import (REGISTRY, Histogram, MetricsRegistry,
                                SampleWindow, log_bounds)
from repro.obsv.trace import (NOOP_SPAN, TraceRecorder, merge_snapshots,
                              traced)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(autouse=True)
def _clean_trace():
    """Every test starts and ends with the global recorder disabled and
    empty (several suites share the process)."""
    trace.TRACE.disable()
    trace.TRACE.clear()
    trace.TRACE.context.clear()
    yield
    trace.TRACE.disable()
    trace.TRACE.clear()
    trace.TRACE.context.clear()


# -- histogram bucket math ----------------------------------------------------

def test_log_bounds_cover_range():
    b = log_bounds(1e-3, 1.0, 2.0)
    assert b[0] == 1e-3
    assert b[-1] >= 1.0
    for lo, hi in zip(b, b[1:]):
        assert hi == pytest.approx(lo * 2.0)


def test_histogram_bucket_placement_exact():
    h = Histogram("t", lo=1e-3, hi=1.0, factor=2.0)
    # a value equal to a bucket's upper bound lands IN that bucket
    h.observe(1e-3)
    assert h.counts[0] == 1
    h.observe(2e-3)
    assert h.counts[1] == 1
    # under lo → first bucket; over hi → +Inf overflow slot
    h.observe(1e-9)
    assert h.counts[0] == 2
    h.observe(50.0)
    assert h.counts[-1] == 1
    # count conservation + sidecars
    assert sum(h.counts) == h.count == 4
    assert h.vmin == 1e-9 and h.vmax == 50.0
    assert h.sum == pytest.approx(1e-3 + 2e-3 + 1e-9 + 50.0)
    assert h.mean == pytest.approx(h.sum / 4)


def test_histogram_quantile_monotone():
    h = Histogram("t", lo=1e-3, hi=10.0, factor=2.0)
    for v in np.geomspace(1e-3, 5.0, 200):
        h.observe(float(v))
    q50, q90, q99 = h.quantile(0.5), h.quantile(0.9), h.quantile(0.99)
    # estimates are bucket upper bounds: monotone, within the bound range
    assert q50 <= q90 <= q99 <= h.bounds[-1]
    assert q50 >= h.vmin


def test_registry_snapshot_delta_and_text():
    reg = MetricsRegistry()
    c = reg.counter("a.count")
    g = reg.gauge("a.level")
    h = reg.histogram("a.lat", lo=1e-3, hi=1.0, factor=2.0)
    c.inc(3)
    g.set(7.5)
    h.observe(0.25)
    before = reg.snapshot()
    c.inc(2)
    h.observe(0.5)
    g.set(1.0)
    delta = MetricsRegistry.delta(reg.snapshot(), before)
    assert delta["a.count"] == 2
    assert delta["a.lat"]["count"] == 1
    assert delta["a.lat"]["sum"] == pytest.approx(0.5)
    # scalar metrics subtract uniformly (a snapshot can't tell a gauge
    # from a counter; consumers pick the names they know are counters)
    assert delta["a.level"] == pytest.approx(1.0 - 7.5)
    text = reg.render_text()
    assert "a.count 5" in text
    assert 'a.lat_bucket{le="+Inf"} 2' in text
    assert "a.lat_count 2" in text
    # cumulative bucket lines are monotone non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("a.lat_bucket")]
    assert cums == sorted(cums)


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_fn_backed_gauge_reads_live():
    reg = MetricsRegistry()
    box = {"v": 1}
    reg.gauge("live", fn=lambda: box["v"])
    assert reg.snapshot()["live"] == 1
    box["v"] = 9
    assert reg.snapshot()["live"] == 9


def test_kernel_compile_gauges_registered():
    import repro.kernels.quantize  # noqa: F401 — registers the gauges
    snap = REGISTRY.snapshot("kernels.")
    assert "kernels.quantize_padded.compiles" in snap
    assert snap["kernels.quantize_padded.compiles"] >= 0


# -- sample window (satellite: RpcSamples fold) -------------------------------

class _FakeSample:
    def __init__(self, op, measured_s, payload_bytes):
        self.op = op
        self.measured_s = measured_s
        self.payload_bytes = payload_bytes


def test_sample_window_feeds_histograms_once():
    reg = MetricsRegistry()
    w = SampleWindow("ex", maxlen=4, registry=reg)
    for i in range(6):
        w.observe(_FakeSample("gather", 1e-3 * (i + 1), 1024))
    # deque is bounded, histograms saw every observe
    assert len(w) == 4 and w.maxlen == 4
    snap = reg.snapshot()
    assert snap["ex.latency_s.gather"]["count"] == 6
    assert snap["ex.bytes.gather"]["count"] == 6
    w.clear()
    assert len(w) == 0
    # clearing the window must NOT rewind the histograms
    assert reg.snapshot()["ex.latency_s.gather"]["count"] == 6
    assert list(iter(w)) == []


# -- trace recorder -----------------------------------------------------------

def test_disabled_span_is_shared_noop_and_records_nothing():
    rec = TraceRecorder()
    assert rec.span("x") is NOOP_SPAN
    assert rec.span("y", args={"k": 1}) is NOOP_SPAN
    with rec.span("z"):
        pass
    rec.instant("i")
    assert len(rec.events) == 0


def test_enabled_span_records_name_tid_duration_args():
    rec = TraceRecorder()
    rec.enable()
    rec.set_context(round=3)
    with rec.span("outer", cat="phase", args={"client": 1}):
        with rec.span("inner"):
            pass
    assert len(rec.events) == 2
    names = [e[0] for e in rec.events]
    assert names == ["inner", "outer"]      # inner closes first
    for name, cat, tid, t0, dur, args in rec.events:
        assert tid == threading.get_ident()
        assert dur >= 0.0
        assert args["round"] == 3           # context tag merged
    outer = rec.events[1]
    assert outer[5] == {"round": 3, "client": 1}


def test_ring_buffer_bounded():
    rec = TraceRecorder(capacity=8)
    rec.enable()
    for i in range(100):
        with rec.span(f"s{i}"):
            pass
    assert len(rec.events) == 8
    assert rec.events[0][0] == "s92"        # oldest dropped


def test_traced_decorator():
    trace.TRACE.enable()

    @traced("fn.work")
    def work(x):
        return x + 1

    assert work(1) == 2
    assert [e[0] for e in trace.TRACE.events] == ["fn.work"]
    trace.TRACE.disable()
    assert work(2) == 3
    assert len(trace.TRACE.events) == 1     # disabled call recorded nothing


# -- chrome export + merge ----------------------------------------------------

def _sample_snapshot(label="p", n=3):
    rec = TraceRecorder(process=label)
    rec.enable()
    for i in range(n):
        with rec.span(f"e{i}", cat="test", args={"i": i}):
            pass
    return rec.snapshot()


def test_chrome_events_valid_schema():
    rec = TraceRecorder(process="me")
    rec.enable()
    with rec.span("work", args={"k": "v"}):
        pass
    events = rec.chrome_events()
    text = json.dumps({"traceEvents": events})
    parsed = json.loads(text)
    for ev in parsed["traceEvents"]:
        assert ev["ph"] in ("X", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert isinstance(ev["ts"], float) and isinstance(ev["dur"],
                                                              float)
            assert ev["dur"] >= 0.0


def test_merge_deterministic_and_distinct_pids():
    s1 = _sample_snapshot("alpha")
    s2 = _sample_snapshot("beta")
    doc_a = merge_snapshots([s1, s2], [0.0, 0.5])
    doc_b = merge_snapshots([s1, s2], [0.0, 0.5])
    assert json.dumps(doc_a, sort_keys=True) \
        == json.dumps(doc_b, sort_keys=True)
    meta = [e for e in doc_a["traceEvents"] if e["ph"] == "M"]
    spans = [e for e in doc_a["traceEvents"] if e["ph"] == "X"]
    # both sources are threads of THIS process (same OS pid), but each
    # gets its own synthetic track
    assert len({e["pid"] for e in meta}) == 2
    assert {e["pid"] for e in spans} == {e["pid"] for e in meta}
    labels = {e["args"]["name"].split(" ")[0] for e in meta}
    assert labels == {"alpha", "beta"}


def test_merge_applies_clock_offsets():
    s1 = _sample_snapshot("a", n=1)
    s2 = json.loads(json.dumps(s1))
    s2["process"] = "b"
    base = merge_snapshots([s1], [0.0])
    shifted = merge_snapshots([s2], [10.0])
    t_base = [e["ts"] for e in base["traceEvents"] if e["ph"] == "X"][0]
    t_shift = [e["ts"] for e in shifted["traceEvents"]
               if e["ph"] == "X"][0]
    assert t_shift - t_base == pytest.approx(10.0 * 1e6, rel=1e-6)


# -- live TCP scrape: all server types ----------------------------------------

def test_scrape_embed_server_roundtrip():
    trace.TRACE.enable()
    with embed_serve(3, 8) as h:
        tr = TcpTransport(3, 8, [h.address])
        gids = np.arange(16)
        tr.register(gids)
        tr.write(gids, [np.random.default_rng(0).standard_normal(
            (16, 8)).astype(np.float32)] * 2)
        tr.gather(gids)
        with teleserve.TelemetryClient(h.address) as c:
            sc = c.scrape("embed0")
        tr.close()
    assert sc.pid > 0
    # same-process loopback: offsets are sub-50ms even on a loaded box
    assert abs(sc.offset_s) < 0.05
    # client-side RPC histograms and server-side spans both visible
    assert sc.metrics["exchange.latency_s.gather"]["count"] >= 1
    assert sc.metrics["exchange.bytes.write"]["count"] >= 1
    assert any(e[0].startswith("embed.") for e in sc.trace["events"])
    # sample window and histogram saw the same RPCs
    n_gather = sum(1 for s in tr.rpc_samples if s.op == "gather")
    assert sc.metrics["exchange.latency_s.gather"]["count"] >= n_gather


def test_scrape_coordinator_roundtrip():
    from repro.fedsvc.coordinator import CoordinatorState
    from repro.fedsvc.coordinator import serve_in_thread as coord_serve
    state = CoordinatorState(num_clients=1, num_rounds=1)
    h = coord_serve(state)
    try:
        with teleserve.TelemetryClient(h.address) as c:
            m, off_m = c.metrics()
            t, off_t = c.trace()
    finally:
        h.stop()
    assert "coord.aggregations" in m["metrics"]
    assert abs(off_m) < 0.05 and abs(off_t) < 0.05
    assert t["pid"] > 0 and isinstance(t["events"], list)


class _StubPlane:
    """pending()/stats() are all the frontend needs when no predict
    traffic flows — keeps the scrape test independent of a trained
    model."""

    def pending(self):
        return 0

    def step(self):
        return []

    def stats(self):
        return {"served": 0, "exits_by_depth": {}, "forwards": 0,
                "cache": {}, "cache_hit_rate": 0.0}


def test_scrape_gnnserve_frontend_and_registry_backed_sstats():
    from repro.gnnserve.frontend import GnnServeClient
    from repro.gnnserve.frontend import serve_in_thread as front_serve
    h = front_serve(_StubPlane())
    try:
        with teleserve.TelemetryClient(h.address) as c:
            sc = c.scrape("serve")
        cli = GnnServeClient(h.address)
        stats = cli.stats()
        cli.close()
    finally:
        h.stop()
    assert sc.pid > 0 and abs(sc.offset_s) < 0.05
    # satellite: OP_SSTATS carries the gnnserve.* registry slice next to
    # the plane's own counts, including the cache hit-rate
    assert "cache_hit_rate" in stats
    assert "metrics" in stats
    assert all(k.startswith("gnnserve.") for k in stats["metrics"])
    assert "gnnserve.cache.hits" in stats["metrics"]


def test_telemetry_only_listener_rejects_other_opcodes():
    with teleserve.serve_telemetry() as h:
        with teleserve.TelemetryClient(h.address) as c:
            sc = c.scrape("w0")
            assert sc.pid > 0
            # a data-plane opcode on the telemetry listener errors
            # cleanly instead of hanging the connection
            wire.send_frame(c._sock, wire.build_stats())
            resp = wire.recv_frame(c._sock)
            with pytest.raises(RuntimeError):
                wire.parse_response(resp)


def test_obs_dump_merges_multiple_endpoints(tmp_path):
    trace.TRACE.enable()
    with embed_serve(3, 8) as e1, embed_serve(3, 8) as e2, \
            teleserve.serve_telemetry() as w0:
        tr = TcpTransport(3, 8, [e1.address, e2.address])
        tr.register(np.arange(32))
        tr.close()
        doc, table = obs_dump.dump([
            ("embed0", e1.address), ("embed1", e2.address),
            ("worker0", w0.address)])
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(meta) == 3
    json.dumps(doc)                          # serializable end to end
    assert "# embed0" in table and "# worker0" in table
    assert "embed.requests" in table


def test_obs_dump_cli_writes_files(tmp_path):
    trace.TRACE.enable()
    with embed_serve(3, 8) as h:
        tr = TcpTransport(3, 8, [h.address])
        tr.register(np.arange(8))
        tr.close()
        out = tmp_path / "trace.json"
        mout = tmp_path / "metrics.txt"
        obs_dump.main(["--embed", f"{h.host}:{h.port}",
                       "--out", str(out), "--metrics-out", str(mout)])
    doc = json.loads(out.read_text())
    assert any(e["ph"] == "X" for e in doc["traceEvents"])
    assert "embed.requests" in mout.read_text()


def test_servers_still_reject_unknown_opcodes():
    """Telemetry dispatch must not swallow genuinely bad opcodes."""
    with embed_serve(3, 8) as h:
        s = socket.create_connection(h.address)
        wire.send_frame(s, bytes([200]))
        resp = wire.recv_frame(s)
        s.close()
    with pytest.raises(RuntimeError, match="opcode"):
        wire.parse_response(resp)


# -- acceptance: 6 real processes, one obs_dump -------------------------------

def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _scrapeable(endpoints) -> list | None:
    """One scrape attempt across all endpoints; None while any endpoint
    is still unreachable or span-less."""
    try:
        scrapes = teleserve.scrape_all(endpoints)
    except (ConnectionError, OSError, json.JSONDecodeError):
        return None
    if any(not s.trace.get("events") for s in scrapes):
        return None
    return scrapes


@pytest.mark.slow
def test_six_process_obs_dump_acceptance(tmp_path):
    """Acceptance: coordinator + 2 workers + 2 embed shards + serving
    frontend as real OS processes under ``REPRO_TRACE=1``; one obs_dump
    invocation yields one valid Chrome trace with spans from all six
    processes plus the merged metrics table."""
    e1, e2, cp = _free_port(), _free_port(), _free_port()
    w0, w1, sp = _free_port(), _free_port(), _free_port()
    env = {**os.environ, "REPRO_TRACE": "1"}
    common = ["--graph", "reddit", "--scale", "0.05", "--graph-seed", "3",
              "--clients", "2", "--strategy", "E", "--rounds", "3",
              "--embed", f"127.0.0.1:{e1}", "--embed", f"127.0.0.1:{e2}"]
    endpoints = [("coordinator", f"127.0.0.1:{cp}"),
                 ("embed0", f"127.0.0.1:{e1}"),
                 ("embed1", f"127.0.0.1:{e2}"),
                 ("worker0", f"127.0.0.1:{w0}"),
                 ("worker1", f"127.0.0.1:{w1}"),
                 ("serve", f"127.0.0.1:{sp}")]
    procs = []
    try:
        for port in (e1, e2):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.embed_server",
                 "--port", str(port), "--num-layers", "3",
                 "--hidden", "32"], env=env))
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.fed_coordinator",
             "--port", str(cp), "--timeout", "540"] + common,
            env=env, stdout=subprocess.DEVNULL))
        # serving frontend trains its model in-process (REPRO_TRACE=1 ⇒
        # the training spans are what its ring holds at scrape time)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.gnn_serve",
             "--port", str(sp), "--graph", "reddit", "--scale", "0.05",
             "--graph-seed", "3", "--clients", "2", "--strategy", "E",
             "--rounds", "1", "--cache-rows", "5000"],
            env=env, stdout=subprocess.DEVNULL))
        time.sleep(1.0)
        for i, wp in enumerate((w0, w1)):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.fed_worker",
                 "--coordinator", f"127.0.0.1:{cp}",
                 "--client-ids", str(i), "--obs-port", str(wp),
                 "--straggler-s", "2.0"] + common,
                env=env, stdout=subprocess.DEVNULL))

        # poll until every process is up AND has recorded spans (the
        # straggler pacing keeps the workers alive long enough)
        deadline = time.monotonic() + 540
        while time.monotonic() < deadline:
            if _scrapeable(endpoints) is not None:
                break
            time.sleep(1.0)
        else:
            pytest.fail("deployment never became fully scrapeable")

        out = tmp_path / "trace.json"
        mout = tmp_path / "metrics.txt"
        obs_dump.main(["--coordinator", f"127.0.0.1:{cp}",
                       "--embed", f"127.0.0.1:{e1}",
                       "--embed", f"127.0.0.1:{e2}",
                       "--worker", f"127.0.0.1:{w0}",
                       "--worker", f"127.0.0.1:{w1}",
                       "--serve", f"127.0.0.1:{sp}",
                       "--out", str(out), "--metrics-out", str(mout)])
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()

    doc = json.loads(out.read_text())
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(meta) == 6
    # every one of the six tracks contributed at least one span
    assert {e["pid"] for e in spans} == {e["pid"] for e in meta}
    # real OS pids are distinct processes, not threads of the test
    real_pids = {e["args"]["name"].rsplit("pid ", 1)[1].rstrip(")")
                 for e in meta}
    assert len(real_pids) == 6
    assert os.getpid() not in {int(p) for p in real_pids}
    for ev in spans:
        assert ev["dur"] >= 0.0 and isinstance(ev["ts"], float)
    table = mout.read_text()
    for label in ("coordinator", "embed0", "worker1", "serve"):
        assert f"# {label}" in table
    assert "coord.aggregations" in table
    assert "embed.requests" in table
