"""Federated control plane: coordinator protocol, aggregation policies,
barrier semantics, dropout, scenario injection, and end-to-end parity
of the multi-worker deployment with the in-process trainer.  Plus the
satellite follow-ups that ride on the same machinery: error-feedback
quantization, the adaptive-τ schedule, and transport-independent
RoundStats."""

import dataclasses
import json
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (FederatedGNNTrainer, NetworkModel, Strategy,
                        default_strategies, peak_accuracy)
from repro.exchange import ExchangeClient, InProcessTransport, wire
from repro.fedsvc import protocol
from repro.fedsvc.aggregation import (apply_buffered_deltas, fedavg_leaves,
                                      staleness_scale)
from repro.fedsvc.coordinator import CoordinatorState, serve_in_thread
from repro.fedsvc.runtime import (EvalHarness, RunConfig,
                                  make_coordinator_state)
from repro.fedsvc.worker import FedWorker, WorkerScenario, run_in_thread
from repro.graphs import make_graph
from repro.launch.embed_server import serve_in_thread as embed_serve


# -- wire tensor framing ------------------------------------------------------

def test_tensor_list_roundtrip_byte_exact():
    arrays = [
        np.float32(np.pi).reshape(()),                       # 0-d
        np.arange(12, dtype=np.float32).reshape(3, 4),
        np.array([], dtype=np.int64),
        np.nextafter(np.ones((2, 3), np.float32), 0.0),      # awkward ulps
        np.arange(5, dtype=np.int32),
    ]
    blob = wire.build_tensors(arrays)
    assert len(blob) == wire.tensors_nbytes(arrays)
    back, off = wire.parse_tensors(memoryview(blob))
    assert off == len(blob)
    for a, b in zip(arrays, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()


def test_protocol_body_roundtrip():
    leaves = [np.random.default_rng(0).standard_normal((4, 3))
              .astype(np.float32)]
    body = protocol.build_body(protocol.OP_UPDATE,
                               {"round": 3, "weight": 2.5}, leaves)
    op, header, tensors = protocol.parse_body(body)
    assert op == protocol.OP_UPDATE
    assert header == {"round": 3, "weight": 2.5}
    assert tensors[0].tobytes() == leaves[0].tobytes()
    with pytest.raises(RuntimeError, match="boom"):
        protocol.parse_reply(protocol.build_err("boom"))


# -- aggregation math ---------------------------------------------------------

def test_fedavg_leaves_matches_jnp_tree_map():
    """The shared FedAvg must reproduce the historical jnp aggregation
    bit-for-bit — that equivalence is what lets the coordinator replace
    the in-process loop."""
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    leaves_list = [[rng.standard_normal((5, 3)).astype(np.float32),
                    rng.standard_normal(7).astype(np.float32)]
                   for _ in range(3)]
    weights = [31.0, 17.0, 52.0]
    got = fedavg_leaves(leaves_list, weights)
    wsum = sum(weights)
    want = jax.tree_util.tree_map(
        lambda *ps: sum(w * p for w, p in zip(weights, ps)) / wsum,
        *[[jnp.asarray(l) for l in ls] for ls in leaves_list])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, np.asarray(w))


def test_async_staleness_math():
    assert staleness_scale(0, 0.5) == 1.0
    assert staleness_scale(2, 0.5) == 0.25
    model = [np.zeros(3, np.float32)]
    ups = [(1.0, 0.5, [np.full(3, 2.0, np.float32)]),
           (3.0, 1.0, [np.zeros(3, np.float32)])]
    out = apply_buffered_deltas(model, ups)
    np.testing.assert_allclose(out[0], (1 * 0.5 * 2.0) / (0.5 + 3.0),
                               rtol=1e-6)
    # all-fresh, every client in the buffer ⇒ plain FedAvg step
    base = [np.full(2, 5.0, np.float32)]
    deltas = [[np.full(2, 1.0, np.float32)], [np.full(2, 3.0, np.float32)]]
    out = apply_buffered_deltas(base, [(1.0, 1.0, deltas[0]),
                                       (1.0, 1.0, deltas[1])])
    np.testing.assert_allclose(out[0], 5.0 + 2.0, rtol=1e-6)
    # fully-discounted drain (decay=0, all stale) moves nothing — no NaN
    out = apply_buffered_deltas(base, [(1.0, 0.0, deltas[0])])
    np.testing.assert_array_equal(out[0], base[0])


# -- coordinator protocol (no trainers: tiny fake workers) --------------------

LEAF = np.arange(4, dtype=np.float32)


def _state(**kw):
    kw.setdefault("num_clients", 2)
    kw.setdefault("num_rounds", 1)
    return CoordinatorState(**kw)


def test_registration_and_model_roundtrip():
    state = _state()
    with serve_in_thread(state) as coord:
        init = [np.nextafter(LEAF, 100.0), np.float32(1.5).reshape(())]
        with protocol.CoordinatorClient(coord.address) as a, \
                protocol.CoordinatorClient(coord.address) as b:
            h = a.hello("w0", [0], init_leaves=init)
            assert h["mode"] == "sync" and h["round"] == 0
            # duplicate claim + out-of-range are rejected
            with pytest.raises(RuntimeError, match="already registered"):
                b.hello("w1", [0])
            with pytest.raises(RuntimeError, match="out of range"):
                b.hello("w1", [5])
            b.hello("w1", [1])
            head, leaves = a.get_model(0)
            assert head["round"] == 0 and not head["done"]
            for x, y in zip(init, leaves):       # byte-exact round trip
                assert x.tobytes() == y.tobytes()
                assert x.dtype == y.dtype and x.shape == y.shape


def test_sync_barrier_semantics():
    state = _state(num_rounds=2)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[LEAF])
        b.hello("w1", [1])
        a.get_model(0)

        # wait_pulled blocks until every active client pulled
        a.pulled(0, [0])
        unblocked = threading.Event()

        def waiter():
            with protocol.CoordinatorClient(coord.address) as c:
                c.wait_pulled(0)
            unblocked.set()

        t = threading.Thread(target=waiter, daemon=True)
        t.start()
        time.sleep(0.3)
        assert not unblocked.is_set()          # one client still missing
        b.pulled(0, [1])
        assert unblocked.wait(timeout=5.0)

        # get_model(1) blocks until round 0 fully aggregated
        got_model = threading.Event()

        def getter():
            with protocol.CoordinatorClient(coord.address) as c:
                c.get_model(1)
            got_model.set()

        t2 = threading.Thread(target=getter, daemon=True)
        t2.start()
        a.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF])
        time.sleep(0.3)
        assert state.round == 0 and not got_model.is_set()
        b.update({"round": 0, "client_id": 1, "weight": 3.0}, [LEAF * 5])
        assert got_model.wait(timeout=5.0)
        assert state.round == 1
        np.testing.assert_array_equal(
            state.leaves[0],
            fedavg_leaves([[LEAF], [LEAF * 5]], [1.0, 3.0])[0])
        # stale-round updates are refused
        with pytest.raises(RuntimeError, match="round 0"):
            a.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF])
        a.close()
        b.close()


def test_worker_dropout_mid_round():
    """A worker that dies after the pull barrier but before its update
    must not wedge the round: the coordinator deregisters it and
    aggregates with the survivors."""
    state = _state(num_rounds=2)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[LEAF])
        b.hello("w1", [1])
        a.get_model(0)
        a.pulled(0, [0])
        b.pulled(0, [1])
        a.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF + 1])
        assert state.round == 0                # still waiting on client 1
        b.close()                              # mid-round death
        deadline = time.monotonic() + 5.0
        while state.round == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert state.round == 1                # aggregated without client 1
        assert state.history[0]["clients"] == [0]
        np.testing.assert_array_equal(state.leaves[0], LEAF + 1)
        # round 1 now only needs the survivor
        a.pulled(1, [0])
        a.wait_pulled(1)                       # returns: active ⊆ pulled
        a.update({"round": 1, "client_id": 0, "weight": 1.0}, [LEAF])
        h, _ = a.get_model(2)
        assert h["done"]
        a.close()


def test_async_coordinator_staleness_weighting():
    state = _state(num_rounds=2, mode="async", buffer_size=2,
                   staleness_decay=0.5)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[np.zeros(3, np.float32)])
        b.hello("w1", [1])
        assert a.get_model(0)[0]["version"] == 0
        one = np.ones(3, np.float32)
        a.update({"version": 0, "client_id": 0, "weight": 1.0}, [one])
        assert state.version == 0              # buffer not full yet
        b.update({"version": 0, "client_id": 1, "weight": 1.0}, [one])
        assert state.version == 1              # both fresh ⇒ mean delta
        np.testing.assert_allclose(state.leaves[0], 1.0, rtol=1e-6)
        # staleness 1 (version 0 base at version 1) is discounted 0.5
        h = a.update({"version": 0, "client_id": 0, "weight": 1.0},
                     [np.full(3, 2.0, np.float32)])
        h = b.update({"version": 1, "client_id": 1, "weight": 3.0},
                     [np.zeros(3, np.float32)])
        assert h["done"] and state.version == 2
        np.testing.assert_allclose(
            state.leaves[0], 1.0 + (0.5 * 1.0 * 2.0) / (0.5 + 3.0),
            rtol=1e-6)
        assert state.history[-1]["staleness"] == [1, 0]
        a.close()
        b.close()


# -- end-to-end: threads ------------------------------------------------------

CFG_KW = dict(graph="reddit", scale=0.05, graph_seed=3, num_clients=2,
              batch_size=64, seed=0)


@pytest.fixture(scope="module")
def ref_run():
    """In-process reference: 2 clients, strategy E, 2 rounds."""
    g = make_graph("reddit", scale=0.05, seed=3)
    tr = FederatedGNNTrainer(g, 2, default_strategies()["E"],
                             batch_size=64, seed=0)
    stats = tr.train(2)
    return tr, stats


def test_sync_control_plane_bit_identical(ref_run):
    """Acceptance: a 2-worker deployment (real coordinator + TCP embed
    shards, workers as threads with their own trainers) reproduces the
    in-process FedAvg parameters and accuracies."""
    tr_ref, stats = ref_run
    shards = [embed_serve(3, 32), embed_serve(3, 32)]
    try:
        cfg = RunConfig(strategy="E", rounds=2,
                        embed_addrs=[f"{h.host}:{h.port}" for h in shards],
                        **CFG_KW)
        harness = EvalHarness(cfg)
        state = CoordinatorState(num_clients=2, num_rounds=2, mode="sync",
                                 init_leaves=harness.init_leaves(),
                                 eval_fn=harness.evaluate_leaves)
        with serve_in_thread(state) as coord:
            workers = [FedWorker(cfg, [i], coord.address) for i in range(2)]
            threads = [run_in_thread(w) for w in workers]
            assert coord.join(timeout=600)
            for t in threads:
                t.join(timeout=60)
        assert [h["accuracy"] for h in state.history] == \
            [s.accuracy for s in stats]
        for a, b in zip(tr_ref.params_leaves(), state.leaves):
            np.testing.assert_array_equal(a, b)
        # dual ledgers populated on every aggregation
        for h in state.history:
            assert h["round_modelled_s"] > 0 and h["wall_s"] > 0
    finally:
        for h in shards:
            h.stop()


def test_async_with_straggler_and_dropout_scenarios():
    """Async mode under scenario injection: a paced straggler and a
    dropout-prone worker; the coordinator must still reach its
    aggregation budget, with staleness recorded."""
    shards = [embed_serve(3, 32)]
    try:
        cfg = RunConfig(strategy="E", rounds=3,
                        overrides={"aggregation": "async", "buffer_size": 2,
                                   "staleness_decay": 0.5},
                        embed_addrs=[f"{h.host}:{h.port}" for h in shards],
                        **CFG_KW)
        state = CoordinatorState(num_clients=2, num_rounds=3, mode="async",
                                 buffer_size=2, staleness_decay=0.5)
        with serve_in_thread(state) as coord:
            workers = [
                FedWorker(cfg, [0], coord.address,
                          scenario=WorkerScenario(straggler_s=0.2)),
                FedWorker(cfg, [1], coord.address,
                          scenario=WorkerScenario(pacing=1.5, seed=1)),
            ]
            threads = [run_in_thread(w) for w in workers]
            assert coord.join(timeout=600)
            for t in threads:
                t.join(timeout=60)
        assert state.version == 3
        assert all("staleness" in h for h in state.history)
        # the injected straggler delay must show up in the measured
        # ledger of worker 0's records
        assert all(r["measured_s"] >= 0.2 for r in workers[0].records)
    finally:
        for h in shards:
            h.stop()


# -- end-to-end: real subprocesses --------------------------------------------

def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


@pytest.mark.slow
def test_multiprocess_smoke_matches_in_process(ref_run, tmp_path):
    """Acceptance: coordinator + 2 workers + 2 embed shards as real OS
    processes (the launch CLIs), FedAvg accuracies equal to the
    in-process trainer."""
    _, stats = ref_run
    e1, e2, cp = _free_port(), _free_port(), _free_port()
    common = ["--graph", "reddit", "--scale", "0.05", "--graph-seed", "3",
              "--clients", "2", "--strategy", "E", "--rounds", "2",
              "--embed", f"127.0.0.1:{e1}", "--embed", f"127.0.0.1:{e2}"]
    out_json = tmp_path / "history.json"
    procs = []
    try:
        for port in (e1, e2):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.embed_server",
                 "--port", str(port), "--num-layers", "3",
                 "--hidden", "32"]))
        coord = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.fed_coordinator",
             "--port", str(cp), "--timeout", "540",
             "--out", str(out_json)] + common,
            stdout=subprocess.PIPE, text=True)
        procs.append(coord)
        time.sleep(1.0)
        for i in range(2):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.fed_worker",
                 "--coordinator", f"127.0.0.1:{cp}",
                 "--client-ids", str(i)] + common,
                stdout=subprocess.DEVNULL))
        out, _ = coord.communicate(timeout=600)
        assert "fed_coordinator DONE" in out, out
        history = json.loads(out_json.read_text())
        assert [h["accuracy"] for h in history] == \
            [s.accuracy for s in stats]
        assert all(h["round_modelled_s"] > 0 and h["round_measured_s"] > 0
                   for h in history)
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()


# -- satellites ---------------------------------------------------------------

def test_embeddings_stored_transport_independent():
    """RoundStats.embeddings_stored must agree between the in-process
    transport and live TCP shards (the STATS RPC summed across shards),
    so telemetry is transport-independent."""
    g = make_graph("reddit", scale=0.05, seed=3)
    base = default_strategies()["E"]
    st_in = dataclasses.replace(base, num_server_shards=2)
    n_in = FederatedGNNTrainer(g, 2, st_in, batch_size=64, seed=0) \
        .train(1)[-1].embeddings_stored
    handles = [embed_serve(3, 32), embed_serve(3, 32)]
    try:
        st_tcp = dataclasses.replace(base, num_server_shards=2,
                                     transport="tcp")
        tr = FederatedGNNTrainer(g, 2, st_tcp, batch_size=64, seed=0,
                                 transport_addrs=[h.address
                                                  for h in handles])
        n_tcp = tr.train(1)[-1].embeddings_stored
        tr.exchange.close()
    finally:
        for h in handles:
            h.stop()
    assert n_in == n_tcp > 0


def test_error_feedback_unit_semantics():
    """EF carries the quantization residual into the next push: after a
    second push of identical raw rows, the server value plus the stored
    residual reconstructs the raw value exactly."""
    tp = InProcessTransport(3, 8)
    ex = ExchangeClient(tp, "int8", error_feedback=True)
    gids = np.arange(10)
    ex.register(gids)
    rng = np.random.default_rng(0)
    raw = [rng.standard_normal((10, 8)).astype(np.float32)
           for _ in range(2)]
    ex.push(gids, raw)
    assert ex.ef.max_abs_residual > 0          # int8 is lossy
    ex.push(gids, raw)
    # compensated = raw + r1; server holds decode(compensated);
    # residual2 = compensated - server  ⇒  server + residual2 - r1 = raw
    # (we check the weaker, telemetry-visible invariant: the residual
    # stays bounded by one quantization step instead of accumulating)
    step = np.abs(np.stack(raw)).max() / 127 * 2
    assert ex.ef.max_abs_residual <= step
    # fp32 codec ⇒ exact wire ⇒ zero residual
    ex32 = ExchangeClient(InProcessTransport(3, 8), "fp32",
                          error_feedback=True)
    ex32.register(gids)
    ex32.push(gids, raw)
    assert ex32.ef.max_abs_residual == 0.0


def test_int8_error_feedback_recovers_fp32_accuracy():
    """Satellite acceptance: int8 + EF reaches fp32 peak accuracy within
    tolerance on the synthetic graph."""
    g = make_graph("reddit", scale=0.08, seed=3)
    runs = {}
    for name, knobs in [("fp32", {}),
                        ("int8", {"codec": "int8"}),
                        ("int8+ef", {"codec": "int8",
                                     "error_feedback": True})]:
        st = dataclasses.replace(default_strategies()["E"], **knobs)
        tr = FederatedGNNTrainer(g, 2, st, batch_size=64, seed=0)
        runs[name] = peak_accuracy(tr.train(4))
    assert runs["int8+ef"] >= runs["fp32"] - 0.02, runs


def test_delta_schedule_shapes():
    base = Strategy("E", delta_threshold=0.1)
    const = base
    assert const.delta_for_round(0) == 0.1
    assert const.delta_for_round(99) == 0.1
    lin = dataclasses.replace(base, delta_schedule="linear", delta_rounds=4)
    assert lin.delta_for_round(0) == 0.0
    assert lin.delta_for_round(2) == pytest.approx(0.05)
    assert lin.delta_for_round(4) == pytest.approx(0.1)
    assert lin.delta_for_round(400) == pytest.approx(0.1)
    plat = dataclasses.replace(base, delta_schedule="plateau",
                               plateau_window=2, plateau_eps=0.01)
    assert plat.delta_for_round(0, []) == 0.0              # no history
    assert plat.delta_for_round(3, [0.1, 0.2, 0.3]) == 0.0  # improving
    assert plat.delta_for_round(5, [0.1, 0.3, 0.301, 0.302]) == 0.1
    # no τ at all ⇒ schedule is moot
    assert Strategy("E").delta_for_round(3) is None
    with pytest.raises(ValueError, match="delta_schedule"):
        dataclasses.replace(base, delta_schedule="bogus").delta_for_round(0)


def test_trainer_applies_delta_schedule():
    g = make_graph("reddit", scale=0.05, seed=3)
    st = dataclasses.replace(default_strategies()["E"],
                             delta_threshold=0.2, delta_schedule="linear",
                             delta_rounds=4)
    tr = FederatedGNNTrainer(g, 2, st, batch_size=64, seed=0)
    tr.set_round_tau(0)
    assert all(ex.delta.tau == 0.0 for ex in tr.ex_clients)
    tr.set_round_tau(2)
    assert all(ex.delta.tau == pytest.approx(0.1) for ex in tr.ex_clients)


# -- coordinator churn + aggregation-set regressions --------------------------


def _wait_for(predicate, timeout=5.0):
    deadline = time.monotonic() + timeout
    while not predicate() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert predicate()


def test_sync_orphaned_update_not_aggregated():
    """Regression: an update from a client whose worker deregistered
    mid-round must not fold into FedAvg (the old trigger only checked
    active ⊆ updates, so the dead client's update rode along), and the
    history must record the set actually aggregated."""
    state = _state(num_rounds=1)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[LEAF])
        b.hello("w1", [1])
        a.get_model(0)
        a.pulled(0, [0])
        b.pulled(0, [1])
        b.update({"round": 0, "client_id": 1, "weight": 9.0}, [LEAF * 100])
        assert state.round == 0               # still waiting on client 0
        b.close()                             # dies with update pending
        _wait_for(lambda: "w1" not in state.workers)
        assert 1 not in state.updates         # orphan cleared
        a.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF + 2])
        _wait_for(lambda: state.round == 1)
        assert state.history[0]["clients"] == [0]
        np.testing.assert_array_equal(state.leaves[0], LEAF + 2)
        a.close()


def test_sync_all_workers_drop_does_not_wedge():
    """Regression: if every worker dies mid-round, the pending updates
    are stale — a later re-join must restart the round from scratch,
    not aggregate the dead processes' leftovers."""
    state = _state(num_rounds=1)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[LEAF])
        b.hello("w1", [1])
        a.get_model(0)
        a.pulled(0, [0])
        a.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF * 50])
        # kill the updater FIRST and wait for its deregistration — if
        # the other worker's death were observed first, the coordinator
        # would legitimately close the round over the survivor
        a.close()
        _wait_for(lambda: "w0" not in state.workers)
        assert 0 not in state.updates         # orphan cleared at once
        b.close()                             # now everyone is gone
        _wait_for(lambda: not state.workers)
        assert state.updates == {} and state.round == 0
        # one worker re-joins owning both clients and replays the round
        c = protocol.CoordinatorClient(coord.address)
        c.hello("w2", [0, 1])
        c.get_model(0)
        c.pulled(0, [0, 1])
        c.wait_pulled(0)
        c.update({"round": 0, "client_id": 0, "weight": 1.0}, [LEAF + 1])
        c.update({"round": 0, "client_id": 1, "weight": 1.0}, [LEAF + 3])
        _wait_for(lambda: state.round == 1)
        assert state.history[0]["clients"] == [0, 1]
        np.testing.assert_array_equal(
            state.leaves[0],
            fedavg_leaves([[LEAF + 1], [LEAF + 3]], [1.0, 1.0])[0])
        c.close()


def test_hello_empty_init_consistency():
    """Regression: an empty-but-non-None init leaves list used to set
    has_init=True with zero tensors, seeding a zero-parameter model.
    The stub now sends has_init only for non-empty leaves, and the
    server rejects a has_init header without tensors."""
    state = _state()
    with serve_in_thread(state) as coord:
        with protocol.CoordinatorClient(coord.address) as c:
            h = c.hello("w0", [0], init_leaves=[])
            assert h["mode"] == "sync"
            assert state.leaves is None       # [] is "no init", not a model
            # a crafted has_init with no tensors is refused server-side
            with pytest.raises(RuntimeError, match="empty init"):
                c._rpc(protocol.OP_HELLO,
                       {"worker_id": "w0", "client_ids": [0],
                        "has_init": True})
            c.hello("w0", [0], init_leaves=[LEAF])   # re-hello, real init
            assert state._num_params() == len(LEAF)


def test_sync_client_sampling_subset_and_eligible_only():
    """sample_frac=0.5 with K=2: each round runs over exactly one
    client; the barrier and the FedAvg trigger ignore the unsampled
    one, and a gratuitous update from it never enters the aggregate."""
    state = _state(num_rounds=2, sample_frac=0.5)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[LEAF])
        b.hello("w1", [1])
        stubs = {0: a, 1: b}
        seen = []
        for rnd in range(2):
            h, _ = a.get_model(rnd)
            assert not h["done"]
            sampled = h["sampled"]
            assert len(sampled) == 1
            seen.append(sampled[0])
            cid = sampled[0]
            other = 1 - cid
            # the unsampled client's update must not trigger or join
            stubs[other].update({"round": rnd, "client_id": other,
                                 "weight": 99.0}, [LEAF * 99])
            assert state.round == rnd         # not aggregated
            stubs[cid].pulled(rnd, [cid])
            stubs[cid].wait_pulled(rnd)       # barrier ignores `other`
            stubs[cid].update({"round": rnd, "client_id": cid,
                               "weight": 1.0}, [LEAF + rnd])
            _wait_for(lambda: state.round == rnd + 1)
            assert state.history[rnd]["clients"] == [cid]
            np.testing.assert_array_equal(state.leaves[0], LEAF + rnd)
        assert state.done
        a.close()
        b.close()


def test_async_client_sampling_rate_limits_and_refuses():
    """sample_frac=0.5, K=2, async: sample_seed=1 draws {0} at version 0
    and {1} at version 1.  The unsampled worker's get_model parks until
    its client is drawn (rate-limiting), and an update from a client not
    sampled at its base version is refused — no buffering, no version
    bump, no weight-wire charge."""
    state = _state(num_rounds=2, mode="async", buffer_size=1,
                   sample_frac=0.5, sample_seed=1)
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[np.zeros(3, np.float32)])
        b.hello("w1", [1])
        h, _ = a.get_model(0)
        assert h["version"] == 0 and h["sampled"] == [0]
        # client 1 was not sampled at version 0: its update is refused
        bytes_before = state.weight_bytes_cum
        h = b.update({"version": 0, "client_id": 1, "weight": 1.0},
                     [np.ones(3, np.float32)])
        assert h["accepted"] is False
        assert state.version == 0 and state.buffer == []
        assert state.weight_bytes_cum == bytes_before
        # w1's get_model parks while its client is unsampled
        got = {}
        unblocked = threading.Event()

        def fetch():
            got["head"], got["leaves"] = b.get_model(0)
            unblocked.set()

        t = threading.Thread(target=fetch, daemon=True)
        t.start()
        time.sleep(0.3)
        assert not unblocked.is_set()          # still parked at version 0
        # the sampled client's update advances the version ...
        h = a.update({"version": 0, "client_id": 0, "weight": 1.0},
                     [np.ones(3, np.float32)])
        assert h["accepted"] is True and state.version == 1
        # ... which samples client 1 and releases the parked worker
        assert unblocked.wait(5.0)
        t.join()
        assert got["head"]["version"] == 1
        assert got["head"]["sampled"] == [1]
        h = b.update({"version": 1, "client_id": 1, "weight": 1.0},
                     [np.ones(3, np.float32)])
        assert h["accepted"] is True and h["done"]
        assert state.version == 2
        assert [rec["clients"] for rec in state.history] == [[0], [1]]
        a.close()
        b.close()


def test_async_dead_sample_redrawn_on_disconnect():
    """If every client sampled at the current version deregisters, the
    sample is redrawn from the survivors — parked workers wake up
    instead of waiting on the dead forever."""
    state = _state(num_rounds=1, mode="async", buffer_size=1,
                   sample_frac=0.5, sample_seed=1)   # version 0 → {0}
    with serve_in_thread(state) as coord:
        a = protocol.CoordinatorClient(coord.address)
        b = protocol.CoordinatorClient(coord.address)
        a.hello("w0", [0], init_leaves=[np.zeros(3, np.float32)])
        b.hello("w1", [1])
        got = {}
        unblocked = threading.Event()

        def fetch():
            got["head"], got["leaves"] = b.get_model(0)
            unblocked.set()

        t = threading.Thread(target=fetch, daemon=True)
        t.start()
        time.sleep(0.3)
        assert not unblocked.is_set()          # parked: {0} is sampled
        a.close()                              # the whole sample dies
        assert unblocked.wait(5.0)
        t.join()
        assert got["head"]["sampled"] == [1]   # redrawn from survivors
        h = b.update({"version": 0, "client_id": 1, "weight": 1.0},
                     [np.ones(3, np.float32)])
        assert h["accepted"] is True and h["done"]
        assert state.history[-1]["clients"] == [1]
        b.close()


# -- weight-wire compression + churn (worker-level, strategy D) ---------------

D_KW = dict(graph="reddit", scale=0.05, graph_seed=3, num_clients=2,
            batch_size=64, epochs_per_round=2, seed=0)


def _run_deployment(overrides, *, rounds=4, scenarios=None, timeout=600):
    """Thread-deployment helper: coordinator + one worker per client,
    strategy D (no embedding plane — these tests isolate the weight
    wire and the churn machinery)."""
    cfg = RunConfig(strategy="D", rounds=rounds, overrides=overrides,
                    **D_KW)
    state = make_coordinator_state(cfg)
    scenarios = scenarios or {}
    with serve_in_thread(state) as coord:
        workers = [FedWorker(cfg, [i], coord.address, worker_id=f"w{i}",
                             scenario=scenarios.get(i))
                   for i in range(2)]
        threads = [run_in_thread(w) for w in workers]
        assert coord.join(timeout=timeout)
        for t in threads:
            t.join(timeout=60)
    return state, workers


@pytest.fixture(scope="module")
def d_ref_run():
    """Uninterrupted raw-weight-wire reference deployment (strategy D,
    4 rounds) shared by the weight-codec and re-join tests."""
    return _run_deployment({})


@pytest.mark.slow
def test_weight_codec_int8_ef_matches_raw_and_compresses(d_ref_run):
    """Tentpole acceptance (test-scale): the int8+EF weight wire
    reaches the raw fp32 baseline's peak accuracy within tolerance at
    ≥3× fewer weight-plane bytes per steady-state round, with both
    ledgers populated."""
    ref_state, _ = d_ref_run
    state, workers = _run_deployment({"weight_codec": "int8",
                                      "weight_error_feedback": True})
    assert len(state.history) == len(ref_state.history)
    for h in state.history + ref_state.history:
        assert h["weight_bytes"] > 0 and h["weight_modelled_s"] > 0
    # steady state: round ≥ 1 (first get_models ship the full model)
    raw_b = np.mean([h["weight_bytes"] for h in ref_state.history[1:]])
    cmp_b = np.mean([h["weight_bytes"] for h in state.history[1:]])
    assert raw_b / cmp_b >= 3.0, (raw_b, cmp_b)
    # codec-aware modelled ledger follows the byte reduction
    raw_t = np.mean([h["weight_modelled_s"] for h in ref_state.history[1:]])
    cmp_t = np.mean([h["weight_modelled_s"] for h in state.history[1:]])
    assert cmp_t < raw_t
    peak_raw = max(h["accuracy"] for h in ref_state.history)
    peak_cmp = max(h["accuracy"] for h in state.history)
    assert peak_cmp >= peak_raw - 0.02, (peak_raw, peak_cmp)
    # EF actually engaged: a lossy codec leaves a nonzero residual
    assert any(ef.max_abs_residual > 0
               for w in workers for ef in w._wef.values())


@pytest.mark.slow
def test_worker_rejoin_mid_training(d_ref_run):
    """Acceptance: a worker killed mid-round re-joins on a fresh
    connection with the same client ids, the run completes all rounds,
    it participates again by the final round, and convergence matches
    the uninterrupted run within tolerance."""
    ref_state, _ = d_ref_run
    # strategy-D rounds are sub-second once jit is warm: the rejoin
    # delay must be short enough that the worker returns with rounds
    # still to play
    state, workers = _run_deployment(
        {}, rounds=4,
        scenarios={1: WorkerScenario(drop_round=1, rejoin=True,
                                     rejoin_delay_s=0.05)})
    assert workers[1].rejoins == 1
    assert len(state.history) == 4
    for h in state.history:
        assert h["clients"]                   # never an empty aggregate
        assert set(h["clients"]) <= {0, 1}
    # the rejoined worker contributes again before the run ends
    assert 1 in set(c for h in state.history[1:] for c in h["clients"])
    # the churned run loses (at least) one full aggregation round, so
    # on a still-steep convergence curve it trails the uninterrupted
    # run by about one round — gate against the reference's
    # previous-round accuracy, which still fails a worker that never
    # recovers (accuracy would sit at the round-0 level)
    final_ref_prev = ref_state.history[-2]["accuracy"]
    final = state.history[-1]["accuracy"]
    assert final >= final_ref_prev - 0.1, (final_ref_prev, final)


@pytest.mark.slow
def test_weight_codec_async_smoke():
    """FedBuff async with the compressed weight wire: updates are
    codec-encoded deltas, downloads become version diffs, the run
    reaches its aggregation budget with the wire ledger populated."""
    state, workers = _run_deployment({"aggregation": "async",
                                      "buffer_size": 2,
                                      "weight_codec": "int8"}, rounds=2)
    assert state.version == 2
    assert all(h["weight_bytes"] > 0 and h["weight_modelled_s"] > 0
               for h in state.history)
    assert not any(w.disconnected and not w.records for w in workers)


@pytest.mark.slow
def test_sampled_sync_smoke_workers():
    """sample_frac=0.5 end to end: every round aggregates exactly one
    client, unsampled workers skip cleanly, and the run finishes."""
    state, workers = _run_deployment({"sample_frac": 0.5})
    assert len(state.history) == 4
    for h in state.history:
        assert len(h["clients"]) == 1
    # each worker recorded only the rounds its client was drawn in
    for i, w in enumerate(workers):
        drawn = [h["round"] for h in state.history if h["clients"] == [i]]
        assert [r["round"] for r in w.records] == drawn


@pytest.mark.slow
def test_barrier_wait_split_from_measured():
    """Regression: a fast worker's measured_s used to include the sync
    wait_pulled barrier, charging a slow *puller*'s delay to everyone
    (round_measured_s = max over clients then exceeded any single
    worker's own work).  The wait is now its own field."""
    state, workers = _run_deployment(
        {}, rounds=1,
        scenarios={1: WorkerScenario(pull_delay_s=8.0)})
    fast, slow = workers[0].records[0], workers[1].records[0]
    # the slow puller spends 8s of its own pull phase: that is ITS
    # measured time, and the fast worker's *barrier* wait — not the
    # fast worker's measured time (8s >> the fast worker's round-0
    # train incl. jit warmup, so the ordering is robust)
    assert slow["measured_s"] >= 8.0
    assert slow["barrier_s"] < 1.0
    assert fast["barrier_s"] >= 2.0
    assert fast["measured_s"] <= slow["measured_s"] - 2.0
    assert state.history[0]["max_barrier_s"] >= 2.0
    # the round ledger is the max of *own-work* times
    assert state.history[0]["round_measured_s"] >= slow["measured_s"]


def test_runconfig_roundtrip_and_strategy_build():
    cfg = RunConfig(strategy="OPP", rounds=5,
                    overrides={"codec": "int8", "delta_threshold": 0.05,
                               "aggregation": "async"},
                    embed_addrs=["127.0.0.1:7040"])
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    st = back.build_strategy()
    assert st.codec == "int8" and st.aggregation == "async"
    assert st.transport == "tcp"               # inferred from embed_addrs
    assert st.prefetch_frac == 0.25            # OPP base preserved
