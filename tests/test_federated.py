"""Federated core: embedding server, pruning, strategies, round lifecycle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EmbeddingServer, FederatedGNNTrainer, NetworkModel,
                        Strategy, default_strategies, frequency_scores,
                        peak_accuracy, retention_pruned_sets,
                        score_remote_nodes, time_to_accuracy, top_fraction)
from repro.graphs import bfs_partition, make_client_shards, make_graph


# -- embedding server ---------------------------------------------------------

def test_server_push_pull_roundtrip():
    srv = EmbeddingServer(num_layers=3, hidden=8)
    ids = np.array([5, 9, 2])
    srv.register(ids)
    vals = [np.random.default_rng(i).standard_normal((3, 8)).astype(np.float32)
            for i in range(2)]
    t_push = srv.push(ids, vals)
    got, t_pull = srv.pull(ids)
    for a, b in zip(vals, got):
        np.testing.assert_array_equal(a, b)
    assert t_push > 0 and t_pull > 0
    assert srv.num_embeddings_stored == 3 * 2
    # selective layer pull
    got1, _ = srv.pull(ids, layers=[2])
    np.testing.assert_array_equal(got1[0], vals[1])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50), st.integers(2, 4), st.integers(1, 16))
def test_server_roundtrip_property(n, L, hidden):
    srv = EmbeddingServer(L, hidden)
    ids = np.arange(n) * 3 + 1
    srv.register(ids)
    rng = np.random.default_rng(n)
    vals = [rng.standard_normal((n, hidden)).astype(np.float32)
            for _ in range(L - 1)]
    srv.push(ids, vals)
    # pulls are order-sensitive on ids
    perm = rng.permutation(n)
    got, _ = srv.pull(ids[perm])
    for a, b in zip(vals, got):
        np.testing.assert_array_equal(a[perm], b)


def test_network_model_monotone():
    net = NetworkModel()
    assert net.transfer_time(1000, 32, 2) < net.transfer_time(100000, 32, 2)
    assert net.transfer_time(100, 32, 2, n_rpcs=50) > \
        net.transfer_time(100, 32, 2, n_rpcs=1)


# -- pruning -------------------------------------------------------------------

def test_retention_limits(small_graph):
    g = small_graph
    part = bfs_partition(g, 4, seed=0)
    full = make_client_shards(g, part)
    for limit in (0, 2, 4):
        shards = make_client_shards(g, part, retention_limit=limit, seed=0)
        for sh, fu in zip(shards, full):
            assert len(sh.pull_nodes) <= len(fu.pull_nodes)
            if limit == 0:
                assert len(sh.pull_nodes) == 0
            # §4.1.1: each local vertex keeps <= limit remote in-edges
            for u in range(sh.num_local):
                nbrs = sh.indices[sh.indptr[u]: sh.indptr[u + 1]]
                assert int((nbrs >= sh.num_local).sum()) <= limit
            # local edges are untouched by pruning
            for u in range(sh.num_local):
                nbrs = sh.indices[sh.indptr[u]: sh.indptr[u + 1]]
                fnbrs = fu.indices[fu.indptr[u]: fu.indptr[u + 1]]
                assert int((nbrs < sh.num_local).sum()) == \
                    int((fnbrs < fu.num_local).sum())
    assert retention_pruned_sets(g, part, None) is None  # P_inf


def _retention_reference(g, part, limit, seed):
    """Per-vertex mirror of the vectorized retention rule: one uniform
    priority per edge, each boundary vertex keeps its ``limit``
    lowest-priority remote in-neighbours."""
    rng = np.random.default_rng(seed)
    prio = rng.random(g.num_edges)
    k = int(part.max()) + 1
    out = {c: set() for c in range(k)}
    for u in range(g.num_vertices):
        c = int(part[u])
        lo, hi = int(g.indptr[u]), int(g.indptr[u + 1])
        nbrs = g.indices[lo:hi].astype(np.int64)
        pr = prio[lo:hi]
        rem = part[nbrs] != c
        rnb, rpr = nbrs[rem], pr[rem]
        keep = rnb if len(rnb) <= limit else rnb[np.argsort(rpr)[:limit]]
        out[c].update(int(v) for v in keep)
    return {c: np.array(sorted(v), dtype=np.int64) for c, v in out.items()}


@pytest.mark.parametrize("limit,seed", [(1, 0), (3, 0), (4, 9)])
def test_retention_pruned_sets_matches_reference(small_graph, limit, seed):
    """The vectorized retention_pruned_sets is output-identical to the
    per-vertex reference for fixed seeds (ISSUE-5 satellite gate)."""
    g = small_graph
    part = bfs_partition(g, 4, seed=0)
    got = retention_pruned_sets(g, part, limit, seed=seed)
    want = _retention_reference(g, part, limit, seed)
    assert set(got) == set(want)
    for c in got:
        np.testing.assert_array_equal(got[c], want[c])


def test_frequency_scores_range_and_signal(small_shards):
    shards, _ = small_shards
    sh = shards[0]
    s = frequency_scores(sh, num_hops=3)
    assert s.shape == (sh.num_remote,)
    assert np.all(s >= 0) and np.all(s <= 1)
    assert s.max() > 0  # somebody is reachable


@pytest.mark.parametrize("kind", ["frequency", "degree", "bridge"])
def test_score_kinds(small_shards, kind):
    shards, _ = small_shards
    s = score_remote_nodes(shards[1], kind, num_hops=2)
    assert s.shape == (shards[1].num_remote,)
    assert np.all(np.isfinite(s))


def test_top_fraction():
    scores = np.array([0.1, 0.9, 0.5, 0.7])
    idx = top_fraction(scores, 0.5)
    assert set(idx) == {1, 3}
    r = top_fraction(scores, 0.5, rng=np.random.default_rng(0),
                     random_subset=True)
    assert len(r) == 2


# -- strategies / trainer -------------------------------------------------------

def test_default_strategies_knobs():
    s = default_strategies()
    assert not s["D"].use_embeddings
    assert s["E"].retention_limit is None and not s["E"].overlap_push
    assert s["OPG"].scored_prune_frac == 0.25
    assert s["OPP"].prefetch_frac == 0.25
    assert "P_4" in s["OP"].describe()


@pytest.fixture(scope="module")
def tiny_dense():
    return make_graph("reddit", scale=0.12, seed=11)


def run(graph, strat, rounds=4, **kw):
    tr = FederatedGNNTrainer(graph, 3, strat, batch_size=64, seed=0, **kw)
    return tr, tr.train(rounds)


def test_trainer_round_lifecycle(tiny_dense):
    strat = default_strategies()["E"]
    tr, stats = run(tiny_dense, strat)
    assert len(stats) == 4
    assert stats[-1].cum_time > stats[0].cum_time > 0
    ph = stats[-1].phases
    assert ph.pull > 0 and ph.train > 0 and ph.push_transfer > 0
    assert tr.server.num_embeddings_stored > 0
    assert 0 <= stats[-1].accuracy <= 1


def test_embeddings_improve_dense_graph(tiny_dense):
    """Fig. 6a trend: embedding sharing (E) beats default FL (D) on a
    dense graph with cross-client dependencies."""
    _, d_stats = run(tiny_dense, default_strategies()["D"], rounds=8)
    _, e_stats = run(tiny_dense, default_strategies()["E"], rounds=8)
    assert peak_accuracy(e_stats) >= peak_accuracy(d_stats) - 0.01


def test_pruning_reduces_traffic(tiny_dense):
    _, e_stats = run(tiny_dense, default_strategies()["E"])
    _, p_stats = run(tiny_dense, default_strategies()["P"])
    assert p_stats[-1].embeddings_stored < e_stats[-1].embeddings_stored
    assert p_stats[-1].phases.pull <= e_stats[-1].phases.pull + 1e-6


def test_overlap_hides_push(tiny_dense):
    """§4.2: with overlap the push transfer is absorbed into the final
    epoch wall time whenever train-epoch >= push."""
    strat_e = default_strategies()["E"]
    strat_o = default_strategies()["O"]
    tr_e, e_stats = run(tiny_dense, strat_e)
    tr_o, o_stats = run(tiny_dense, strat_o)
    pe, po = e_stats[-1].phases, o_stats[-1].phases
    # client_total with overlap must not exceed the serial sum
    serial = po.pull + po.train + po.push_compute + po.push_transfer
    assert po.client_total(overlap=True, interference=1.0, epochs=3) \
        <= serial + 1e-9


def test_prefetch_dynamic_pull_accounting(tiny_dense):
    _, stats = run(tiny_dense, default_strategies()["OPP"])
    s = stats[-1]
    # prefetch round must record on-demand RPCs (dense graph ⇒ misses)
    assert s.phases.pull > 0
    assert len(s.pull_rpc_sizes) >= 0  # histogram exists
    _, e_stats = run(tiny_dense, default_strategies()["E"])
    # prefetch pulls fewer embeddings upfront than pull-all
    assert s.phases.pull < e_stats[-1].phases.pull + 1e-6


def test_time_to_accuracy_metric():
    from repro.core.federated import RoundStats, PhaseTimes
    mk = lambda i, acc, t: RoundStats(i, acc, t, t * (i + 1), PhaseTimes(),
                                      [], 0, 0.0)
    stats = [mk(0, 0.2, 1.0), mk(1, 0.9, 1.0), mk(2, 0.9, 1.0)]
    assert time_to_accuracy(stats, 0.5, smooth=1) == 2.0
    assert time_to_accuracy(stats, 0.99) is None
