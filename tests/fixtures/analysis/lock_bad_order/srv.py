"""Bad fixture: lock l1 is taken before l2 on one path (through a
helper call) and l2 before l1 on another → LD003 cycle."""
import threading


class Server:
    def __init__(self):
        self.l1 = threading.Lock()
        self.l2 = threading.Lock()

    def one_then_two(self):
        with self.l1:
            self._grab_two()

    def _grab_two(self):
        with self.l2:
            pass

    def two_then_one(self):
        with self.l2:
            with self.l1:
                pass
