"""Bad fixture: Condition.wait with no predicate loop → LD002 (a bare
wait misses wakeups and returns spuriously)."""
import threading


class Server:
    def __init__(self):
        self.cond = threading.Condition()
        self.ready = False       # guarded-by: self.cond

    def await_ready(self):
        with self.cond:
            if not self.ready:
                self.cond.wait(1.0)      # no while loop!
            return self.ready
