"""Good fixture: jitted code sticks to jnp, no host syncs, sizes are
parameters rather than closure captures."""
import jax
import jax.numpy as jnp


@jax.jit
def scale(x, s):
    return x * s


def step(x):
    y = scale(x, jnp.float32(2.0))
    return jnp.sum(y)
