"""Bad fixture: .item() and float() on traced values → JX002."""
import jax
import jax.numpy as jnp


@jax.jit
def loss(x):
    m = jnp.mean(x)
    return float(m.item())
