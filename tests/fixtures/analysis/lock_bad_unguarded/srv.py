"""Bad fixture: reads a guarded field outside the lock → LD001.
Mirrors the render_text bug pattern: snapshot under the lock, then a
second read of the shared dict after releasing it."""
import threading


class Server:
    def __init__(self):
        self.lock = threading.Lock()
        self.items = {}          # guarded-by: self.lock

    def put(self, k, v):
        with self.lock:
            self.items[k] = v

    def render(self):
        with self.lock:
            names = sorted(self.items)
        lines = []
        for n in names:
            lines.append(f"{n} {self.items[n]}")     # unguarded re-read!
        return "\n".join(lines)
