"""Bad fixture: builder packs a u16 seq, parser unpacks a u32 → WP005."""
import struct

import numpy as np

OP_PING = 1
OP_DATA = 2

_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")


def build_ping() -> bytes:
    return _U8.pack(OP_PING)


def build_data(seq: int, ids: np.ndarray) -> bytes:
    return (_U8.pack(OP_DATA) + _U16.pack(seq)
            + _U64.pack(len(ids)) + np.asarray(ids, np.int64).tobytes())


def parse_request(body: bytes):
    view = memoryview(body)
    (op,) = _U8.unpack_from(view, 0)
    if op == OP_PING:
        return op, {}
    if op == OP_DATA:
        (seq,) = _U32.unpack_from(view, 1)      # builder sent u16!
        (n,) = _U64.unpack_from(view, 5)
        ids = np.frombuffer(view[13:13 + 8 * n], np.int64)
        return op, {"seq": seq, "ids": ids}
    raise ValueError(f"unknown opcode {op}")
