"""Bad fixture: a traced inner function closes over a shape-derived
python int → JX003 (recompiles for every distinct size)."""
import jax
import jax.numpy as jnp


def gather_all(table, ids):
    n = len(ids)

    def _inner(t):
        return jnp.take(t, jnp.arange(n), axis=0)

    return jax.jit(_inner)(table)
