"""Bad fixture: guarded-by names a lock attribute that does not exist
on the class → LD004."""
import threading


class Server:
    def __init__(self):
        self.lock = threading.Lock()
        self.items = []          # guarded-by: self.mutex
