"""Good fixture: every guarded access happens under the lock, waits
loop on their predicate, and the two locks nest in one order."""
import threading


class Server:
    def __init__(self):
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.items = []          # guarded-by: self.cond
        self.closed = False      # guarded-by: self.cond

    def put(self, x):
        with self.cond:
            self.items.append(x)
            self.cond.notify_all()

    def take(self):
        with self.cond:
            while not self.items and not self.closed:
                self.cond.wait(0.1)
            return self.items.pop(0) if self.items else None

    def close(self):
        with self.lock:
            self.closed = True
            self.cond.notify_all()
