"""Bad fixture, module 2 of 2: re-defines plane_a's OP_PING → WP006."""
OP_PING = 16
