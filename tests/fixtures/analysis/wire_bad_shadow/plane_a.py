"""Bad fixture, module 1 of 2: OP_PING defined here and in plane_b."""
OP_PING = 1
