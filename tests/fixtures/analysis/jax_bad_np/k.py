"""Bad fixture: host-numpy call inside a jitted function → JX001."""
import jax
import numpy as np


@jax.jit
def scale(x):
    return x * np.sqrt(np.asarray(x).sum())
