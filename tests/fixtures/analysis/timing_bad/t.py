"""Bad fixture: wall-clock duration arithmetic → TM001."""
import time


def bench(fn):
    t0 = time.time()
    fn()
    return time.time() - t0
