"""Bad fixture, module 2 of 2: re-registers m1's serve.shared_total."""
from repro.obsv.metrics import REGISTRY


def record_more():
    REGISTRY.counter("serve.shared_total").inc()
