"""Bad fixture, module 1 of 2: dynamic metric name (TL001), name that
breaks plane.noun_unit (TL002), and a metric m2 also registers (TL003)."""
from repro.obsv.metrics import REGISTRY


def record(op, v):
    REGISTRY.counter(f"serve.ops.{op}").inc()           # TL001
    REGISTRY.gauge("BadName").set(v)                    # TL002
    REGISTRY.counter("serve.shared_total").inc()        # TL003 with m2
