"""Per-kernel validation: Pallas body (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gnn_aggregate import gnn_aggregate as pallas_agg
from repro.kernels.swa_attention import swa_attention_decode as pallas_swa
from repro.kernels.topk_mask import topk_mask as pallas_topk


# -- gnn_aggregate ------------------------------------------------------------

@pytest.mark.parametrize("n_src,n_dst,k,f", [
    (64, 32, 5, 16), (257, 100, 5, 32), (1024, 300, 8, 96),
    (33, 500, 3, 200),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gnn_aggregate_shapes_dtypes(n_src, n_dst, k, f, dtype):
    rng = np.random.default_rng(n_src + n_dst)
    feats = jnp.asarray(rng.standard_normal((n_src, f)), dtype)
    idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, k)) < 0.7)
    got = pallas_agg(feats, idx, mask, interpret=True)
    want = ref.gnn_aggregate(feats, idx, mask)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 150), st.integers(1, 7),
       st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_gnn_aggregate_property(n_src, n_dst, k, f, seed):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.standard_normal((n_src, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, k)) < 0.5)
    got = np.asarray(pallas_agg(feats, idx, mask, interpret=True))
    want = np.asarray(ref.gnn_aggregate(feats, idx, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # isolated vertices → exactly zero
    iso = ~np.asarray(mask).any(axis=1)
    assert np.all(got[iso] == 0)


def test_gnn_aggregate_matches_segment_mean_path(small_shards):
    """Kernel result == the segment-mean the GNN layer actually uses."""
    shards, _ = small_shards
    sh = shards[0]
    ell_idx, ell_mask = ops.ell_from_csr(sh.indptr, sh.indices, max_deg=16)
    feats = jnp.asarray(
        np.random.default_rng(0).standard_normal(
            (len(sh.global_ids), 24)).astype(np.float32))
    got = ops.gnn_aggregate(feats, jnp.asarray(ell_idx),
                            jnp.asarray(ell_mask), use_pallas=True)
    want = ref.gnn_aggregate(feats, jnp.asarray(ell_idx),
                             jnp.asarray(ell_mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- swa_attention ------------------------------------------------------------

@pytest.mark.parametrize("B,T,Hkv,G,dh,window", [
    (2, 64, 2, 3, 16, 32), (1, 128, 1, 1, 64, 128), (3, 256, 4, 2, 32, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_shapes_dtypes(B, T, Hkv, G, dh, window, dtype):
    rng = np.random.default_rng(B * T)
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, H, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), dtype)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    length = rng.integers(T // 2, T)
    kv_valid = kv_pos < length
    q_pos = jnp.full((B,), length - 1, jnp.int32)
    got = pallas_swa(q, k, v, kv_pos, kv_valid, q_pos, window=window,
                     interpret=True)
    want = ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([16, 48]), st.integers(1, 2),
       st.integers(1, 3), st.sampled_from([8, 32]), st.integers(4, 64),
       st.integers(0, 10**6))
def test_swa_decode_property(B, T, Hkv, G, dh, window, seed):
    rng = np.random.default_rng(seed)
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    kv_valid = kv_pos < T
    q_pos = jnp.full((B,), T - 1, jnp.int32)
    got = pallas_swa(q, k, v, kv_pos, kv_valid, q_pos, window=window,
                     interpret=True)
    want = ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# -- topk_mask -----------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(100, 10), (1024, 256), (5000, 1250),
                                 (10, 10), (64, 0)])
def test_topk_mask_counts(n, k):
    rng = np.random.default_rng(n + k)
    scores = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = pallas_topk(scores, k, interpret=True)
    want = ref.topk_mask(scores, k)
    # identical threshold semantics (distinct scores a.s. ⇒ equality)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2000), st.data())
def test_topk_mask_property(n, data):
    k = data.draw(st.integers(0, n))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = np.asarray(pallas_topk(scores, k, interpret=True))
    # at least k selected; everything selected dominates the unselected
    assert got.sum() >= min(k, n)
    if 0 < k < n:
        sel_min = np.asarray(scores)[got].min()
        if (~got).any():
            assert sel_min >= np.asarray(scores)[~got].max()
        # no gross over-selection (ties aside, counts are exact)
        assert got.sum() <= k + np.sum(
            np.asarray(scores) == np.sort(np.asarray(scores))[-k])


def test_ops_dispatch_cpu_defaults(small_shards):
    """auto on CPU = oracle path; forced pallas = interpret mode."""
    scores = jnp.asarray(np.random.default_rng(0).standard_normal(50),
                         jnp.float32)
    a = ops.topk_mask(scores, 10, use_pallas="auto")
    b = ops.topk_mask(scores, 10, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
