"""Per-kernel validation: Pallas body (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gnn_aggregate import gnn_aggregate as pallas_agg
from repro.kernels.swa_attention import swa_attention_decode as pallas_swa
from repro.kernels.topk_mask import topk_mask as pallas_topk


# -- gnn_aggregate ------------------------------------------------------------

@pytest.mark.parametrize("n_src,n_dst,k,f", [
    (64, 32, 5, 16), (257, 100, 5, 32), (1024, 300, 8, 96),
    (33, 500, 3, 200),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gnn_aggregate_shapes_dtypes(n_src, n_dst, k, f, dtype):
    rng = np.random.default_rng(n_src + n_dst)
    feats = jnp.asarray(rng.standard_normal((n_src, f)), dtype)
    idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, k)) < 0.7)
    got = pallas_agg(feats, idx, mask, interpret=True)
    want = ref.gnn_aggregate(feats, idx, mask)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 150), st.integers(1, 7),
       st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_gnn_aggregate_property(n_src, n_dst, k, f, seed):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.standard_normal((n_src, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, (n_dst, k)), jnp.int32)
    mask = jnp.asarray(rng.random((n_dst, k)) < 0.5)
    got = np.asarray(pallas_agg(feats, idx, mask, interpret=True))
    want = np.asarray(ref.gnn_aggregate(feats, idx, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # isolated vertices → exactly zero
    iso = ~np.asarray(mask).any(axis=1)
    assert np.all(got[iso] == 0)


def test_gnn_aggregate_matches_segment_mean_path(small_shards):
    """Kernel result == the segment-mean the GNN layer actually uses."""
    shards, _ = small_shards
    sh = shards[0]
    ell_idx, ell_mask = ops.ell_from_csr(sh.indptr, sh.indices, max_deg=16)
    feats = jnp.asarray(
        np.random.default_rng(0).standard_normal(
            (len(sh.global_ids), 24)).astype(np.float32))
    got = ops.gnn_aggregate(feats, jnp.asarray(ell_idx),
                            jnp.asarray(ell_mask), use_pallas=True)
    want = ref.gnn_aggregate(feats, jnp.asarray(ell_idx),
                             jnp.asarray(ell_mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- swa_attention ------------------------------------------------------------

@pytest.mark.parametrize("B,T,Hkv,G,dh,window", [
    (2, 64, 2, 3, 16, 32), (1, 128, 1, 1, 64, 128), (3, 256, 4, 2, 32, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_shapes_dtypes(B, T, Hkv, G, dh, window, dtype):
    rng = np.random.default_rng(B * T)
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, H, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), dtype)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    length = rng.integers(T // 2, T)
    kv_valid = kv_pos < length
    q_pos = jnp.full((B,), length - 1, jnp.int32)
    got = pallas_swa(q, k, v, kv_pos, kv_valid, q_pos, window=window,
                     interpret=True)
    want = ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([16, 48]), st.integers(1, 2),
       st.integers(1, 3), st.sampled_from([8, 32]), st.integers(4, 64),
       st.integers(0, 10**6))
def test_swa_decode_property(B, T, Hkv, G, dh, window, seed):
    rng = np.random.default_rng(seed)
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    kv_valid = kv_pos < T
    q_pos = jnp.full((B,), T - 1, jnp.int32)
    got = pallas_swa(q, k, v, kv_pos, kv_valid, q_pos, window=window,
                     interpret=True)
    want = ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# -- topk_mask -----------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(100, 10), (1024, 256), (5000, 1250),
                                 (10, 10), (64, 0)])
def test_topk_mask_counts(n, k):
    rng = np.random.default_rng(n + k)
    scores = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = pallas_topk(scores, k, interpret=True)
    want = ref.topk_mask(scores, k)
    # identical threshold semantics (distinct scores a.s. ⇒ equality)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2000), st.data())
def test_topk_mask_property(n, data):
    k = data.draw(st.integers(0, n))
    seed = data.draw(st.integers(0, 10**6))
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = np.asarray(pallas_topk(scores, k, interpret=True))
    # at least k selected; everything selected dominates the unselected
    assert got.sum() >= min(k, n)
    if 0 < k < n:
        sel_min = np.asarray(scores)[got].min()
        if (~got).any():
            assert sel_min >= np.asarray(scores)[~got].max()
        # no gross over-selection (ties aside, counts are exact)
        assert got.sum() <= k + np.sum(
            np.asarray(scores) == np.sort(np.asarray(scores))[-k])


def test_ops_dispatch_cpu_defaults(small_shards):
    """auto on CPU = oracle path; forced pallas = interpret mode."""
    scores = jnp.asarray(np.random.default_rng(0).standard_normal(50),
                         jnp.float32)
    a = ops.topk_mask(scores, 10, use_pallas="auto")
    b = ops.topk_mask(scores, 10, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- fused exchange kernels: gather+quantize / dequant+scatter ----------------
#
# Odd shapes on purpose: rows not a ROW_TILE multiple, hidden off the
# 128-lane boundary, empty row blocks, all-zero rows (scale 0).  Every
# path — Pallas interpret, the jitted jnp twin, the numpy mirror — must
# be bit-identical to the two-step oracle.

from repro.kernels.exchange_fused import (dequant_scatter as fused_scatter,
                                          gather_quantize as fused_gather)
from repro.kernels.gnn_aggregate import dequant_aggregate as pallas_deagg
from repro.kernels.quantize import (bucket_rows, quantize_int8,
                                    quantize_padded, row_buckets)


def _table_rows(R, h, n, seed, *, zero_row=False):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((R, h)).astype(np.float32) * 3
    if zero_row and R:
        table[R // 2] = 0.0
    rows = rng.choice(R, size=n, replace=False).astype(np.int64)
    return table, rows


@pytest.mark.parametrize("R,n,h", [
    (300, 123, 32),      # rows % ROW_TILE != 0, hidden % LANE != 0
    (257, 257, 129),     # both off-boundary, n == R
    (64, 0, 16),         # empty pull
    (512, 300, 128),     # lane-aligned hidden, odd rows
])
def test_gather_quantize_paths_bit_identical(R, n, h):
    table, rows = _table_rows(R, h, n, R + n + h, zero_row=True)
    tdev = jnp.asarray(table)
    wv, ws = ref.gather_quantize(tdev, jnp.asarray(rows))
    for got_v, got_s in (
        fused_gather(tdev, rows, interpret=True),          # Pallas body
        fused_gather(tdev, rows, via="jnp"),               # jitted twin
        ops._np_gather_quantize(table, rows),              # numpy mirror
        ops.gather_quantize(tdev, rows, use_pallas="auto"),
    ):
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(wv))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(ws))


@pytest.mark.parametrize("accumulate", [False, True])
@pytest.mark.parametrize("R,n,h", [
    (300, 123, 32), (257, 100, 129), (64, 0, 16), (512, 300, 128),
])
def test_dequant_scatter_paths_bit_identical(R, n, h, accumulate):
    table, rows = _table_rows(R, h, n, R + n + h + int(accumulate))
    values, scales = ops._np_quantize_int8(
        np.random.default_rng(7).standard_normal((n, h)).astype(np.float32))
    values[n // 2:] = 0                      # all-zero rows survive decode
    tdev = jnp.asarray(table)
    want = ref.dequant_scatter(tdev, jnp.asarray(rows), jnp.asarray(values),
                               jnp.asarray(scales), accumulate=accumulate)
    for got in (
        fused_scatter(tdev, rows, values, scales, accumulate=accumulate,
                      interpret=True),
        fused_scatter(tdev, rows, values, scales, accumulate=accumulate,
                      via="jnp"),
        ops._np_dequant_scatter(table, rows, values, scales,
                                accumulate=accumulate),
        ops.dequant_scatter(tdev, rows, values, scales,
                            accumulate=accumulate, use_pallas="auto"),
    ):
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 500), st.sampled_from([1, 32, 128, 129]),
       st.integers(0, 10**6))
def test_fused_exchange_property(R, h, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, R + 1))
    table, rows = _table_rows(R, h, n, seed)
    tdev = jnp.asarray(table)
    gv, gs = fused_gather(tdev, rows, interpret=True)
    wv, ws = ref.gather_quantize(tdev, jnp.asarray(rows))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    # scatter the gathered rows back: the stored fp32 equals the decode
    out = fused_scatter(tdev, rows, np.asarray(gv), np.asarray(gs),
                        interpret=True)
    want = ref.dequant_scatter(tdev, jnp.asarray(rows), wv, ws)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("n_src,n_dst,k,h", [
    (300, 100, 5, 32), (257, 257, 3, 129), (64, 30, 4, 128),
])
def test_dequant_aggregate_matches_two_step(n_src, n_dst, k, h):
    """Fused dequant→ELL-mean == host dequant then gnn_aggregate, bit
    for bit, on all dispatch paths."""
    rng = np.random.default_rng(n_src + h)
    values, scales = ops._np_quantize_int8(
        rng.standard_normal((n_src, h)).astype(np.float32))
    idx = rng.integers(0, n_src, (n_dst, k)).astype(np.int32)
    mask = rng.random((n_dst, k)) < 0.7
    feats = ops.dequantize_int8(jnp.asarray(values), jnp.asarray(scales),
                                use_pallas="auto")
    want = ops.gnn_aggregate(feats, jnp.asarray(idx), jnp.asarray(mask),
                             use_pallas="auto")
    for got in (
        pallas_deagg(jnp.asarray(values), jnp.asarray(scales),
                     jnp.asarray(idx), jnp.asarray(mask), interpret=True),
        ops.dequant_aggregate(values, scales, idx, mask, use_pallas="auto"),
    ):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- bucketed padding: retrace guard + boundary bit-identity ------------------

def test_bucketed_quantize_retrace_guard():
    """50 pushes with 50 distinct row counts compile at most one program
    per bucket (the quantize program is keyed on the bucket shape, never
    the row count)."""
    h = 32
    before = quantize_padded._cache_size()
    rng = np.random.default_rng(0)
    counts = rng.choice(np.arange(1, 4000), size=50, replace=False)
    for n in counts:
        x = jnp.asarray(rng.standard_normal((int(n), h)), jnp.float32)
        quantize_int8(x, interpret=True)
    grown = quantize_padded._cache_size() - before
    assert grown <= len(row_buckets()), \
        f"{grown} compiles for 50 row counts (buckets: {row_buckets()})"
    assert grown <= len({bucket_rows(int(n)) for n in counts})


@pytest.mark.parametrize("bucket", [256, 512])
def test_bucket_boundary_bit_identity(bucket):
    """n = bucket-1 / bucket / bucket+1 all round-trip bit-identically
    to the numpy oracle — the pad rows never leak into real rows."""
    h = 48
    rng = np.random.default_rng(bucket)
    for n in (bucket - 1, bucket, bucket + 1):
        x = (rng.standard_normal((n, h)) * 2).astype(np.float32)
        nv, ns = ops._np_quantize_int8(x)
        for pv, ps in (quantize_int8(jnp.asarray(x), interpret=True),
                       quantize_int8(x, interpret=True)):
            assert pv.shape == (n, h) and ps.shape == (n, 1)
            np.testing.assert_array_equal(np.asarray(pv), nv)
            np.testing.assert_array_equal(np.asarray(ps), ns)


# -- ell_from_csr: vectorized construction vs the reference loop --------------

def _ell_from_csr_loop(indptr, indices, max_deg):
    n = len(indptr) - 1
    idx = np.zeros((n, max_deg), np.int32)
    mask = np.zeros((n, max_deg), bool)
    for v in range(n):
        nbrs = indices[indptr[v]:indptr[v + 1]][:max_deg]
        idx[v, :len(nbrs)] = nbrs
        mask[v, :len(nbrs)] = True
    return idx, mask


@pytest.mark.parametrize("n,avg_deg,max_deg", [
    (1, 0, 4), (50, 3, 5), (200, 12, 8), (97, 1, 1),
])
def test_ell_from_csr_matches_loop(n, avg_deg, max_deg):
    rng = np.random.default_rng(n + max_deg)
    deg = rng.poisson(avg_deg, n)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n, indptr[-1]).astype(np.int32)
    got = ops.ell_from_csr(indptr, indices, max_deg)
    want = _ell_from_csr_loop(indptr, indices, max_deg)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


def test_ell_from_csr_empty_graph():
    idx, mask = ops.ell_from_csr(np.zeros(1, np.int64),
                                 np.zeros(0, np.int32), 4)
    assert idx.shape == (0, 4) and mask.shape == (0, 4)
