"""Tests for repro-lint (``src/repro/analysis``).

Three layers:

1. fixture pairs — every rule fires on its bad fixture, every good
   fixture is clean;
2. mutation tests — textual copies of the three *real* wire modules
   with one opcode value or one pack field changed must each produce a
   finding (the acceptance criterion: the byte-layout checker provably
   cross-validates every builder/parser pair);
3. a meta-test that the live tree itself is clean, plus targeted
   regressions for the fixes the analyzer drove (namespaced opcodes,
   render_text bounds under the registry lock, embed-server store
   access under its lock).
"""

from __future__ import annotations

import ast
import json
import os
import pathlib
import re
import subprocess
import sys
import threading

import pytest

from repro.analysis import run_analysis
from repro.analysis import rules_lock, rules_wire
from repro.analysis.core import SourceFile

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "analysis"
PLANES = {s.name: s for s in rules_wire.PLANES}


def _fixture_rules(name: str) -> set[str]:
    res = run_analysis(FIXTURES / name, exclude_fixtures=False)
    return {f.rule for f in res.findings}


# -- 1. fixture pairs ---------------------------------------------------------

BAD_FIXTURES = [
    ("wire_bad_layout", "WP005"),
    ("wire_bad_shadow", "WP006"),
    ("lock_bad_unguarded", "LD001"),
    ("lock_bad_wait", "LD002"),
    ("lock_bad_order", "LD003"),
    ("lock_bad_annotation", "LD004"),
    ("jax_bad_np", "JX001"),
    ("jax_bad_item", "JX002"),
    ("jax_bad_closure", "JX003"),
    ("timing_bad", "TM001"),
    ("telemetry_bad", "TL001"),
]


@pytest.mark.parametrize("name,rule", BAD_FIXTURES)
def test_bad_fixture_flags_rule(name, rule):
    assert rule in _fixture_rules(name)


@pytest.mark.parametrize("name", ["wire_good", "lock_good", "jax_good"])
def test_good_fixture_clean(name):
    assert _fixture_rules(name) == set()


def test_telemetry_bad_covers_all_three_rules():
    assert {"TL001", "TL002", "TL003"} <= _fixture_rules("telemetry_bad")


def test_suppression_comment(tmp_path):
    bad = "import time\n\n\ndef f():\n    return time.time()\n"
    (tmp_path / "a.py").write_text(bad)
    assert {f.rule for f in run_analysis(tmp_path).findings} == {"TM001"}
    (tmp_path / "a.py").write_text(bad.replace(
        "return time.time()",
        "return time.time()  # repro-lint: disable=TM001"))
    assert run_analysis(tmp_path).clean
    (tmp_path / "a.py").write_text(
        "# repro-lint: disable-file=TM001\n" + bad)
    assert run_analysis(tmp_path).clean


# -- 2. mutation tests against the real wire modules --------------------------

def _plane_findings(spec, text: str):
    sf = SourceFile(REPO_ROOT / spec.wire_rel, spec.wire_rel, text)
    parent = None
    if spec.parent_rel:
        p = REPO_ROOT / spec.parent_rel
        parent = SourceFile(p, spec.parent_rel,
                            p.read_text(encoding="utf-8"))
    return rules_wire.check_plane(spec, sf, None, {}, parent_sf=parent)


# functions whose byte layout the checker verifies; mutations outside
# them (framing, response status, codec payload helpers) are covered by
# the runtime round-trip tests instead
_EXCLUDED_FNS = {"build_ok", "build_err", "parse_response"}


def _verified_spans(text: str) -> list[tuple[int, int]]:
    spans = []
    for node in ast.parse(text).body:
        if isinstance(node, ast.FunctionDef) \
                and node.name not in _EXCLUDED_FNS \
                and (node.name.startswith(("build_", "parse_"))
                     or node.name == "_gid_bytes"):
            spans.append((node.lineno, node.end_lineno))
    return spans


_STRUCT_CALL = re.compile(
    r"(_U8|_U16|_U32|_U64|_STATS)\.(pack|unpack_from|unpack)\(")
_STRUCT_PREF = ["_U16", "_U64", "_U8", "_U32", "_STATS"]
_DTYPE_SWAPS = {"np.int64": "np.int32", "np.float32": "np.float64",
                "np.int32": "np.int64"}
_OPCODE_DEF = re.compile(r"^(OP_\w+) = (\d+)", re.M)


def _mutations(spec, text: str):
    """Yield (description, mutated_text): every opcode renumbered, and
    every struct/dtype pack field in a verified function swapped."""
    for m in _OPCODE_DEF.finditer(text):
        name, value = m.group(1), int(m.group(2))
        yield (f"{name} {value}->{value + 1}",
               text[:m.start(2)] + str(value + 1) + text[m.end(2):])
    avail = set(re.findall(r"^(_\w+) = struct\.Struct", text, re.M))
    avail |= {n for n in _STRUCT_PREF
              if re.search(rf"import.*\b{n}\b", text)}
    lines = text.splitlines(True)
    spans = _verified_spans(text)
    for i, ln in enumerate(lines):
        if not any(lo <= i + 1 <= hi for lo, hi in spans):
            continue
        for m in _STRUCT_CALL.finditer(ln):
            orig = m.group(1)
            swap = next((s for s in _STRUCT_PREF
                         if s != orig and s in avail), None)
            if swap is None:
                continue
            mut = lines[:]
            mut[i] = ln[:m.start(1)] + swap + ln[m.start(1) + len(orig):]
            yield (f"line {i + 1}: {orig}->{swap}", "".join(mut))
        if "frombuffer" in ln or ".tobytes()" in ln \
                or "asarray" in ln:
            for old, new in _DTYPE_SWAPS.items():
                if old in ln:
                    mut = lines[:]
                    mut[i] = ln.replace(old, new, 1)
                    yield (f"line {i + 1}: {old}->{new}", "".join(mut))
                    break


@pytest.mark.parametrize("plane", sorted(PLANES))
def test_wire_module_baseline_clean(plane):
    spec = PLANES[plane]
    text = (REPO_ROOT / spec.wire_rel).read_text(encoding="utf-8")
    assert _plane_findings(spec, text) == []


@pytest.mark.parametrize("plane", sorted(PLANES))
def test_every_opcode_and_pack_field_mutation_caught(plane):
    spec = PLANES[plane]
    text = (REPO_ROOT / spec.wire_rel).read_text(encoding="utf-8")
    missed, total = [], 0
    for desc, mutated in _mutations(spec, text):
        total += 1
        if not _plane_findings(spec, mutated):
            missed.append(desc)
    assert total >= 3, f"mutation generator found too little in {plane}"
    assert not missed, f"{plane}: undetected mutations: {missed}"


def test_fedsvc_handwritten_layout_mutations():
    """Field reorder, field drop, and field widening in build_body —
    shapes the generic generator cannot produce with a single struct."""
    spec = PLANES["fedsvc"]
    text = (REPO_ROOT / spec.wire_rel).read_text(encoding="utf-8")
    muts = [
        text.replace(
            "bytes([op_or_status]) + _U32.pack(len(blob)) + blob",
            "bytes([op_or_status]) + blob + _U32.pack(len(blob))"),
        text.replace(
            "bytes([op_or_status]) + _U32.pack(len(blob))",
            "bytes([op_or_status])"),
        text.replace(
            "bytes([op_or_status]) + _U32.pack(len(blob))",
            "_U32.pack(op_or_status) + _U32.pack(len(blob))"),
    ]
    for mutated in muts:
        assert mutated != text
        assert _plane_findings(spec, mutated)


def test_all_builder_parser_pairs_cross_validated():
    """The WP family verifies every request opcode and payload pair of
    all three planes — nothing silently skipped as unverifiable."""
    res = run_analysis(REPO_ROOT, select=["WP"])
    assert res.clean
    pairs = set(res.stats["pairs_verified"])
    assert {
        "exchange:OP_REGISTER", "exchange:OP_WRITE", "exchange:OP_GATHER",
        "exchange:OP_VGATHER", "exchange:OP_EMBED_STATS",
        "exchange:OP_EMBED_SHUTDOWN", "exchange:build_stats_payload",
        "exchange:build_tensors",
        "fedsvc:build_body",
        "gnnserve:OP_PREDICT", "gnnserve:OP_SSTATS",
        "gnnserve:OP_EMBED_SHUTDOWN", "gnnserve:build_predict_payload",
        "gnnserve:build_stats_payload",
    } <= pairs


# -- 3. live tree + regressions ----------------------------------------------

def test_live_tree_clean():
    res = run_analysis(REPO_ROOT)
    assert res.findings == [], "\n".join(f.render() for f in res.findings)


def test_cli_exit_zero_on_repo():
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--format", "json"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert out["findings"] == []
    assert out["stats"]["files_scanned"] > 0


@pytest.mark.parametrize("name,rule", BAD_FIXTURES)
def test_cli_nonzero_on_bad_fixture(name, rule):
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint",
         "--root", str(FIXTURES / name), "--include-fixtures",
         "--format", "json"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    assert any(f["rule"] == rule for f in out["findings"])
    for f in out["findings"]:
        assert set(f) == {"rule", "file", "line", "message", "hint"}


def test_opcode_namespacing():
    """Satellite: the three planes no longer export colliding OP_STATS /
    OP_SHUTDOWN names, and all opcode values are globally unique."""
    from repro.exchange import wire as xwire
    from repro.fedsvc import protocol
    from repro.gnnserve import wire as swire
    for mod in (xwire, protocol):
        assert not hasattr(mod, "OP_STATS")
        assert not hasattr(mod, "OP_SHUTDOWN")
    assert xwire.OP_EMBED_STATS == 4
    assert xwire.OP_EMBED_SHUTDOWN == 5
    assert protocol.OP_COORD_STATS == 21
    assert protocol.OP_COORD_SHUTDOWN == 22
    assert swire.OP_EMBED_SHUTDOWN is xwire.OP_EMBED_SHUTDOWN
    values = []
    for spec in rules_wire.PLANES:
        values.extend(spec.opcodes.values())
    assert len(values) == len(set(values))


def test_lock_annotations_live_embed_server():
    """The guarded-by annotations actually police embed_server: the
    current module is clean, and an unguarded store read in new code
    is flagged — proving the annotations are not vacuous."""
    rel = "src/repro/launch/embed_server.py"
    text = (REPO_ROOT / rel).read_text(encoding="utf-8")
    sf = SourceFile(REPO_ROOT / rel, rel, text)
    assert not {f.rule for f in rules_lock.check([sf], repo_mode=False)}
    marker = "    def _handle_vgather"
    probe = ("    def _probe(self):\n"
             "        return self.store.hidden\n\n")
    assert marker in text
    sf = SourceFile(REPO_ROOT / rel, rel,
                    text.replace(marker, probe + marker, 1))
    assert "LD001" in {f.rule
                       for f in rules_lock.check([sf], repo_mode=False)}


def test_lock_annotations_live_coordinator():
    rel = "src/repro/fedsvc/coordinator.py"
    text = (REPO_ROOT / rel).read_text(encoding="utf-8")
    marker = "    def _op_stats(self)"
    probe = ("    def _probe(self):\n"
             "        return self.round + len(self.updates)\n\n")
    assert marker in text
    sf = SourceFile(REPO_ROOT / rel, rel,
                    text.replace(marker, probe + marker, 1))
    findings = [f for f in rules_lock.check([sf], repo_mode=False)
                if f.rule == "LD001"]
    assert len(findings) >= 2          # self.round and self.updates


def test_render_text_bounds_under_lock():
    """Regression for the unguarded ``self._metrics[name]`` read:
    render_text must stay consistent while other threads register
    metrics."""
    from repro.obsv.metrics import MetricsRegistry
    reg = MetricsRegistry()
    h = reg.histogram("t.lat_s", lo=1e-3, hi=10.0, factor=2.0)
    h.observe(0.5)
    stop = threading.Event()

    def churn():
        i = 0
        while not stop.is_set():
            reg.counter(f"t.c{i % 256}").inc()
            i += 1

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        for _ in range(100):
            out = reg.render_text("t.")
            assert "t.lat_s_count 1" in out
            assert 't.lat_s_bucket{le="+Inf"} 1' in out
    finally:
        stop.set()
        t.join(5.0)


def test_embed_server_concurrent_write_gather():
    """Regression for store-attribute reads outside the server lock:
    concurrent writers and gatherers over one shard must neither crash
    nor interleave torn rows."""
    import numpy as np

    from repro.exchange.socket_transport import TcpTransport
    from repro.launch.embed_server import serve_in_thread

    handle = serve_in_thread(3, 8)
    try:
        tr = TcpTransport(3, 8, [handle.address], codec="fp32")
        gids = np.arange(16, dtype=np.int64)
        tr.register(gids)
        rows = np.tile(np.arange(16, dtype=np.float32)[:, None], (1, 8))
        errs = []

        def writer():
            try:
                for _ in range(10):
                    tr2 = TcpTransport(3, 8, [handle.address],
                                       codec="fp32")
                    tr2.write(gids, [rows, rows])
                    tr2.close()
            except Exception as e:       # pragma: no cover
                errs.append(e)

        w = threading.Thread(target=writer, daemon=True)
        tr.write(gids, [rows, rows])     # ensure data before gathers
        w.start()
        for _ in range(20):
            got = tr.gather(gids, layers=[1, 2])
            for block in got:
                # every row is either all-k (written) — never torn
                np.testing.assert_array_equal(block, rows)
        w.join(10.0)
        assert not errs
        tr.close()
    finally:
        handle.stop()
