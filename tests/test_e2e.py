"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_reduced, list_archs
from repro.core import (FederatedGNNTrainer, default_strategies,
                        peak_accuracy, time_to_accuracy)
from repro.graphs import make_graph
from repro.launch.steps import input_specs, shape_variant, cache_capacity
from repro.models import lm
from repro.optim import adamw


def test_full_federated_session_matches_paper_shape():
    """One complete FL session: pre-training bootstrap, pull/train/push
    rounds, FedAvg, validation — accuracy rises, phases are populated,
    OptimES reduces communication vs EmbC."""
    g = make_graph("reddit", scale=0.15, seed=5)
    runs = {}
    for name in ("E", "OPG"):
        tr = FederatedGNNTrainer(g, 3, default_strategies()[name],
                                 batch_size=64, seed=0)
        stats = tr.train(6)
        runs[name] = (tr, stats)
        accs = [s.accuracy for s in stats]
        assert max(accs[2:]) > accs[0]          # learning happens
    (tr_e, e), (tr_o, o) = runs["E"], runs["OPG"]
    # OPG holds fewer embeddings at the server and ships fewer bytes
    assert o[-1].embeddings_stored < e[-1].embeddings_stored
    assert tr_o.server.log.bytes < tr_e.server.log.bytes
    # peak accuracy stays comparable (within a few points)
    assert peak_accuracy(o) > peak_accuracy(e) - 0.05


def test_transformer_training_loop_learns():
    from repro.data import synthetic_batches
    cfg = get_reduced("smollm-360m")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(5e-3)
    state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt))
    gen = synthetic_batches(cfg, batch=8, seq=64, seed=0)
    losses = []
    for _ in range(25):
        params, state, m = step(params, state, next(gen))
        losses.append(float(m["loss"]))
    # the Markov structure is learnable: loss must drop meaningfully
    assert min(losses[-3:]) < losses[0] - 0.5, losses


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_cover_all_archs(shape_name):
    """input_specs (deliverable e.2): ShapeDtypeStruct stand-ins exist for
    every model input of every (arch × shape), no device allocation."""
    for arch in list_archs():
        cfg = get_reduced(arch)   # structure identical to full configs
        from repro.configs import get_config
        full = get_config(arch)
        specs = input_specs(full, SHAPES[shape_name])
        leaves = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        shp = SHAPES[shape_name]
        if shp.kind == "decode":
            assert specs["tokens"].shape == (shp.global_batch, 1)
            assert "cache" in specs
        else:
            assert specs["tokens"].shape == (shp.global_batch, shp.seq_len)
        if full.family == "vlm" and shp.kind != "decode":
            assert specs["vision"].shape[1] == full.vision_tokens
        if full.family == "audio" and shp.kind != "decode":
            assert specs["frames"].shape[1] == full.encoder_seq


def test_long_context_variant_rules():
    """DESIGN §4: long_500k forces SWA for attention archs, leaves SSM
    native, caps decode caches at the window."""
    from repro.configs import get_config
    long = SHAPES["long_500k"]
    dense = shape_variant(get_config("command-r-35b"), long)
    assert dense.sliding_window == 8192
    assert cache_capacity(dense, long) == 8192
    ssm = shape_variant(get_config("mamba2-1.3b"), long)
    assert ssm.sliding_window is None
    hymba = shape_variant(get_config("hymba-1.5b"), long)
    assert hymba.sliding_window == 8192       # its own design window
    d32 = shape_variant(get_config("command-r-35b"), SHAPES["decode_32k"])
    assert d32.sliding_window is None
    assert cache_capacity(d32, SHAPES["decode_32k"]) == 32768


def test_roofline_analytics():
    from benchmarks.roofline import analytic_hbm_bytes, model_flops_per_chip
    # train: 6·N·T/devices
    mf = model_flops_per_chip("smollm-360m", "train_4k", 256)
    from repro.configs import get_config
    n = get_config("smollm-360m").active_param_count()
    assert abs(mf - 6 * n * 4096 * 256 / 256) / mf < 1e-6
    # decode memory: MLA latent cache ≪ equivalent GQA cache
    mla = analytic_hbm_bytes("deepseek-v2-lite-16b", "decode_32k", 256)
    gqa = analytic_hbm_bytes("command-r-35b", "decode_32k", 256)
    assert mla < gqa
    # every (arch × shape) produces finite positive terms
    for arch in list_archs():
        for s in SHAPES:
            v = analytic_hbm_bytes(arch, s, 256)
            assert np.isfinite(v) and v > 0
