"""GNN forward/backward semantics over sampler blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import NeighborSampler
from repro.models import gnn


@pytest.fixture(scope="module")
def setup(small_shards):
    shards, _ = small_shards
    sh = shards[0]
    L, hidden = 3, 16
    s = NeighborSampler(sh, fanout=4, num_layers=L, batch_size=16, seed=0)
    mb = s.sample_batch(sh.train_vertices()[:16])
    feats = jnp.asarray(sh.features)
    caches = [jnp.asarray(np.random.default_rng(0).standard_normal(
        (max(1, sh.num_remote), hidden)).astype(np.float32))
        for _ in range(L - 1)]
    return sh, s, mb, feats, caches, L, hidden


@pytest.mark.parametrize("conv", ["graphconv", "sageconv"])
def test_forward_shapes_and_grads(setup, conv, small_graph):
    sh, s, mb, feats, caches, L, hidden = setup
    params = gnn.init_gnn(jax.random.PRNGKey(0), conv, small_graph.feat_dim,
                          hidden, small_graph.num_classes, L)
    batch = gnn.blocks_to_arrays(mb)
    logits = gnn.forward(params, batch, feats, caches, conv=conv)
    assert logits.shape == (mb.blocks[-1].p_dst, small_graph.num_classes)
    assert not bool(jnp.isnan(logits).any())
    labels = jnp.asarray(sh.labels)
    loss, grads = jax.value_and_grad(
        lambda p: gnn.loss_fn(p, batch, feats, caches, labels, conv=conv)
    )(params)
    assert float(loss) > 0
    gnorm = sum(float(jnp.abs(g).sum())
                for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0


def test_remote_rows_come_from_cache(setup, small_graph):
    """Remote dst rows must equal the cache values, not computed values —
    the core EmbC semantics (§3.2.2)."""
    sh, s, mb, feats, caches, L, hidden = setup
    params = gnn.init_gnn(jax.random.PRNGKey(1), "graphconv",
                          small_graph.feat_dim, hidden,
                          small_graph.num_classes, L)
    batch = gnn.blocks_to_arrays(mb)

    # capture intermediate h after layer 1
    layers = params
    h = feats[batch["input_ids"]]
    out = gnn._layer_forward(layers[0], "graphconv", h, batch["blocks"][0],
                             last=False)
    blk = batch["blocks"][0]
    cached = caches[0][blk["dst_remote_slot"]]
    expected = jnp.where(blk["dst_remote_mask"][:, None], cached, out)
    full = gnn.forward(params, batch, feats, caches, conv="graphconv")
    # recompute forward manually to layer 1 and compare against library
    h2 = feats[batch["input_ids"]]
    got = gnn._layer_forward(layers[0], "graphconv", h2, blk, last=False)
    got = jnp.where(blk["dst_remote_mask"][:, None],
                    caches[0][blk["dst_remote_slot"]], got)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               rtol=1e-6)
    rm = np.asarray(blk["dst_remote_mask"])
    if rm.any():
        np.testing.assert_allclose(
            np.asarray(got)[rm],
            np.asarray(caches[0])[np.asarray(blk["dst_remote_slot"])[rm]],
            rtol=1e-6)


def test_full_propagate_masks_remotes_without_cache(setup, small_graph):
    """Pre-training (§3.2.1): without caches, remote neighbours contribute
    nothing; with caches they change the result."""
    sh, s, mb, feats, caches, L, hidden = setup
    params = gnn.init_gnn(jax.random.PRNGKey(2), "sageconv",
                          small_graph.feat_dim, hidden,
                          small_graph.num_classes, L)
    arrays = gnn.shard_to_arrays(sh)
    no_cache = gnn.full_propagate(params, arrays, None, conv="sageconv")
    with_cache = gnn.full_propagate(params, arrays, caches, conv="sageconv")
    assert no_cache[-1].shape == (sh.num_local, small_graph.num_classes)
    if sh.num_remote:
        # layer ≥ 2 outputs must differ once remote embeddings flow in
        assert float(jnp.abs(no_cache[1] - with_cache[1]).max()) > 0


def test_zero_cache_equals_pruned_everything(setup, small_graph):
    """With all-zero caches, remote aggregation contributes zeros for
    sageconv's neighbour term at layers ≥ 2 — sanity for P_0 ≈ D."""
    sh, s, mb, feats, _, L, hidden = setup
    params = gnn.init_gnn(jax.random.PRNGKey(3), "sageconv",
                          small_graph.feat_dim, hidden,
                          small_graph.num_classes, L)
    zero = [jnp.zeros((max(1, sh.num_remote), hidden)) for _ in range(L - 1)]
    batch = gnn.blocks_to_arrays(mb)
    out = gnn.forward(params, batch, feats, zero, conv="sageconv")
    assert not bool(jnp.isnan(out).any())
