"""Sharding rules, fedopt bridge, HLO census calibration.

Mesh-dependent tests use AbstractMesh so they run on 1 CPU device without
forcing placeholder devices (the dry-run owns that)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as sh
from repro.launch.mesh import make_abstract_mesh
from repro.models import lm
from repro.optim import adafactor, adamw


def fake_mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_abstract_mesh(shape, axes)


@pytest.mark.parametrize("arch", ["nemotron-4-340b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-1.3b", "deepseek-v2-lite-16b"])
def test_param_specs_structure_and_divisibility(arch):
    cfg = get_config(arch)
    mesh = fake_mesh()
    rules = sh.make_rules(mesh, cfg)
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    pspecs = sh.param_specs(rules, pshapes)
    flat_s = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree_util.tree_leaves(pshapes)
    assert len(flat_s) == len(flat_p)
    for spec, leaf in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim
        for dim, ax in zip(leaf.shape, list(spec) + [None] * leaf.ndim):
            if ax is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))])
            assert dim % size == 0, (arch, spec, leaf.shape)


def test_fsdp_thresholds():
    mesh = fake_mesh()
    big = sh.make_rules(mesh, get_config("nemotron-4-340b"))
    small = sh.make_rules(mesh, get_config("smollm-360m"))
    assert big.fsdp and big.seq_parallel
    assert not small.fsdp and not small.seq_parallel


def test_nemotron_param_bytes_fit_hbm():
    """Per-device param+optimizer bytes for the 340B config must fit the
    16 GiB v5e budget under the published sharding rules."""
    cfg = get_config("nemotron-4-340b")
    mesh = fake_mesh()
    rules = sh.make_rules(mesh, cfg)
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    pspecs = sh.param_specs(rules, pshapes)
    total = 0
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(pshapes),
            jax.tree_util.tree_leaves(pspecs,
                                      is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shards *= mesh.shape[a]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // shards
    assert total < 4 * 2**30, f"params/device {total/2**30:.2f} GiB"


def test_opt_specs_mirror_params():
    cfg = get_reduced("smollm-360m")
    mesh = fake_mesh()
    rules = sh.make_rules(mesh, cfg)
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    pspecs = sh.param_specs(rules, pshapes)
    for opt in (adamw(1e-3), adafactor(1e-3)):
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = sh.opt_specs(rules, oshapes, pspecs)
        flat_shapes = jax.tree_util.tree_leaves(oshapes)
        flat_specs = jax.tree_util.tree_leaves(
            ospecs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)
        for leaf, spec in zip(flat_shapes, flat_specs):
            assert len(spec) <= leaf.ndim


def test_batch_and_cache_specs():
    from repro.configs.base import SHAPES
    cfg = get_config("nemotron-4-340b")
    mesh = fake_mesh(multi_pod=True)
    rules = sh.make_rules(mesh, cfg)
    bs = sh.batch_specs(rules, cfg, SHAPES["train_4k"])
    assert bs["tokens"] == P(("pod", "data"), None)
    # long_500k batch=1: never shard a size-1 dim
    bs1 = sh.batch_specs(rules, cfg, SHAPES["long_500k"])
    assert bs1["tokens"][0] is None
    cshapes = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024))
    cspecs = sh.cache_specs(rules, cfg, cshapes, 128)
    flat = jax.tree_util.tree_leaves(cspecs,
                                     is_leaf=lambda x: isinstance(x, P))
    assert flat  # exists and parses


# -- fedopt bridge ------------------------------------------------------------

def test_fedopt_round_and_delta_pruning():
    from repro.core.fedopt import FedOptConfig, FederatedLMTrainer
    from repro.data import synthetic_batches
    cfg = get_reduced("smollm-360m")
    fed = FedOptConfig(num_silos=2, local_steps=2, delta_topk_frac=0.2)
    tr = FederatedLMTrainer(cfg, adamw(1e-3), fed)
    gens = [synthetic_batches(cfg, batch=2, seq=16, seed=s)
            for s in range(2)]
    steps = [[next(g) for _ in range(2)] for g in gens]
    batches = jax.tree_util.tree_map(lambda *x: jnp.stack(x),
                                     *[jax.tree_util.tree_map(
                                         lambda *y: jnp.stack(y), *s)
                                       for s in steps])
    m = tr.round(batches)
    assert np.isfinite(m["loss"])
    assert tr.comm_bytes_per_round() < 0.25 * sum(
        p.size * p.dtype.itemsize
        for p in jax.tree_util.tree_leaves(tr.anchor))


def test_fedopt_stale_aggregation_defers_one_round():
    from repro.core.fedopt import FedOptConfig, FederatedLMTrainer
    from repro.data import synthetic_batches
    cfg = get_reduced("smollm-360m")
    fed = FedOptConfig(num_silos=2, local_steps=1, stale_aggregation=True)
    tr = FederatedLMTrainer(cfg, adamw(1e-3), fed)
    anchor0 = jax.tree_util.tree_map(jnp.copy, tr.anchor)
    gen = synthetic_batches(cfg, batch=2, seq=16, seed=0)
    b = next(gen)
    batches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (2, 1) + x.shape), b)
    tr.round(batches)
    # first round: nothing applied yet (delta pending)
    d0 = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(anchor0),
        jax.tree_util.tree_leaves(tr.anchor)))
    assert d0 == 0.0
    tr.round(batches)
    d1 = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(anchor0),
        jax.tree_util.tree_leaves(tr.anchor)))
    assert d1 > 0.0


# -- HLO census calibration -----------------------------------------------------

def test_census_counts_scan_trips():
    from repro.launch.hlo_census import census
    x = jnp.ones((8, 16))
    w = jnp.ones((16, 16))

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    txt = jax.jit(f).lower(x, w).compile().as_text()
    cen = census(txt)
    expected = 2 * 8 * 16 * 16 * 5
    assert abs(cen["flops"] - expected) / expected < 0.05, cen["flops"]


def test_census_matches_cost_analysis_loop_free():
    from repro.launch.hlo_census import census, compiled_flops
    x = jnp.ones((32, 64))
    w = jnp.ones((64, 128))
    c = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
    cen = census(c.as_text())
    ca = compiled_flops(c)
    assert abs(cen["flops"] - ca) / ca < 0.05
