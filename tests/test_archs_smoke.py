"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same
family (≤2 layers, d_model ≤ 512, ≤ 4 experts) and runs one forward +
one train step + two decode steps on CPU, asserting output shapes and
the absence of NaNs.  The FULL configs are exercised via the dry-run
(`launch/dryrun.py`, ShapeDtypeStruct only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.models import lm
from repro.optim import adamw

ARCHS = list_archs()


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "vlm":
        b["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.vision_dim)),
            jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return b


def test_all_archs_have_reduced_variants():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg, red = get_config(a), get_reduced(a)
        assert red.family == cfg.family
        assert red.num_layers <= 2 and red.d_model <= 512
        assert red.num_experts <= 4
        assert cfg.citation and red.citation


def test_full_configs_match_assignment():
    c = get_config("nemotron-4-340b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (96, 18432, 96, 8, 73728, 256000)
    assert c.activation == "squared_relu"
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.num_experts, c.top_k, c.d_model) == (16, 2, 4096)
    c = get_config("deepseek-v2-lite-16b")
    assert (c.kv_lora_rank, c.num_experts, c.top_k,
            c.num_shared_experts) == (512, 64, 6, 2)
    c = get_config("mamba2-1.3b")
    assert c.ssm_state == 128 and c.family == "ssm"
    c = get_config("hymba-1.5b")
    assert c.ssm_state == 16 and c.num_heads == 25 and c.num_kv_heads == 5
    c = get_config("whisper-tiny")
    assert c.encoder_layers == 4 and c.d_model == 384
    c = get_config("llama-3.2-vision-11b")
    assert c.cross_attn_every == 5 and c.num_kv_heads == 8
    c = get_config("smollm-360m")
    assert (c.d_model, c.num_heads, c.num_kv_heads) == (960, 15, 5)
    c = get_config("command-r-35b")
    assert not c.use_bias and c.d_ff == 22528
    c = get_config("starcoder2-15b")
    assert c.use_bias and c.num_kv_heads == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train_decode(arch):
    cfg = get_reduced(arch)
    S = 64 if cfg.family in ("ssm", "hybrid") else 32
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, S=S)

    logits, aux = jax.jit(lambda p, b: lm.forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    opt = adamw(1e-3)
    step = jax.jit(lm.make_train_step(cfg, opt))
    p2, st, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)))
    assert delta > 0

    cache = lm.init_cache(cfg, 2, 16)
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    lg, cache = dec(params, batch["tokens"][:, :1], cache)
    lg2, cache = dec(params, batch["tokens"][:, 1:2], cache)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg2).any())


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-1.3b",
                                  "hymba-1.5b", "deepseek-v2-lite-16b"])
def test_smoke_loss_decreases(arch):
    """A few steps on a repeated batch must reduce the loss."""
    cfg = get_reduced(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B=4, S=64 if cfg.family in ("ssm", "hybrid")
                       else 32)
    opt = adamw(3e-3)
    step = jax.jit(lm.make_train_step(cfg, opt))
    st = opt.init(params)
    losses = []
    for _ in range(8):
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
