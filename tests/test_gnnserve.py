"""Serving plane: cache coherence, early exit, batcher accounting.

The deterministic serving invariants gated here:

  * fresh-cache serving is bit-identical to an offline forward pass
  * rows invalidated by a τ-delta push are re-pulled, and serving
    answers from the refreshed rows
  * threshold 1.0 disables early exit — every request runs full depth
    and reproduces the exact argmax
  * the batcher drains bursty, mixed-threshold traffic without
    dropping or duplicating a single request id
"""

import collections

import numpy as np
import pytest

from repro.core import FederatedGNNTrainer, Strategy
from repro.gnnserve import build_serving
from repro.gnnserve.frontend import GnnServeClient, serve_in_thread
from repro.graphs import make_graph

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def trained():
    g = make_graph("arxiv", scale=0.1, seed=7)
    tr = FederatedGNNTrainer(g, 2, Strategy("E"), num_layers=2, hidden=8,
                             fanout=4, batch_size=16, epochs_per_round=1,
                             seed=0)
    tr.pretrain_round()
    tr.run_round(0, 0.0)
    return tr


@pytest.fixture(scope="module")
def bundle(trained):
    return trained.export_for_serving()


def _plane(bundle, **kw):
    kw.setdefault("cache_rows", 4096)
    kw.setdefault("serve_fanout", 4)
    kw.setdefault("batch_size", 16)
    return build_serving(bundle, **kw)


def _offline_ref(plane, vids):
    """vid -> offline full-depth argmax, computed per owner shard in
    engine-batch-sized chunks (the reference path shares no batcher or
    early-exit state with serving)."""
    by_owner = collections.defaultdict(list)
    for v in sorted(set(int(v) for v in vids)):
        by_owner[int(plane.part[v])].append(v)
    ref = {}
    for ci, vs in by_owner.items():
        eng = plane.engines[ci]
        for i in range(0, len(vs), eng.batch_size):
            chunk = vs[i: i + eng.batch_size]
            lids = np.array([eng.local_id(v) for v in chunk], np.int64)
            preds = eng.offline_predict(lids)
            for v, p in zip(chunk, preds):
                ref[v] = int(p)
    return ref


def test_fresh_cache_serving_is_bit_identical(bundle):
    plane = _plane(bundle)
    rng = np.random.default_rng(0)
    V = len(plane.part)
    # duplicates on purpose: coalesced queries for the same vertex must
    # not perturb each other's answers
    vids = rng.integers(0, V, size=48)
    vids[::7] = vids[0]
    rid_to_vid = {plane.submit(int(v), 1.0): int(v) for v in vids[:40]}
    for v in vids[40:]:
        rid_to_vid[plane.submit(int(v), 1.0)] = int(v)
    results = {r.rid: r for r in plane.drain()}
    assert sorted(results) == sorted(rid_to_vid)
    ref = _offline_ref(plane, vids)
    for rid, v in rid_to_vid.items():
        assert results[rid].pred == ref[v], f"vid {v} diverged from offline"
        assert results[rid].depth == plane.engines[0].L
    st = plane.cache.stats()
    assert st["stale_refreshes"] == 0     # nothing pushed since export
    assert st["misses"] > 0 and st["rows"] > 0


def test_stale_rows_repulled_after_push(trained, bundle):
    plane = _plane(bundle)
    rng = np.random.default_rng(1)
    V = len(plane.part)
    vids = rng.integers(0, V, size=48)
    first = {r.rid: r for r in _serve_all(plane, vids)}
    assert len(first) == len(vids)
    assert plane.cache.stats()["stale_refreshes"] == 0

    # a real training round lands τ-delta pushes on the reciprocal
    # boundary rows — exactly the rows the serving cache revalidates
    trained.run_round(1, 0.0)

    plane.cache.reset_stats()
    second = {r.rid: r for r in _serve_all(plane, vids)}
    st = plane.cache.stats()
    assert st["stale_refreshes"] > 0, \
        "push bumped row versions but the cache never refreshed"
    # the refreshed serve answers from current store rows: bit-identical
    # to an offline pass that peeks the store directly
    ref = _offline_ref(plane, vids)
    for r in second.values():
        assert r.pred == ref[r.vid]


def _serve_all(plane, vids, thresholds=None):
    if thresholds is None:
        thresholds = [1.0] * len(vids)
    for v, t in zip(vids, thresholds):
        plane.submit(int(v), float(t))
    return plane.drain()


def test_threshold_one_never_exits_early(bundle):
    plane = _plane(bundle)
    rng = np.random.default_rng(2)
    V = len(plane.part)
    vids = rng.integers(0, V, size=32)
    # mix aggressive early-exiters into the same batches: they must not
    # drag the threshold-1.0 requests out of the full-depth path
    thrs = [0.0 if i % 2 else 1.0 for i in range(len(vids))]
    results = _serve_all(plane, vids, thrs)
    L = plane.engines[0].L
    ref = _offline_ref(plane, vids)
    for r, t in zip(sorted(results, key=lambda r: r.rid), thrs):
        if t == 1.0:
            assert r.depth == L
            assert r.pred == ref[r.vid]
        else:
            # softmax max is always strictly positive: threshold 0.0
            # retires at the first scheduled depth
            assert r.depth == plane.engines[0].depth_schedule[0]

    # same invariant on the raw engine path (no batcher): threshold 1.0
    # reproduces the full-depth argmax exactly
    eng = plane.engines[0]
    seeds = np.arange(min(12, eng.shard.num_local), dtype=np.int64)
    preds, confs, depths = eng.predict(seeds, np.ones(len(seeds)))
    full = np.argmax(eng.forward_depth(seeds, L)[: len(seeds)], axis=-1)
    np.testing.assert_array_equal(preds, full.astype(np.int32))
    assert np.all(depths == L)
    assert np.all(confs <= 1.0)


def test_batcher_drains_bursts_without_loss(bundle):
    plane = _plane(bundle, depth_schedule=None)
    rng = np.random.default_rng(3)
    V = len(plane.part)
    submitted = set()
    done = []
    # three bursts with steps interleaved, so escalated survivors from
    # earlier bursts re-batch with fresh arrivals
    for burst in range(3):
        vids = rng.integers(0, V, size=25)
        thrs = rng.choice([0.0, 0.5, 1.0], size=25)
        for v, t in zip(vids, thrs):
            submitted.add(plane.submit(int(v), float(t)))
        for _ in range(burst + 1):
            done.extend(plane.step())
    done.extend(plane.drain())
    assert plane.pending() == 0
    rids = [r.rid for r in done]
    assert len(rids) == len(set(rids)), "duplicated request ids"
    assert set(rids) == submitted, "dropped request ids"
    st = plane.stats()
    assert st["served"] == len(submitted)
    assert sum(st["exits_by_depth"].values()) == len(submitted)


def test_frontend_roundtrip_matches_offline(bundle):
    plane = _plane(bundle)
    rng = np.random.default_rng(4)
    V = len(plane.part)
    vids = rng.integers(0, V, size=20)
    with serve_in_thread(plane) as handle:
        with GnnServeClient(handle.address) as cli:
            preds, confs, depths = cli.predict(vids)
            stats = cli.stats()
    ref = _offline_ref(plane, vids)
    np.testing.assert_array_equal(
        preds, np.array([ref[int(v)] for v in vids], np.int32))
    assert np.all(depths == plane.engines[0].L)
    assert np.all((confs > 0.0) & (confs <= 1.0))
    assert stats["served"] == len(vids)
