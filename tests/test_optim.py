"""Optimizers: reference behaviour + state shapes + checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.optim import adafactor, adam, adamw, sgd


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


PARAMS = {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.1), adamw(0.1, weight_decay=0.0),
                                 adafactor(0.5)])
def test_optimizers_minimize_quadratic(opt):
    params = PARAMS
    state = opt.init(params)
    step = jax.jit(lambda p, s: opt.step(p, jax.grad(quad_loss)(p), s))
    l0 = float(quad_loss(params))
    for _ in range(60):
        params, state = step(params, state)
    assert float(quad_loss(params)) < 0.2 * l0, opt.name


def test_adam_matches_reference_first_step():
    """One Adam step against a hand-computed update."""
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    new, _ = opt.step(params, g, state)
    # bias-corrected m̂=4, v̂=16 ⇒ step = lr·4/(4+eps) ≈ lr
    np.testing.assert_allclose(float(new["w"][0]), 2.0 - 0.1, rtol=1e-5)


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.ones((64, 128)), "b": jnp.zeros((128,)),
              "stacked": jnp.ones((4, 32, 16))}
    st = opt.init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (128,)
    assert st.vr["b"].shape == (128,)      # vectors keep full stats
    assert st.vr["stacked"].shape == (4, 32)
    assert st.vc["stacked"].shape == (4, 16)
    # factored state is tiny relative to params
    pn = sum(x.size for x in jax.tree_util.tree_leaves(params))
    sn = sum(x.size for x in jax.tree_util.tree_leaves((st.vr, st.vc)))
    assert sn < 0.1 * pn


def test_adafactor_layer_stacked_map_equivalence():
    """The lax.map chunked path must equal updating layer slices
    individually (the memory fix for 340B stacked leaves)."""
    opt = adafactor(1e-2)
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.standard_normal((3, 8, 16)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((3, 8, 16)), jnp.float32)}
    st = opt.init(stacked)
    new, _ = opt.step(stacked, grads, st)
    for i in range(3):
        sl = {"w": stacked["w"][i]}
        gl = {"w": grads["w"][i]}
        sti = opt.init(sl)
        ni, _ = opt.step(sl, gl, sti)
        np.testing.assert_allclose(np.asarray(new["w"][i]),
                                   np.asarray(ni["w"]), rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    opt = adam(1e-3)
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    state = opt.init(params)
    save_pytree(tmp_path / "ck", {"params": params, "opt": state}, step=7)
    like = {"params": params, "opt": state}
    restored, manifest = load_pytree(tmp_path / "ck", like)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(like),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    save_pytree(tmp_path / "ck", {"a": jnp.ones(3)})
    with pytest.raises(AssertionError):
        load_pytree(tmp_path / "ck", {"b": jnp.ones(3)})
