"""Out-of-core graph plane: builder/generator bit-identity, streaming
partitioner properties, store-backed trainer parity, shard rebalancing."""

import dataclasses
import json
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FederatedGNNTrainer, default_strategies
from repro.core.federated import eval_arrays_for, sampled_eval_vertices
from repro.graphs import (bfs_partition, edge_cut, hash_partition,
                          make_client_shards, make_graph)
from repro.graphs.graph import from_edges
from repro.graphstore import (build_csr_store, build_rmat_store,
                              build_sbm_store, chunked, ldg_partition,
                              open_store, store_from_graph,
                              stream_client_shards)

SHARD_FIELDS = ("indptr", "indices", "global_ids", "features", "labels",
                "train_mask", "pull_nodes", "push_nodes", "all_pull_nodes")


def assert_graph_equal(g, st_):
    np.testing.assert_array_equal(g.indptr, st_.indptr)
    np.testing.assert_array_equal(g.indices, st_.indices)
    np.testing.assert_array_equal(g.features, st_.features)
    np.testing.assert_array_equal(g.labels, st_.labels)
    np.testing.assert_array_equal(g.train_mask, st_.train_mask)
    assert g.num_classes == st_.num_classes


# -- chunked CSR builder -------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(10, 400), st.integers(0, 5000), st.integers(0, 10_000))
def test_builder_bit_identical_to_from_edges(n_v, n_e, seed):
    import tempfile
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_v, n_e)
    dst = rng.integers(0, n_v, n_e)
    g = from_edges(n_v, src, dst, symmetric=True, dedup=True)
    with tempfile.TemporaryDirectory() as out:
        store = build_csr_store(
            chunked(src.astype(np.int64), dst.astype(np.int64), 257),
            n_v, out, est_pairs=max(1, n_e), bucket_pairs=501)
        np.testing.assert_array_equal(g.indptr, store.indptr)
        np.testing.assert_array_equal(g.indices, store.indices)
        store.validate()


@pytest.mark.parametrize("preset,scale", [("arxiv", 0.1), ("reddit", 0.1),
                                          ("products", 0.05),
                                          ("papers", 0.02)])
def test_sbm_stream_bit_identical(tmp_path, preset, scale):
    """Same (preset, scale, seed) key ⇒ the streaming chunk-replay and
    the in-memory generator emit the same graph, bit for bit."""
    g = make_graph(preset, scale=scale, seed=3)
    store = build_sbm_store(str(tmp_path / preset), preset, scale=scale,
                            seed=3, chunk_edges=997)
    assert_graph_equal(g, store)


def test_rmat_store_deterministic_and_valid(tmp_path):
    a = build_rmat_store(str(tmp_path / "a"), 10, edge_factor=8, seed=5)
    b = build_rmat_store(str(tmp_path / "b"), 10, edge_factor=8, seed=5)
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.features, b.features)
    a.validate()
    assert a.num_vertices == 1024
    assert a.num_classes > 0 and a.feat_dim > 0
    assert a.train_mask.sum() >= a.num_classes
    # reopening mmaps the same bytes
    c = open_store(str(tmp_path / "a"))
    np.testing.assert_array_equal(a.indices, c.indices)


# -- streaming shard extraction ------------------------------------------------

@pytest.mark.parametrize("limit", [None, 0, 3])
def test_stream_shards_bit_identical(tmp_path, small_graph, limit):
    g = small_graph
    part = bfs_partition(g, 4, seed=0)
    store = store_from_graph(g, str(tmp_path / "g"))
    a = make_client_shards(g, part, retention_limit=limit, seed=0)
    b = stream_client_shards(store, part, retention_limit=limit, seed=0,
                             chunk_edges=251)
    for x, y in zip(a, b):
        for f in SHARD_FIELDS:
            np.testing.assert_array_equal(getattr(x, f), getattr(y, f),
                                          err_msg=f"client {x.client_id} {f}")


def test_stream_shards_subset_matches_full(tmp_path, small_graph):
    g = small_graph
    part = bfs_partition(g, 4, seed=0)
    store = store_from_graph(g, str(tmp_path / "g"))
    full = stream_client_shards(store, part, seed=0)
    sub = stream_client_shards(store, part, client_ids=[1, 3], seed=0)
    for x, y in zip([full[1], full[3]], sub):
        for f in SHARD_FIELDS:
            np.testing.assert_array_equal(getattr(x, f), getattr(y, f))


# -- streaming LDG partitioner -------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10))
def test_ldg_balance_and_cut_property(k, seed):
    g = make_graph("arxiv", scale=0.1, seed=seed % 5)
    part = ldg_partition(g, k, seed=seed, chunk_vertices=200)
    assert part.min() >= 0 and part.max() < k
    sizes = np.bincount(part, minlength=k)
    cap = int(np.ceil(g.num_vertices / k) * 1.05)
    assert sizes.max() <= cap
    # locality: a streaming greedy partitioner must beat random
    # placement (decorrelated seed: hashing with the *graph's* seed
    # replays the label stream and inherits homophily for free)
    assert edge_cut(g, part) <= \
        edge_cut(g, hash_partition(g, k, seed=seed + 101))


def test_ldg_deterministic_and_store_agnostic(tmp_path, small_graph):
    g = small_graph
    store = store_from_graph(g, str(tmp_path / "g"))
    a = ldg_partition(g, 4, seed=1)
    b = ldg_partition(store, 4, seed=1)
    np.testing.assert_array_equal(a, b)


# -- store-backed trainer ------------------------------------------------------

def _round_fingerprint(stats):
    return [(s.accuracy, s.train_loss, s.embeddings_stored) for s in stats]


@pytest.mark.parametrize("sname", ["E", "OPG"])
def test_trainer_numerics_bit_identical_off_store(tmp_path, sname):
    """ISSUE-5 acceptance: FederatedGNNTrainer rounds off a GraphStore
    match the in-memory Graph exactly."""
    g = make_graph("reddit", scale=0.08, seed=11)
    part = bfs_partition(g, 3, seed=0)
    strat = default_strategies()[sname]
    tr1 = FederatedGNNTrainer(g, 3, strat, batch_size=64, seed=0, part=part)
    s1 = tr1.train(2)
    store = store_from_graph(g, str(tmp_path / "g"))
    tr2 = FederatedGNNTrainer(store, 3, strat, batch_size=64, seed=0,
                              part=part)
    s2 = tr2.train(2)
    assert _round_fingerprint(s1) == _round_fingerprint(s2)


def test_store_runconfig_shard_local_worker(tmp_path):
    """A store-backed RunConfig with prebuilt shards gives a worker an
    mmap'd shard-local trainer: owned samplers only, no eval graph, and
    a client_round that runs off the loaded shards."""
    from repro.fedsvc.runtime import RunConfig
    g = make_graph("arxiv", scale=0.1, seed=3)
    store = store_from_graph(g, str(tmp_path / "g"))
    k, seed = 3, 0
    part = ldg_partition(store, k, seed=seed)
    store.save_partition(part, k, seed)
    shards = stream_client_shards(store, part, seed=seed)
    for sh in shards:
        wanted = [o.pull_nodes[part[o.pull_nodes] == sh.client_id]
                  for o in shards if o.client_id != sh.client_id]
        sh.push_nodes = np.unique(np.concatenate(wanted)) if wanted \
            else np.zeros(0, np.int64)
    store.save_shards(shards, k, seed, None)

    cfg = RunConfig(graph=f"store:{store.path}", num_clients=k,
                    strategy="E", rounds=1, seed=seed)
    tr = cfg.build_trainer(only_clients=[1])
    assert tr.samplers[1] is not None and tr.samplers[0] is None
    assert tr.eval_arrays is None
    with pytest.raises(RuntimeError):
        tr.evaluate()
    tr.pretrain_round()
    res = tr.client_round(1)
    assert res.client_id == 1 and np.isfinite(res.loss)
    # the loaded shard is the one the full build produced
    for f in SHARD_FIELDS:
        np.testing.assert_array_equal(getattr(tr.shards[1], f),
                                      getattr(shards[1], f))
    # full (all-clients) trainer off the prebuilt shard files is
    # bit-identical to the in-memory trainer on the same partition
    tr_store = cfg.build_trainer()
    s_store = tr_store.train(1)
    tr_mem = FederatedGNNTrainer(
        g, k, cfg.build_strategy(), conv=cfg.conv,
        num_layers=cfg.num_layers, hidden=cfg.hidden, fanout=cfg.fanout,
        batch_size=cfg.batch_size, epochs_per_round=cfg.epochs_per_round,
        lr=cfg.lr, seed=seed, part=part)
    s_mem = tr_mem.train(1)
    assert _round_fingerprint(s_store) == _round_fingerprint(s_mem)


def test_store_eval_sampled_cap(tmp_path):
    """Past eval_max_edges the evaluation graph is the subgraph induced
    by a *seeded uniform vertex sample* whose edge mass fits the budget
    — deterministic in the seed and no longer a vertex prefix."""
    g = make_graph("arxiv", scale=0.1, seed=3)
    store = store_from_graph(g, str(tmp_path / "g"))
    strat = default_strategies()["D"]
    tr = FederatedGNNTrainer(store, 2, strat, batch_size=32, seed=0,
                             eval_max_edges=g.num_edges // 4)
    n_eval = int(tr.eval_arrays["num_local"])
    assert 0 < n_eval < g.num_vertices
    assert 0.0 <= tr.evaluate() <= 1.0
    # a uniform draw of a strict subset is (overwhelmingly) not the
    # prefix, and the same seed redraws the same subset
    assert not np.array_equal(tr.eval_gids, np.arange(n_eval))
    np.testing.assert_array_equal(
        tr.eval_gids, sampled_eval_vertices(g, g.num_edges // 4, seed=0))


def test_sampled_eval_full_budget_is_exact():
    """With a budget covering every edge the sampled estimator selects
    all vertices, and its induced arrays are bit-identical to the exact
    full-graph eval arrays the trainer builds below the cap."""
    g = make_graph("arxiv", scale=0.1, seed=3)
    sel = sampled_eval_vertices(g, g.num_edges, seed=5)
    np.testing.assert_array_equal(sel, np.arange(g.num_vertices))
    tr = FederatedGNNTrainer(g, 2, default_strategies()["D"],
                             batch_size=32, seed=0)   # default cap: exact
    ours = eval_arrays_for(g, sel)
    for k in ("edge_src", "edge_dst", "src_is_remote", "features"):
        np.testing.assert_array_equal(np.asarray(ours[k]),
                                      np.asarray(tr.eval_arrays[k]))
    assert ours["num_local"] == tr.eval_arrays["num_local"]


def test_sampled_eval_removes_prefix_bias(tmp_path):
    """Skewed store: labels follow build order (first half class 0), no
    train mask, and a crafted constant-class-0 model.  True full-graph
    accuracy is 0.5; the old vertex-prefix fallback reports 1.0; the
    seeded uniform sample must land near the truth."""
    v = 2000
    src = np.arange(v - 1)
    labels = (np.arange(v) >= v // 2).astype(np.int32)
    g = from_edges(v, src, src + 1,
                   features=np.ones((v, 4), np.float32), labels=labels,
                   train_mask=np.zeros(v, bool), num_classes=2)
    store = store_from_graph(g, str(tmp_path / "skew"))
    tr = FederatedGNNTrainer(store, 2, default_strategies()["D"],
                             batch_size=32, seed=0, num_layers=2,
                             hidden=8, eval_max_edges=g.num_edges // 4)
    # constant predictor: zero weights, bias argmax at class 0
    params = [dict(layer) for layer in tr.params]
    params[-1]["w_neigh"] = params[-1]["w_neigh"] * 0.0
    params[-1]["b"] = params[-1]["b"].at[0].set(1.0)
    acc = tr.evaluate(params)
    n_eval = len(tr.eval_gids)
    assert 0 < n_eval < v
    # what the removed prefix fallback would have estimated
    prefix_acc = float((labels[:n_eval] == 0).mean())
    assert prefix_acc == 1.0
    assert abs(acc - 0.5) < 0.15, acc


@pytest.mark.slow
def test_store_backed_multiprocess_control_plane(tmp_path):
    """Carried over from ISSUE-5: coordinator + 2 workers + 2 embed
    shards as real OS processes, every participant opening one prebuilt
    mmap store (``--graph store:<dir>`` with baked partition + shards),
    FedAvg history equal to the in-process trainer off the same store."""
    import socket
    import time as _time

    from repro.fedsvc.runtime import RunConfig

    out = str(tmp_path / "store")
    built = subprocess.run(
        [sys.executable, "-m", "repro.launch.build_store", "--out", out,
         "--preset", "reddit", "--scale", "0.05", "--graph-seed", "3",
         "--seed", "0", "--clients", "2"],
        capture_output=True, text=True, timeout=300)
    assert built.returncode == 0, built.stderr
    spec = f"store:{out}"

    # in-process reference off the very same store files
    cfg = RunConfig(graph=spec, num_clients=2, strategy="E", rounds=2,
                    seed=0)
    ref = cfg.build_trainer().train(2)

    def free_port() -> int:
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
        s.close()
        return port

    e1, e2, cp = free_port(), free_port(), free_port()
    common = ["--graph", spec, "--clients", "2", "--strategy", "E",
              "--rounds", "2", "--seed", "0",
              "--embed", f"127.0.0.1:{e1}", "--embed", f"127.0.0.1:{e2}"]
    out_json = tmp_path / "history.json"
    procs = []
    try:
        for port in (e1, e2):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.embed_server",
                 "--port", str(port), "--num-layers", "3",
                 "--hidden", "32"]))
        coord = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.fed_coordinator",
             "--port", str(cp), "--timeout", "540",
             "--out", str(out_json)] + common,
            stdout=subprocess.PIPE, text=True)
        procs.append(coord)
        _time.sleep(1.0)
        for i in range(2):
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.fed_worker",
                 "--coordinator", f"127.0.0.1:{cp}",
                 "--client-ids", str(i)] + common,
                stdout=subprocess.DEVNULL))
        stdout, _ = coord.communicate(timeout=600)
        assert "fed_coordinator DONE" in stdout, stdout
        history = json.loads(out_json.read_text())
        assert [h["accuracy"] for h in history] == \
            [s.accuracy for s in ref]
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()


# -- pull-frequency shard rebalancing -----------------------------------------

def test_rebalance_numerics_unchanged_and_balanced():
    g = make_graph("reddit", scale=0.08, seed=11)
    part = bfs_partition(g, 3, seed=0)
    base = dataclasses.replace(default_strategies()["E"],
                               num_server_shards=4)
    reb = dataclasses.replace(base, shard_placement="pull_frequency")
    s_base = FederatedGNNTrainer(g, 3, base, batch_size=64, seed=0,
                                 part=part).train(3)
    tr = FederatedGNNTrainer(g, 3, reb, batch_size=64, seed=0, part=part)
    s_reb = tr.train(3)
    assert _round_fingerprint(s_base) == _round_fingerprint(s_reb)
    pl = tr.exchange._placement
    assert pl is not None
    counts = tr.exchange._pull_counts
    hot = np.nonzero(counts > 0)[0]
    new_load = np.bincount(pl[hot], weights=counts[hot], minlength=4)
    hash_load = np.bincount(hot % 4, weights=counts[hot], minlength=4)
    assert new_load.max() <= hash_load.max() + 1e-9


def test_rebalance_without_log_keeps_hash_placement():
    from repro.exchange.transport import ShardedTransport
    t = ShardedTransport(3, 8, 4)
    assert t.rebalance_by_pulls() is None
    ids = np.array([3, 7, 11])
    np.testing.assert_array_equal(t.shard_of(ids), ids % 4)
    # pull tallies are off unless rebalancing asked for them (hot path)
    t.register(ids)
    t.gather(ids)
    assert not np.any(t._pull_counts)


def test_pull_frequency_needs_sharded_transport():
    g = make_graph("arxiv", scale=0.08, seed=3)
    strat = dataclasses.replace(default_strategies()["E"],
                                shard_placement="pull_frequency")
    with pytest.raises(ValueError, match="pull_frequency"):
        FederatedGNNTrainer(g, 2, strat, batch_size=32, seed=0)
    with pytest.raises(ValueError, match="shard_placement"):
        FederatedGNNTrainer(
            g, 2,
            dataclasses.replace(strat, shard_placement="pull_freq"),
            batch_size=32, seed=0)


def test_rebalance_migrates_rows():
    from repro.exchange.transport import ShardedTransport
    t = ShardedTransport(3, 4, 2)
    t.track_pulls = True
    ids = np.arange(10)
    t.register(ids)
    vals = [np.arange(40, dtype=np.float32).reshape(10, 4) * (l + 1)
            for l in range(2)]
    t.write(ids, vals)
    before = t.gather(ids)
    # skew the pull counts, rebalance, and read back identical rows
    for _ in range(3):
        t.gather(ids[:4])
    assert t.rebalance_by_pulls() is not None
    after = t.gather(ids)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    # registration still works after migration (fresh rows past holes)
    t.register(np.array([100]))
    t.write(np.array([100]), [np.full((1, 4), 9.0, np.float32)] * 2)
    np.testing.assert_array_equal(t.gather(np.array([100]))[0],
                                  np.full((1, 4), 9.0, np.float32))


# -- scale (dedicated CI job) --------------------------------------------------

@pytest.mark.slow
def test_build_store_cli_100k(tmp_path):
    """≥100k-vertex out-of-core build + partition + shards through the
    CLI, in a subprocess (the graph-plane CI job runs this)."""
    out = tmp_path / "rmat17"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.build_store",
         "--out", str(out), "--rmat-scale", "17", "--edge-factor", "8",
         "--graph-seed", "1", "--seed", "0", "--clients", "8"],
        capture_output=True, text=True, check=True)
    stats = json.loads(proc.stdout.strip().splitlines()[-1])
    assert stats["num_vertices"] == 1 << 17
    sizes = np.asarray(stats["part_sizes"])
    assert sizes.max() <= np.ceil((1 << 17) / 8) * 1.05
    store = open_store(str(out))
    store.validate()
    # one federated round off the freshly built store
    from repro.fedsvc.runtime import RunConfig
    cfg = RunConfig(graph=f"store:{store.path}", num_clients=8,
                    strategy="E", hidden=16, fanout=3, batch_size=32,
                    epochs_per_round=1, rounds=1, seed=0)
    tr = cfg.build_trainer()
    stats_r = tr.train(1)
    assert 0.0 <= stats_r[0].accuracy <= 1.0
