"""Deep numerical semantics of the zoo's building blocks.

The strongest test here is decode≡forward teacher-forcing consistency:
stepping the decode path token by token must reproduce the full-sequence
forward logits for every family (this exercises KV caches, ring-buffer
bookkeeping, RoPE offsets, SSD recurrent state, cross-attention caches).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.configs.base import ModelConfig
from repro.models import lm, moe as moe_lib, ssm as ssm_lib
from repro.models.layers import (blocked_attention, decode_attention,
                                 init_mla, mla_attention, mla_decode,
                                 init_mla_cache, apply_rope)


# -- blocked attention vs naive oracle ------------------------------------------

def naive_attention(q, k, v, *, causal, window, q_pos, kv_pos):
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bthd->bhqt", q, kk) / np.sqrt(dh)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - window < kv_pos[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", p, vv)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 17, 64]), st.sampled_from([None, 7, 16]),
       st.sampled_from([4, 5, 16]))
def test_blocked_attention_matches_naive(seed, g, skv, window, kv_block):
    rng = np.random.default_rng(seed)
    B, Hkv, dh = 2, 2, 8
    H = Hkv * g
    sq = skv
    q = jnp.asarray(rng.standard_normal((B, sq, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, skv, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, skv, Hkv, dh)), jnp.float32)
    pos = jnp.arange(sq)
    got = blocked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                            causal=True, window=window, kv_block=kv_block)
    want = naive_attention(q, k, v, causal=True, window=window,
                           q_pos=pos, kv_pos=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_naive():
    rng = np.random.default_rng(0)
    B, T, Hkv, G, dh = 3, 12, 2, 3, 8
    H = Hkv * G
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    valid = kv_pos < 9
    qpos = jnp.full((B,), 8)
    got = decode_attention(q, k, v, q_position=qpos, kv_positions=kv_pos,
                           window=None, kv_valid=valid)
    want = naive_attention(q, k[:, :9], v[:, :9], causal=True, window=None,
                           q_pos=jnp.array([8]), kv_pos=jnp.arange(9))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- decode == forward (teacher forcing) per family -------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-1.3b", "hymba-1.5b",
                                  "deepseek-v2-lite-16b",
                                  "llama-3.2-vision-11b", "whisper-tiny"])
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    if cfg.num_experts:
        # token-dropping MoE is batch-size-dependent; use capacity big
        # enough that nothing drops in either path
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    S = 16 if cfg.family not in ("ssm", "hybrid") else 64
    B = 2
    rng = np.random.default_rng(1)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    extras = {}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.vision_dim)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)

    ref_logits, _ = lm.forward(params, cfg, batch)

    cache = lm.init_cache(cfg, B, S)
    # seed cross-attention caches from the same memory the forward used
    cache = _seed_cross_caches(params, cfg, cache, batch)
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = dec(params, batch["tokens"][:, t: t + 1], cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        rtol=5e-3, atol=5e-3)


def _seed_cross_caches(params, cfg, cache, batch):
    """Fill decode-time cross K/V from the static memory (vision/encoder)."""
    from repro.models.layers import add_bias
    if cfg.family == "vlm":
        memory = batch["vision"] @ params["vis_proj"]

        def fill(blocks_cache, blocks_params):
            def one(lc, lp):
                k = add_bias(jnp.einsum("bsd,dhk->bshk", memory,
                                        lp["cross"]["wk"]),
                             lp["cross"].get("bk"))
                v = add_bias(jnp.einsum("bsd,dhk->bshk", memory,
                                        lp["cross"]["wv"]),
                             lp["cross"].get("bv"))
                lc = dict(lc)
                lc["cross_k"], lc["cross_v"] = k, v
                return lc

            n = jax.tree_util.tree_leaves(blocks_cache)[0].shape[0]
            return jax.vmap(one)(blocks_cache,
                                 blocks_params)

        cache = {"blocks": fill(cache["blocks"], params["blocks"])}
        return cache
    if cfg.family == "audio":
        # recompute the encoder output exactly as forward does
        enc_logits, _ = lm.forward(params, cfg, {
            "tokens": batch["tokens"][:, :1], "frames": batch["frames"]})
        # cheaper: call the internal encoder by running forward on a
        # 1-token prefix is wasteful but correct isn't available — rebuild:
        enc = _whisper_encode(params, cfg, batch["frames"])
        new = []
        for lp, lc in zip(params["dec_blocks"], cache["dec_blocks"]):
            k = add_bias(jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"]),
                         lp["cross"].get("bk"))
            v = add_bias(jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"]),
                         lp["cross"].get("bv"))
            lc = dict(lc)
            lc["cross_k"], lc["cross_v"] = k, v
            new.append(lc)
        return {"dec_blocks": new}
    return cache


def _whisper_encode(params, cfg, frames):
    from repro.models.layers import rms_norm, mlp, blocked_attention, add_bias
    enc = frames
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
    for lp in params["enc_blocks"]:
        h = rms_norm(enc, lp["ln1"], cfg.norm_eps)
        q = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]),
                     lp["attn"].get("bq"))
        k = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]),
                     lp["attn"].get("bk"))
        v = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"]),
                     lp["attn"].get("bv"))
        q = apply_rope(q, enc_pos, cfg.rope_theta)
        k = apply_rope(k, enc_pos, cfg.rope_theta)
        o = blocked_attention(q, k, v, q_positions=enc_pos,
                              kv_positions=enc_pos, causal=False, window=None)
        o = add_bias(jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"]),
                     lp["attn"].get("bo"))
        enc = enc + o
        h = rms_norm(enc, lp["ln2"], cfg.norm_eps)
        enc = enc + mlp(lp["mlp"], cfg, h)
    return rms_norm(enc, params["enc_norm"], cfg.norm_eps)


# -- sliding window ring buffer ----------------------------------------------------

def test_ring_buffer_window_decode():
    """With capacity == window < S, decode must equal a full forward with
    the same sliding window."""
    cfg = dataclasses.replace(get_reduced("smollm-360m"), sliding_window=8)
    S, B = 24, 2
    rng = np.random.default_rng(3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    ref, _ = lm.forward(params, cfg, batch)
    cache = lm.init_cache(cfg, B, 8)   # ring of window size
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = dec(params, batch["tokens"][:, t: t + 1], cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-3, atol=5e-3)


# -- MLA: absorbed decode == naive decode ----------------------------------------

def test_mla_absorb_equals_naive():
    cfg = get_reduced("deepseek-v2-lite-16b")
    rng = np.random.default_rng(5)
    p = init_mla(jax.random.PRNGKey(2), cfg)
    B, T = 2, 8
    cache_a = init_mla_cache(cfg, B, T, prefill_len=0)
    cache_b = jax.tree_util.tree_map(jnp.copy, cache_a)
    for t in range(4):
        x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
        out_n, cache_a = mla_decode(p, cfg, x, cache_a, absorb=False)
        out_a, cache_b = mla_decode(p, cfg, x, cache_b, absorb=True)
        np.testing.assert_allclose(np.asarray(out_n), np.asarray(out_a),
                                   rtol=2e-4, atol=2e-4)


# -- MoE dispatch ------------------------------------------------------------------

def test_moe_matches_dense_expert_sum():
    """With capacity high enough that nothing drops, sorted-dispatch MoE
    must equal the naive 'run every expert on every token' oracle."""
    cfg = dataclasses.replace(get_reduced("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=8.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    got, aux = moe_lib.moe_ffn(p, cfg, x)

    # oracle
    T = 16
    xt = x.reshape(T, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    all_out = moe_lib._expert_ffn(
        p, cfg, jnp.broadcast_to(xt, (cfg.num_experts, T, cfg.d_model)))
    want = jnp.zeros_like(xt)
    for kk in range(cfg.top_k):
        want = want + top_p[:, kk, None] * \
            all_out[top_e[:, kk], jnp.arange(T)]
    if cfg.num_shared_experts:
        from repro.models.layers import mlp
        want = want + mlp(p["shared"], cfg, xt)
    np.testing.assert_allclose(np.asarray(got).reshape(T, -1),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(get_reduced("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=0.25)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, cfg.d_model)),
                    jnp.float32)
    out, _ = moe_lib.moe_ffn(p, cfg, x)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())


# -- SSD: chunked dual form == stepwise recurrence ----------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100))
def test_ssd_chunked_equals_recurrent(seed):
    cfg = get_reduced("mamba2-1.3b")
    rng = np.random.default_rng(seed)
    p = ssm_lib.init_ssm(jax.random.PRNGKey(seed), cfg)
    B, S = 2, cfg.ssm_chunk * 2
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3,
                    jnp.float32)
    y_seq, (conv_tail, state_seq) = ssm_lib.ssm_forward(p, cfg, x)

    cache = ssm_lib.init_ssm_cache(cfg, B)
    ys = []
    for t in range(S):
        y_t, cache = ssm_lib.ssm_decode(p, cfg, x[:, t: t + 1], cache)
        ys.append(y_t[:, 0])
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(state_seq), rtol=2e-3, atol=2e-3)
