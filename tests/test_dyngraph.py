"""Dynamic-graph plane: overlay/compaction bit-identity, seeded growth
schedules, restreaming quality, and growth parity between the in-process
trainer and a multi-process fedsvc deployment."""

import dataclasses
import os

import numpy as np
import pytest

from repro.analysis.rules_wire import PLANES
from repro.dyngraph import (DeltaLog, GraphOverlay, GrowthRuntime,
                            GrowthSchedule, RestreamConfig, admit, compact,
                            edge_cut_stream, repartition, restream_pass)
from repro.dyngraph import wire as dyn_wire
from repro.fedsvc.coordinator import serve_in_thread
from repro.fedsvc.runtime import RunConfig, make_coordinator_state
from repro.fedsvc.worker import FedWorker, run_in_thread
from repro.graphstore import ldg_partition, open_store
from repro.obsv.metrics import REGISTRY

SCHED = GrowthSchedule(scale=9, seed=7, base_frac=0.5, num_events=4,
                       num_classes=8, feat_dim=16)
ARRAYS = ("indptr", "indices", "features", "labels", "train_mask")


@pytest.fixture(scope="module")
def grown(tmp_path_factory):
    """Base store, the overlay grown through every event, and the
    from-scratch build of the final graph."""
    root = tmp_path_factory.mktemp("dyn")
    base = SCHED.build_base(str(root / "base"))
    ov = GraphOverlay(base)
    for e in range(1, SCHED.num_events + 1):
        ov.apply(*SCHED.event_batch(e))
    full = SCHED.build_full(str(root / "full"))
    return root, base, ov, full


# -- overlay / compaction ------------------------------------------------------

def test_overlay_matches_full_build(grown):
    _, _, ov, full = grown
    assert int(ov.num_vertices) == int(full.num_vertices)
    assert int(ov.num_edges) == int(full.num_edges)
    assert int(ov.num_classes) == int(full.num_classes)
    for key in ARRAYS:
        np.testing.assert_array_equal(np.asarray(getattr(ov, key)),
                                      np.asarray(getattr(full, key)))


def test_compaction_bit_identical_to_rebuild(grown):
    root, _, ov, _ = grown
    out = str(root / "compacted")
    compact(ov, out, name="dyn_full")
    for key in ARRAYS:
        with open(os.path.join(out, f"{key}.npy"), "rb") as fa, \
                open(str(root / "full" / f"{key}.npy"), "rb") as fb:
            assert fa.read() == fb.read(), key
    open_store(out).validate()


def test_empty_overlay_is_passthrough(grown):
    _, base, _, _ = grown
    ov = GraphOverlay(base)
    # no segments: the edge/node accessors are the base's own arrays
    # (the empty-schedule run cannot diverge from the static run);
    # indptr is recomputed but value-identical
    assert ov.indices is base.indices
    assert ov.features is base.features
    assert ov.labels is base.labels
    assert ov.train_mask is base.train_mask
    np.testing.assert_array_equal(ov.indptr, np.asarray(base.indptr))
    assert int(ov.num_vertices) == int(base.num_vertices)


def test_delta_log_roundtrip(grown, tmp_path):
    _, base, ov, _ = grown
    log = DeltaLog(str(tmp_path))
    for seg in ov.segments:
        log.append(seg)
    ov2 = DeltaLog(str(tmp_path)).load(base)
    assert len(ov2.segments) == len(ov.segments)
    for key in ("indptr", "indices"):
        np.testing.assert_array_equal(np.asarray(getattr(ov2, key)),
                                      np.asarray(getattr(ov, key)))


# -- growth schedules ----------------------------------------------------------

def test_schedule_geometry():
    assert SCHED.frontier(0) == SCHED.base_vertices
    assert SCHED.frontier(SCHED.num_events) == SCHED.num_vertices
    fronts = [SCHED.frontier(e) for e in range(SCHED.num_events + 1)]
    assert fronts == sorted(fronts)
    assert SCHED.epoch_for_round(0) == 0
    assert SCHED.epoch_for_round(SCHED.start_round) == 1
    assert SCHED.epoch_for_round(10 ** 6) == SCHED.num_events


def test_events_partition_the_edge_stream():
    """Base + every event batch is exactly the full edge stream: no
    edge is emitted twice or dropped between epochs."""
    def pairs(chunks):
        out = [s * np.int64(SCHED.num_vertices) + d for s, d in chunks]
        return np.sort(np.concatenate(out)) if out else np.zeros(0)

    full = pairs(SCHED.full_chunks())
    split = [pairs(SCHED.base_chunks())]
    split += [pairs([SCHED.event_edges(e)])
              for e in range(1, SCHED.num_events + 1)]
    np.testing.assert_array_equal(np.sort(np.concatenate(split)), full)


def test_node_rows_are_frontier_independent():
    whole = SCHED.node_rows(0, SCHED.num_vertices)
    lo, hi = SCHED.base_vertices, SCHED.frontier(1)
    band = SCHED.node_rows(lo, hi)
    for key in ("features", "labels", "train_mask"):
        np.testing.assert_array_equal(band[key], whole[key][lo:hi])


def test_schedule_dict_roundtrip():
    assert GrowthSchedule.from_dict(SCHED.to_dict()) == SCHED


# -- restreaming ---------------------------------------------------------------

def test_admit_extends_without_moving(grown):
    _, base, ov, _ = grown
    k, cfg = 4, RestreamConfig()
    p0 = ldg_partition(base, k, seed=0)
    out = admit(ov, p0, k, cfg)
    assert len(out) == int(ov.num_vertices)
    np.testing.assert_array_equal(out[:len(p0)], p0)
    assert out.min() >= 0 and out.max() < k
    cap = int(np.ceil(ov.num_vertices / k) * cfg.slack)
    assert np.bincount(out, minlength=k).max() <= cap
    np.testing.assert_array_equal(out, admit(ov, p0, k, cfg))


def test_restream_pass_reduces_cut(grown):
    _, base, ov, _ = grown
    k = 4
    cfg = dataclasses.replace(RestreamConfig(), passes=3)
    p0 = admit(ov, ldg_partition(base, k, seed=0), k, cfg)
    p1 = repartition(ov, ldg_partition(base, k, seed=0), k, cfg)
    assert edge_cut_stream(ov, p1) < edge_cut_stream(ov, p0)
    # a pass never unbalances past the slack cap, and the whole chain
    # is deterministic in (graph, part, config)
    cap = int(np.ceil(ov.num_vertices / k) * cfg.slack)
    assert np.bincount(p1, minlength=k).max() <= cap
    np.testing.assert_array_equal(
        p1, repartition(ov, ldg_partition(base, k, seed=0), k, cfg))


def test_repartition_is_admit_plus_passes(grown):
    _, base, ov, _ = grown
    k = 4
    cfg = dataclasses.replace(RestreamConfig(), passes=2)
    p0 = ldg_partition(base, k, seed=0)
    manual = admit(ov, p0, k, cfg)
    for _ in range(2):
        manual = restream_pass(ov, manual, k, cfg)
    np.testing.assert_array_equal(repartition(ov, p0, k, cfg), manual)


def test_fennel_admission(grown):
    _, base, ov, _ = grown
    k = 4
    cfg = RestreamConfig(method="fennel")
    out = admit(ov, ldg_partition(base, k, seed=0), k, cfg)
    assert out.min() >= 0 and out.max() < k
    assert (np.bincount(out, minlength=k) > 0).all()


# -- wire / opcode band --------------------------------------------------------

def test_growth_wire_roundtrip():
    header = {"worker_id": "w0", "round": 3, "epoch": 2,
              "num_vertices": 512, "num_edges": 4096}
    op, parsed = dyn_wire.parse_growth_request(
        dyn_wire.build_growth(header))
    assert op == dyn_wire.OP_GROWTH
    assert parsed == header
    with pytest.raises(ValueError):
        dyn_wire.parse_growth_request(
            bytes([dyn_wire.GROWTH_HI]) + b"\x00" * 8)


def test_dyngraph_opcode_band_registered():
    spec = {p.name: p for p in PLANES}["dyngraph"]
    assert (spec.lo, spec.hi) == (48, 63)
    assert spec.opcodes["OP_GROWTH"] == dyn_wire.OP_GROWTH
    bands = sorted((p.lo, p.hi) for p in PLANES)
    for (_, hi_a), (lo_b, _) in zip(bands, bands[1:]):
        assert hi_a < lo_b, "opcode bands overlap"


# -- growth runtime ------------------------------------------------------------

def test_growth_runtime_advances_and_meters(grown):
    _, base, _, _ = grown
    rt = GrowthRuntime(SCHED, base, 4, passes=1)
    p0 = ldg_partition(base, 4, seed=0)
    assert rt.advance_to(2, part=p0)
    assert rt.applied_epoch == 2
    assert not rt.advance_to(2)            # idempotent
    assert not rt.advance_to(1)            # never rewinds
    assert int(rt.graph.num_vertices) == SCHED.frontier(2)
    assert len(rt.part) == SCHED.frontier(2)
    assert rt.advance_to(SCHED.num_events)
    snap = REGISTRY.snapshot(prefix="dyngraph")
    assert snap["dyngraph.segments"] >= 1
    assert snap["dyngraph.edge_cut"] > 0


# -- trainer integration -------------------------------------------------------

T_SCHED = GrowthSchedule(scale=10, seed=7, base_frac=0.5, num_events=2,
                         start_round=1, every_rounds=1, num_classes=8,
                         feat_dim=16)
T_KW = dict(num_clients=2, batch_size=64, epochs_per_round=2, seed=0,
            strategy="D", rounds=4)


@pytest.fixture(scope="module")
def t_base(tmp_path_factory):
    root = tmp_path_factory.mktemp("dyn_trainer")
    T_SCHED.build_base(str(root / "base"))
    return str(root / "base")


def _accs(stats):
    return [r.accuracy for r in stats]


def test_trainer_empty_schedule_bit_identical(tmp_path):
    """A growth-enabled run whose schedule has no events is the static
    run, bit for bit."""
    sched = dataclasses.replace(SCHED, num_events=0, base_frac=1.0)
    sched.build_base(str(tmp_path / "g"))
    kw = dict(T_KW, graph="store:" + str(tmp_path / "g"))
    static = RunConfig(**kw).build_trainer()
    h0 = static.train(3)
    dyn = RunConfig(growth=sched.to_dict(), **kw).build_trainer()
    h1 = dyn.train(3)
    assert _accs(h0) == _accs(h1)
    for a, b in zip(static.params_leaves(), dyn.params_leaves()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_growth_run(t_base):
    tr = RunConfig(graph="store:" + t_base,
                   growth=T_SCHED.to_dict(), **T_KW).build_trainer()
    hist = tr.train(T_KW["rounds"])
    assert tr.growth.applied_epoch == T_SCHED.num_events
    assert int(tr.g.num_vertices) == T_SCHED.num_vertices
    assert len(tr.part) == T_SCHED.num_vertices
    # eval set is re-drawn over the grown graph, not the base prefix
    assert len(tr.eval_gids) == T_SCHED.num_vertices
    accs = _accs(hist)
    assert len(accs) == T_KW["rounds"]
    assert all(np.isfinite(a) for a in accs)


# -- fedsvc deployments --------------------------------------------------------

def _deploy(cfg, *, timeout=600):
    state = make_coordinator_state(cfg)
    with serve_in_thread(state) as coord:
        workers = [FedWorker(cfg, [i], coord.address, worker_id=f"w{i}")
                   for i in range(cfg.num_clients)]
        threads = [run_in_thread(w) for w in workers]
        assert coord.join(timeout=timeout)
        for t in threads:
            t.join(timeout=60)
    return state, workers


@pytest.mark.slow
def test_fedsvc_empty_schedule_bit_identical(tmp_path):
    sched = dataclasses.replace(SCHED, num_events=0, base_frac=1.0)
    sched.build_base(str(tmp_path / "g"))
    kw = dict(T_KW, graph="store:" + str(tmp_path / "g"))
    s0, _ = _deploy(RunConfig(**kw))
    s1, _ = _deploy(RunConfig(growth=sched.to_dict(), **kw))
    assert s0.acc_history == s1.acc_history
    for a, b in zip(s0.leaves, s1.leaves):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_fedsvc_growth_matches_in_process(t_base):
    """Two worker processes growing independently under the coordinator
    barrier reproduce the in-process dynamic trainer exactly."""
    cfg = RunConfig(graph="store:" + t_base,
                    growth=T_SCHED.to_dict(), **T_KW)
    tr = cfg.build_trainer()
    want = _accs(tr.train(cfg.rounds))
    state, workers = _deploy(cfg)
    assert state.acc_history == want
    for w in workers:
        assert int(w.trainer.g.num_vertices) == T_SCHED.num_vertices
        assert w.trainer.growth.applied_epoch == T_SCHED.num_events


def test_growth_requires_sync_mode(t_base):
    cfg = RunConfig(graph="store:" + t_base, growth=T_SCHED.to_dict(),
                    **dict(T_KW, overrides={"aggregation": "async"}))
    with pytest.raises(ValueError, match="sync"):
        make_coordinator_state(cfg)
