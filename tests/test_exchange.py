"""Exchange subsystem: codecs, quantize kernel parity, delta pushes,
sharded transports, and the embedding-server regressions that rode
along (capacity-doubling register, explicit-empty layer selection)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EmbeddingServer, FederatedGNNTrainer, NetworkModel,
                        Strategy, default_strategies)
from repro.exchange import (DeltaTracker, ExchangeClient, InProcessTransport,
                            ShardedTransport, available_codecs, get_codec,
                            make_transport)
from repro.graphs import make_graph
from repro.kernels import ops, ref
from repro.kernels.quantize import dequantize_int8, quantize_int8


# -- codecs -------------------------------------------------------------------

def test_codec_registry():
    assert available_codecs() == ["fp16", "fp32", "int8"]
    assert get_codec("fp32").bytes_per_scalar(32) == 4.0
    assert get_codec("fp16").bytes_per_scalar(32) == 2.0
    assert get_codec("int8").bytes_per_scalar(32) == pytest.approx(1.125)
    with pytest.raises(ValueError):
        get_codec("fp8")


def test_fp32_roundtrip_identity():
    x = np.random.default_rng(0).standard_normal((50, 16)).astype(np.float32)
    np.testing.assert_array_equal(get_codec("fp32").roundtrip(x), x)


def test_fp16_exact_on_representable():
    # fp16-representable values survive the wire bit-exactly
    x = (np.random.default_rng(1).standard_normal((64, 8))
         .astype(np.float16).astype(np.float32))
    got = get_codec("fp16").roundtrip(x)
    assert got.dtype == np.float32
    np.testing.assert_array_equal(got, x)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 300), st.integers(1, 64), st.integers(0, 10**6))
def test_int8_roundtrip_error_bound(n, h, seed):
    # per-row symmetric scheme: |x - decode(encode(x))| <= absmax/254
    x = (np.random.default_rng(seed).standard_normal((n, h)) * 5
         ).astype(np.float32)
    got = get_codec("int8").roundtrip(x)
    bound = np.abs(x).max(axis=1, keepdims=True) / 254 + 1e-6
    assert (np.abs(got - x) <= bound).all()


def test_int8_zero_rows_stay_zero():
    got = get_codec("int8").roundtrip(np.zeros((4, 32), np.float32))
    np.testing.assert_array_equal(got, 0)


# -- quantize kernel: Pallas (interpret) vs jnp oracle ------------------------

@pytest.mark.parametrize("n,h", [(1, 1), (7, 32), (300, 32), (257, 129),
                                 (1024, 200)])
def test_quantize_pallas_matches_ref(n, h):
    x = jnp.asarray(np.random.default_rng(n + h).standard_normal((n, h)) * 3,
                    jnp.float32)
    pv, ps = quantize_int8(x, interpret=True)
    rv, rs = ref.quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(rs))
    pd = dequantize_int8(pv, ps, interpret=True)
    rd = ref.dequantize_int8(rv, rs)
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(rd))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 400), st.sampled_from([1, 32, 100, 128]),
       st.integers(0, 10**6))
def test_quantize_parity_property(n, h, seed):
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((n, h)),
                    jnp.float32)
    pv, ps = quantize_int8(x, interpret=True)
    rv, rs = ref.quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(rs))


def test_quantize_zero_rows():
    """Regression: the Pallas path must handle (0, h) — the delta filter
    produces empty pushes near convergence."""
    v, s = quantize_int8(jnp.zeros((0, 16), jnp.float32), interpret=True)
    assert v.shape == (0, 16) and s.shape == (0, 1)
    out = dequantize_int8(v, s, interpret=True)
    assert out.shape == (0, 16)
    rv, rs = ref.quantize_int8(jnp.zeros((0, 16), jnp.float32))
    assert rv.shape == (0, 16) and rs.shape == (0, 1)


def test_quantize_ops_dispatch():
    x = jnp.asarray(np.random.default_rng(3).standard_normal((64, 32)),
                    jnp.float32)
    av, ascale = ops.quantize_int8(x, use_pallas="auto")
    bv, bscale = ops.quantize_int8(x, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(av), np.asarray(bv))
    np.testing.assert_array_equal(np.asarray(ascale), np.asarray(bscale))
    da = ops.dequantize_int8(av, ascale, use_pallas="auto")
    db = ops.dequantize_int8(bv, bscale, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


# -- delta pushes -------------------------------------------------------------

def test_delta_first_push_is_full_then_thresholded():
    tr = DeltaTracker(0.5, num_layers_shared=2, hidden=4)
    gids = np.array([10, 20, 30])
    vals = [np.ones((3, 4), np.float32), np.ones((3, 4), np.float32)]
    sel = tr.select(gids, vals)
    assert sel.all()                          # never-pushed rows always go
    tr.commit(gids[sel], [v[sel] for v in vals])
    # unchanged → nothing selected
    assert not tr.select(gids, vals).any()
    # one row moves 100% (> τ=50%) → only it is re-pushed
    moved = [v.copy() for v in vals]
    moved[0][1] *= 2.0
    sel = tr.select(gids, moved)
    assert list(gids[sel]) == [20]
    tr.commit(gids[sel], [v[sel] for v in moved])
    np.testing.assert_array_equal(tr._shadow[tr._slot[20]][0], moved[0][1])
    assert tr.total_selected == 4 and tr.total_rows == 9


def test_delta_tau0_server_state_bit_exact():
    """τ=0 delta pushes leave the server bit-identical to full pushes."""
    rng = np.random.default_rng(0)
    gids = np.arange(40) * 7
    full = make_transport(3, 8)
    delta = make_transport(3, 8)
    cf = ExchangeClient(full, "fp32")
    cd = ExchangeClient(delta, "fp32", delta_threshold=0.0)
    for t in (full, delta):
        t.register(gids)
    for _ in range(3):
        vals = [rng.standard_normal((40, 8)).astype(np.float32)
                for _ in range(2)]
        # half the rows repeat the previous values exactly
        if _ > 0:
            vals = [np.where(np.arange(40)[:, None] % 2 == 0, prev, v)
                    for prev, v in zip(prev_vals, vals)]
        prev_vals = vals
        cf.push(gids, vals)
        cd.push(gids, vals)
    for a, b in zip(full.gather(gids), delta.gather(gids)):
        np.testing.assert_array_equal(a, b)
    # and the delta side shipped strictly fewer bytes
    assert delta.log.bytes < full.log.bytes


def test_abandoned_plan_leaves_shadow_consistent():
    """plan_push is side-effect free: dropping a plan must not leave the
    delta shadow ahead of the server."""
    gids = np.arange(8)
    t = make_transport(3, 4)
    ex = ExchangeClient(t, "fp32", delta_threshold=0.1)
    ex.register(gids)
    v1 = [np.ones((8, 4), np.float32) for _ in range(2)]
    ex.push(gids, v1)                       # shadow = v1
    v2 = [v * 3.0 for v in v1]
    ex.plan_push(gids, v2)                  # planned... and abandoned
    plan = ex.plan_push(gids, v2)           # must still select all rows
    assert plan.n_selected == 8
    ex.apply_push(plan)
    np.testing.assert_array_equal(t.gather(gids)[0], v2[0])
    # now the shadow is committed: re-planning selects nothing
    assert ex.plan_push(gids, v2).n_selected == 0
    # never-pushed rows stay "never pushed" across abandoned plans, even
    # all-zero ones whose delta against a zero shadow would be 0
    ex2 = ExchangeClient(make_transport(3, 4), "fp32", delta_threshold=0.1)
    ex2.register(gids)
    zeros = [np.zeros((8, 4), np.float32) for _ in range(2)]
    ex2.plan_push(gids, zeros)              # abandoned
    assert ex2.plan_push(gids, zeros).n_selected == 8


def test_delta_trainer_tau0_matches_full_bitexact():
    g = make_graph("reddit", scale=0.1, seed=3)
    base = default_strategies()["E"]
    tau0 = dataclasses.replace(base, delta_threshold=0.0)
    accs = []
    for strat in (base, tau0):
        tr = FederatedGNNTrainer(g, 3, strat, batch_size=64, seed=0)
        accs.append([s.accuracy for s in tr.train(3)])
    assert accs[0] == accs[1]


# -- transports ---------------------------------------------------------------

def _fill(transport, gids, hidden, layers, seed=0):
    rng = np.random.default_rng(seed)
    vals = [rng.standard_normal((len(gids), hidden)).astype(np.float32)
            for _ in range(layers)]
    transport.register(gids)
    transport.write(gids, vals)
    return vals


def test_sharded_gather_matches_inprocess():
    gids = np.random.default_rng(1).permutation(500)[:123]
    single = InProcessTransport(3, 16)
    sharded = ShardedTransport(3, 16, 4)
    v1 = _fill(single, gids, 16, 2, seed=5)
    _fill(sharded, gids, 16, 2, seed=5)
    perm = np.random.default_rng(2).permutation(len(gids))
    got_s = single.gather(gids[perm])
    got_4 = sharded.gather(gids[perm])
    for a, b, v in zip(got_s, got_4, v1):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, v[perm])


def test_sharded_traffic_split_and_parallel_time():
    gids = np.arange(400)
    single = InProcessTransport(3, 32)
    sharded = ShardedTransport(3, 32, 4)
    for t in (single, sharded):
        t.register(gids)
        t.account(gids, 2, 4.0)
    logs = sharded.shard_logs
    assert len(logs) == 4 and all(lg.bytes > 0 for lg in logs)
    # fp32 byte total is preserved exactly by the split
    assert sum(lg.bytes for lg in logs) == single.log.bytes
    assert sharded.log.bytes == single.log.bytes
    assert sharded.log.rpcs == 4 and single.log.rpcs == 1
    # shards run in parallel: wall time below the single-link time
    assert sharded.transfer_time(gids, 2, 4.0) < \
        single.transfer_time(gids, 2, 4.0)


def test_heterogeneous_shard_links():
    slow = NetworkModel(bandwidth_bytes_per_s=1e6,
                        rpc_overhead_s=0.1)
    fast = NetworkModel()
    tr = ShardedTransport(3, 32, 2, nets=[slow, fast])
    gids = np.arange(100)
    tr.register(gids)
    t = tr.account(gids, 2, 4.0)
    # the slow link dominates the parallel max
    assert t == pytest.approx(tr.shard_logs[0].seconds)
    assert tr.shard_logs[0].seconds > tr.shard_logs[1].seconds


def test_sharded_trainer_bit_identical_accuracy():
    """Acceptance: ShardedTransport(4) == single shard, bit-identical."""
    g = make_graph("reddit", scale=0.1, seed=3)
    base = default_strategies()["E"]
    accs, logs = [], []
    for shards in (1, 4):
        strat = dataclasses.replace(base, num_server_shards=shards,
                                    codec="int8")
        tr = FederatedGNNTrainer(g, 3, strat, batch_size=64, seed=0)
        accs.append([s.accuracy for s in tr.train(3)])
        logs.append(tr.server.log)
    assert accs[0] == accs[1]
    assert len(logs) == 2 and logs[1].rpcs > logs[0].rpcs  # split RPCs


# -- exchange client ----------------------------------------------------------

def test_client_pull_codec_bytes():
    gids = np.arange(64)
    for codec, factor in (("fp32", 1.0), ("fp16", 0.5),
                          ("int8", 36 / 128)):
        t = InProcessTransport(3, 32)
        ex = ExchangeClient(t, codec)
        ex.register(gids)
        ex.pull_cost(gids)
        assert t.log.bytes == int(round(64 * 32 * 2 * 4 * factor))


def test_client_pull_values_and_time():
    """pull() == peek() values + pull_cost() accounting in one call."""
    gids = np.arange(32)
    t = InProcessTransport(3, 8)
    ex = ExchangeClient(t, "fp16")
    ex.register(gids)
    vals = [np.random.default_rng(l).standard_normal((32, 8))
            .astype(np.float32) for l in range(2)]
    ex.push(gids, vals)
    bytes_before = t.log.bytes
    got, tm = ex.pull(gids)
    assert tm > 0 and t.log.bytes == bytes_before + 32 * 8 * 2 * 2
    for a, b in zip(got, ex.peek(gids)):
        np.testing.assert_array_equal(a, b)


def test_client_plan_apply_push_accounting():
    gids = np.arange(10)
    t = InProcessTransport(3, 4)
    ex = ExchangeClient(t, "fp32")
    ex.register(gids)
    vals = [np.ones((10, 4), np.float32) for _ in range(2)]
    plan = ex.plan_push(gids, vals)
    assert plan.transfer_time > 0 and t.log.bytes == 0   # planned, not sent
    ex.apply_push(plan)
    assert t.log.bytes == 10 * 4 * 2 * 4
    np.testing.assert_array_equal(t.gather(gids)[0], vals[0])


# -- embedding server regressions ---------------------------------------------

def test_register_amortized_growth():
    srv = EmbeddingServer(3, 8)
    for i in range(0, 1000, 10):                  # 100 incremental calls
        srv.register(np.arange(i, i + 10))
    assert len(srv._row) == 1000
    assert srv._reallocs <= 8                     # doubling, not per-call
    vals = [np.random.default_rng(0).standard_normal((1000, 8))
            .astype(np.float32) for _ in range(2)]
    ids = np.arange(1000)
    srv.push(ids, vals)
    got, _ = srv.pull(ids)
    for a, b in zip(vals, got):
        np.testing.assert_array_equal(a, b)


def test_pull_empty_layer_selection():
    """Regression: pull(layers=[]) must mean "no layers", not "all"."""
    srv = EmbeddingServer(3, 8)
    ids = np.array([1, 2, 3])
    srv.register(ids)
    srv.push(ids, [np.ones((3, 8), np.float32)] * 2)
    got, t = srv.pull(ids, layers=[])
    assert got == [] and t == 0.0
    got_all, _ = srv.pull(ids, layers=None)
    assert len(got_all) == 2


def test_network_model_codec_bytes():
    net = NetworkModel()
    assert net.embedding_bytes(10, 32, 2) == 10 * 32 * 2 * 4
    assert net.embedding_bytes(10, 32, 2, bytes_per_scalar=1.125) == \
        int(round(10 * 32 * 2 * 1.125))
    assert net.transfer_time(10, 32, 2, bytes_per_scalar=1.125) < \
        net.transfer_time(10, 32, 2)


# -- leaf-pytree codec form (the weight wire) ---------------------------------

def _leaves(rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.standard_normal((37, 16)).astype(np.float32),
            rng.standard_normal(16).astype(np.float32),
            np.float32(0.75).reshape(())]


def test_leaf_codec_roundtrip_shapes_and_exactness():
    from repro.exchange import decode_leaves, encode_leaves, wire
    leaves = _leaves()
    sizes = {}
    for name in available_codecs():
        tensors, shapes = encode_leaves(name, leaves)
        assert len(tensors) == get_codec(name).wire_arrays * len(leaves)
        back = decode_leaves(name, tensors, shapes)
        assert [b.shape for b in back] == [l.shape for l in leaves]
        assert all(b.dtype == np.float32 for b in back)
        sizes[name] = wire.tensors_nbytes(tensors)
        if name == "fp32":
            for b, l in zip(back, leaves):
                assert b.tobytes() == l.tobytes()     # lossless
        else:
            step = max(np.abs(l).max() for l in leaves)
            err = max(np.abs(b - l).max() for b, l in zip(back, leaves))
            assert 0 < err <= step / 100              # lossy but bounded
    # the point of the exercise: int8 leaves are ~4x smaller on the wire
    assert sizes["fp32"] / sizes["int8"] > 3.0
    assert sizes["fp32"] / sizes["fp16"] > 1.8


def test_leaf_codec_mismatched_payload_rejected():
    from repro.exchange import decode_leaves, encode_leaves
    tensors, shapes = encode_leaves("int8", _leaves())
    with pytest.raises(ValueError, match="arrays"):
        decode_leaves("int8", tensors[:-1], shapes)


def test_leaf_error_feedback_carries_residual():
    """Weight-plane EF: pushing the same delta repeatedly through int8
    keeps the *time-averaged* decoded value on the true delta — the
    residual is carried, not dropped, and stays bounded by one
    quantization step."""
    from repro.exchange import (LeafErrorFeedback, decode_leaves,
                                encode_leaves)
    rng = np.random.default_rng(3)
    delta = [rng.standard_normal((8, 8)).astype(np.float32) * 1e-3]
    ef = LeafErrorFeedback()
    assert ef.max_abs_residual == 0.0
    decoded_sum = np.zeros_like(delta[0])
    n = 20
    for _ in range(n):
        comp = ef.compensate(delta)
        tensors, shapes = encode_leaves("int8", comp)
        dec = decode_leaves("int8", tensors, shapes)
        ef.commit(comp, dec)
        decoded_sum += dec[0]
    step = float(np.abs(delta[0]).max()) / 127 * 2
    assert 0 < ef.max_abs_residual <= step
    # time-averaged decoded value tracks the true delta to well under a
    # quantization step (the bias EF exists to kill)
    np.testing.assert_allclose(decoded_sum / n, delta[0], atol=step / 4)
    # fp32 wire is exact: residual stays zero
    ef32 = LeafErrorFeedback()
    comp = ef32.compensate(delta)
    t32, s32 = encode_leaves("fp32", comp)
    ef32.commit(comp, decode_leaves("fp32", t32, s32))
    assert ef32.max_abs_residual == 0.0
    ef.reset()
    assert ef.max_abs_residual == 0.0


def test_model_transfer_time_codec_aware():
    net = NetworkModel()
    raw = net.model_transfer_time(10_000)
    q = net.model_transfer_time(10_000, bytes_per_scalar=1.0)
    assert q < raw
    assert raw == net.model_transfer_time(10_000, bytes_per_scalar=4.0)


# -- device-resident tables: fused int8 surface -------------------------------
#
# Acceptance: int8 push/pull through the device path (gather_quantized /
# write_quantized riding ops.gather_quantize / ops.dequant_scatter) is
# bit-identical to the numpy path, for every transport.  The TCP variant
# lives in tests/test_wire.py next to the other live-socket parity tests.

def _device_parity_transports(hidden):
    return {
        "inprocess": InProcessTransport(3, hidden, device_tables=True),
        "sharded": ShardedTransport(3, hidden, 4, device_tables=True),
    }


@pytest.mark.parametrize("kind", ["inprocess", "sharded"])
def test_device_tables_int8_bit_identical(kind):
    """Full ExchangeClient rounds (delta-filtered push → peek) over
    device tables == the numpy-table reference, bit for bit."""
    hidden = 24
    ref_t = InProcessTransport(3, hidden)
    dev_t = _device_parity_transports(hidden)[kind]
    ex_ref = ExchangeClient(ref_t, "int8", delta_threshold=0.05)
    ex_dev = ExchangeClient(dev_t, "int8", delta_threshold=0.05)
    assert ex_dev._fused_int8() and not ex_ref._fused_int8()
    gids = np.random.default_rng(0).permutation(700)[:211]
    rng = np.random.default_rng(1)
    for _ in range(2):
        vals = [rng.standard_normal((211, hidden)).astype(np.float32)
                for _ in range(2)]
        for ex in (ex_ref, ex_dev):
            ex.register(gids)
            ex.push(gids, vals)
        for a, b in zip(ex_ref.peek(gids), ex_dev.peek(gids)):
            np.testing.assert_array_equal(a, b)
    # partial-layer pulls ride the fused surface too
    for a, b in zip(ex_ref.peek(gids[:50], [1]), ex_dev.peek(gids[:50], [1])):
        np.testing.assert_array_equal(a, b)


def test_make_transport_device_tables_flag():
    t = make_transport(3, 8, kind="inprocess", device_tables=True)
    assert t.device_tables
    t = make_transport(3, 8, kind="sharded", num_shards=2,
                       device_tables=True)
    assert t.device_tables
    with pytest.raises(ValueError, match="device.tables"):
        make_transport(3, 8, kind="tcp", addrs=[("127.0.0.1", 1)],
                       device_tables=True)


def test_pull_dequant_aggregate_matches_host_path():
    """e2e consumer chain: int8 pull in wire form → fused
    dequant_aggregate == pull → host dequant → gnn_aggregate, bit for
    bit.  This is the trainer's aggregation step staying on device."""
    hidden = 32
    tr = InProcessTransport(3, hidden, device_tables=True)
    gids = np.arange(150)
    rng = np.random.default_rng(4)
    vals = [rng.standard_normal((150, hidden)).astype(np.float32)
            for _ in range(2)]
    tr.register(gids)
    tr.write(gids, vals)
    idx = rng.integers(0, 150, (60, 5)).astype(np.int32)
    mask = rng.random((60, 5)) < 0.8
    qv, qs = tr.gather_quantized(gids)[0]
    fused = ops.dequant_aggregate(qv, qs, idx, mask)
    host = ops.gnn_aggregate(
        ops.dequantize_int8(jnp.asarray(qv), jnp.asarray(qs)),
        jnp.asarray(idx), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(host))


@pytest.mark.parametrize("device_tables", [False, True])
def test_forget_then_register_reuses_rows(device_tables):
    """Regression for the vectorized gid→row map: forget frees rows,
    re-register must hand back consistent mappings (the dense _gid2row
    array and the free-list stay in sync)."""
    srv = EmbeddingServer(3, 8, device_tables=device_tables)
    srv.register(np.arange(20))
    vals = [np.full((20, 8), l + 1, np.float32) for l in range(2)]
    srv.write(np.arange(20), vals)
    srv.forget(np.arange(5, 15))
    # old survivors still resolve to their values
    np.testing.assert_array_equal(
        srv.gather(np.array([0, 4, 15, 19]))[0], vals[0][[0, 4, 15, 19]])
    # forgotten gids now raise
    with pytest.raises(KeyError, match="7"):
        srv.gather(np.array([7]))
    # new registrations may land on freed rows; values must not bleed
    srv.register(np.arange(100, 110))
    fresh = srv.gather(np.arange(100, 110))
    for layer in fresh:
        np.testing.assert_array_equal(layer, 0)
    new_vals = [np.full((10, 8), 9.0, np.float32) for _ in range(2)]
    srv.write(np.arange(100, 110), new_vals)
    np.testing.assert_array_equal(srv.gather(np.arange(100, 110))[1],
                                  new_vals[1])
    np.testing.assert_array_equal(
        srv.gather(np.array([0, 4, 15, 19]))[0], vals[0][[0, 4, 15, 19]])
