"""Graph substrate: construction, partitioning, sampling invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs import (NeighborSampler, bfs_partition, edge_cut,
                          from_edges, hash_partition, make_client_shards,
                          make_graph)


def test_from_edges_symmetric_dedup():
    g = from_edges(4, np.array([0, 0, 1, 2, 2]), np.array([1, 1, 2, 3, 0]))
    g.validate()
    # symmetric: every edge has its reverse
    for u in range(4):
        for v in g.neighbours(u):
            assert u in g.neighbours(int(v))
    # dedup: 0-1 appears once per direction
    assert list(g.neighbours(1)).count(0) == 1


def test_presets_statistics():
    g = make_graph("reddit", scale=0.2, seed=0)
    a = make_graph("arxiv", scale=0.2, seed=0)
    assert g.avg_degree() > 3 * a.avg_degree()  # density ordering of Table 1
    assert g.num_classes == 41 and a.num_classes == 40
    assert g.train_mask.mean() > a.train_mask.mean() * 0.8


def _bfs_partition_reference(g, k, seed):
    """Per-vertex Python mirror of the vectorized bfs_partition: same
    level-synchronous growth, water-filled leftovers, and frozen-
    snapshot ranked-admission refinement — the fixed-seed parity oracle
    for the CSR-sliced rewrite."""
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    target = (n + k - 1) // k
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    order = rng.permutation(n)
    cursor = 0
    for p in range(k):
        while cursor < n and part[order[cursor]] >= 0:
            cursor += 1
        if cursor >= n:
            break
        frontier = [int(order[cursor])]
        while frontier and sizes[p] < target:
            room = int(target - sizes[p])
            take, rest = frontier[:room], frontier[room:]
            for u in take:
                part[u] = p
            sizes[p] += len(take)
            if rest or sizes[p] >= target:
                break
            nxt = sorted({int(v) for u in take for v in g.neighbours(u)})
            frontier = [v for v in nxt if part[v] < 0]
    # leftovers: sequential-argmin fill counts, handed out to parts in
    # initial-size order, leftover vertices in id order
    left = np.nonzero(part < 0)[0]
    if len(left):
        fills = np.zeros(k, dtype=np.int64)
        s = sizes.copy()
        for _ in range(len(left)):
            p = int(np.argmin(s))
            fills[p] += 1
            s[p] += 1
        recv = np.argsort(sizes, kind="stable")
        seq = [p for p in recv for _ in range(fills[p])]
        for u, p in zip(left, seq):
            part[u] = p
        sizes += fills
    # frozen-snapshot refinement with ranked admission
    lo, hi = int(0.9 * target), int(1.1 * target) + 1
    cnt = np.zeros((n, k), dtype=np.int64)
    for u in range(n):
        for v in g.neighbours(u):
            cnt[u, part[v]] += 1
    best = np.argmax(cnt, axis=1)
    prio = np.empty(n, dtype=np.int64)
    prio[rng.permutation(n)] = np.arange(n)
    cand = [u for u in range(n)
            if len(g.neighbours(u)) and best[u] != part[u]
            and cnt[u, best[u]] > cnt[u, part[u]]
            and sizes[best[u]] < hi and sizes[part[u]] > lo]
    cand.sort(key=lambda u: prio[u])
    seen_dst = np.zeros(k, dtype=np.int64)
    seen_src = np.zeros(k, dtype=np.int64)
    moves = []
    for u in cand:
        d, s_ = int(best[u]), int(part[u])
        if seen_dst[d] < hi - sizes[d] and seen_src[s_] < sizes[s_] - lo:
            moves.append((u, d))
        seen_dst[d] += 1
        seen_src[s_] += 1
    for u, d in moves:
        part[u] = d
    return part


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 9), st.integers(0, 100), st.integers(0, 10_000))
def test_water_fill_matches_sequential_argmin(k, m, seed):
    """_water_fill's claimed semantics: exactly m sequential
    argmin(sizes) assignments (ties → lowest part index)."""
    from repro.graphs.partition import _water_fill
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, 30, size=k).astype(np.int64)
    got = _water_fill(sizes.copy(), m)
    f = np.zeros(k, np.int64)
    s = sizes.copy()
    for _ in range(m):
        p = int(np.argmin(s))
        f[p] += 1
        s[p] += 1
    np.testing.assert_array_equal(got, f)


@pytest.mark.parametrize("k,seed", [(2, 0), (4, 0), (3, 5)])
def test_bfs_partition_matches_reference(small_graph, k, seed):
    """The vectorized bfs_partition is output-identical to the
    per-vertex reference for fixed seeds (ISSUE-5 satellite gate)."""
    got = bfs_partition(small_graph, k, seed=seed)
    want = _bfs_partition_reference(small_graph, k, seed)
    np.testing.assert_array_equal(got, want)


def test_bfs_partition_balanced_and_better_than_hash(small_graph):
    g = small_graph
    for k in (2, 4):
        part = bfs_partition(g, k, seed=0)
        sizes = np.bincount(part, minlength=k)
        assert sizes.min() >= 0.7 * g.num_vertices / k
        assert edge_cut(g, part) <= edge_cut(g, hash_partition(g, k, seed=0))


def test_client_shards_partition_vertices(small_graph, small_shards):
    shards, part = small_shards
    locals_ = np.concatenate([s.global_ids[: s.num_local] for s in shards])
    assert len(locals_) == small_graph.num_vertices
    assert len(np.unique(locals_)) == small_graph.num_vertices
    for s in shards:
        # pull nodes live on other clients
        assert np.all(part[s.pull_nodes] != s.client_id)
        # push nodes are local
        assert np.all(part[s.push_nodes] == s.client_id)
        # remote rows have no in-edges (structural termination rule)
        assert s.indptr.shape[0] == s.num_local + 1


def test_push_pull_reciprocity(small_shards):
    shards, part = small_shards
    all_pull = np.unique(np.concatenate([s.pull_nodes for s in shards]))
    all_push = np.unique(np.concatenate([s.push_nodes for s in shards]))
    assert np.array_equal(all_pull, all_push)


@pytest.mark.parametrize("fanout,L", [(3, 2), (5, 3)])
def test_sampler_rules(small_shards, fanout, L):
    shards, _ = small_shards
    sh = shards[0]
    s = NeighborSampler(sh, fanout, L, batch_size=16, seed=1)
    for mb in list(s.epoch())[:3]:
        # roots are local training vertices
        seeds = mb.seeds[mb.seed_mask]
        assert np.all(seeds < sh.num_local)
        assert np.all(sh.train_mask[seeds])
        # rule 3: layer-1 block aggregates only local features
        b0 = mb.blocks[0]
        src = b0.src_ids[b0.edge_src[b0.edge_mask]]
        assert np.all(src < sh.num_local)
        # dst-prefix chaining: block l dst pad == block l+1 src pad
        for a, b in zip(mb.blocks, mb.blocks[1:]):
            assert a.p_src == 0 or True
            assert a.n_src >= a.n_dst
        for l in range(L - 1):
            assert mb.blocks[l].n_src == mb.blocks[l + 1 - 1].n_src  # sanity
            assert mb.blocks[l].p_dst == mb.blocks[l + 1].p_src
            assert mb.blocks[l].n_dst == mb.blocks[l + 1].n_src


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(2, 3), st.integers(0, 10_000))
def test_sampler_fanout_bound_property(fanout, L, seed):
    g = make_graph("arxiv", scale=0.05, seed=seed % 17)
    part = bfs_partition(g, 2, seed=seed % 5)
    sh = make_client_shards(g, part)[0]
    s = NeighborSampler(sh, fanout, L, batch_size=8, seed=seed)
    train = sh.train_vertices()
    if len(train) == 0:
        return
    mb = s.sample_batch(train[:8])
    for blk in mb.blocks:
        # each dst node aggregates at most `fanout` sampled neighbours
        dst = blk.edge_dst[blk.edge_mask]
        if len(dst):
            assert np.bincount(dst).max() <= fanout
        # remote dst rows carry valid cache slots
        slots = blk.dst_remote_slot[blk.dst_remote_mask]
        assert np.all(slots < max(1, sh.num_remote))
