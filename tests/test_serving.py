"""Continuous-batching serving runtime."""

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.serving import ContinuousBatcher
from repro.models import lm


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-1.3b",
                                  "deepseek-v2-lite-16b"])
def test_continuous_batching_completes_all(arch):
    cfg = get_reduced(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, params, lanes=2, capacity=32)
    rng = np.random.default_rng(0)
    rids = [cb.submit(rng.integers(0, cfg.vocab_size, ln), max_new=4)
            for ln in (3, 7, 5, 2, 6)]          # more requests than lanes
    done = cb.run_to_completion(max_steps=500)
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.generated)
    # continuous batching: lanes were reused (steps < sum of all lengths)
    serial = sum(3 + 4 for _ in rids) + 10
    assert cb.steps < serial


def test_lane_reuse_isolation():
    """A request starting on a reused lane must see a clean cache: its
    outputs must match running it alone on a fresh batcher."""
    cfg = get_reduced("smollm-360m")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, cfg.vocab_size, 5)
    p2 = rng.integers(0, cfg.vocab_size, 4)

    cb = ContinuousBatcher(cfg, params, lanes=1, capacity=32)
    cb.submit(p1, max_new=3)
    cb.submit(p2, max_new=3)
    done = cb.run_to_completion(max_steps=200)
    got = {r.rid: r.generated for r in done}

    fresh = ContinuousBatcher(cfg, params, lanes=1, capacity=32)
    fresh.submit(p2, max_new=3)
    ref = fresh.run_to_completion(max_steps=100)[0].generated
    assert got[1] == ref
