"""Deterministic stand-in for the ``hypothesis`` API surface these tests use.

The container may not ship hypothesis; conftest installs this module as
``sys.modules["hypothesis"]`` so the tier-1 suite still collects and the
property tests still run — each ``@given`` test is executed for
``max_examples`` deterministic draws (seeded per example index), which
keeps the property coverage without shrinking/replay.

Only the constructs the suite uses are provided: ``given``, ``settings``,
and ``strategies.integers / sampled_from / data``.
"""

from __future__ import annotations

import numpy as np


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)


class _DataStrategy(SearchStrategy):
    """Marker for ``st.data()`` — drawn lazily inside the test body."""

    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


class _DataObject:
    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: SearchStrategy):
        return strategy.draw(self._rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def data() -> SearchStrategy:
    return _DataStrategy()


class strategies:  # mirror `from hypothesis import strategies as st`
    SearchStrategy = SearchStrategy
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    floats = staticmethod(floats)
    data = staticmethod(data)


def given(*strategy_args):
    def decorate(fn):
        # deliberately no functools.wraps: pytest must see (*args, **kw)
        # so it does not try to inject fixtures for the drawn arguments.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hypothesis_max_examples", 10)
            for example in range(n):
                rng = np.random.default_rng(0xE5 + 7919 * example)
                drawn = [s.draw(rng) for s in strategy_args]
                fn(*args, *drawn, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return decorate


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def decorate(fn):
        fn._hypothesis_max_examples = max_examples
        return fn
    return decorate
