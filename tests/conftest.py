"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices.

If ``hypothesis`` is not installed (the container image does not ship
it), fall back to the deterministic shim in ``_hypothesis_shim`` so the
suite still collects and the property tests still run."""

import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim  # conftest's dir is on sys.path (no __init__.py)
    sys.modules["hypothesis"] = _hypothesis_shim

import numpy as np
import pytest

from repro.graphs import bfs_partition, make_client_shards, make_graph


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavy control-plane deployments (multi-process "
                   "CLI smokes, full multi-round thread deployments) and "
                   "≥100k-vertex graph-plane builds — run in CI's "
                   "control-plane / graph-plane jobs, not tier1")


@pytest.fixture(scope="session")
def small_graph():
    return make_graph("arxiv", scale=0.15, seed=7)


@pytest.fixture(scope="session")
def dense_graph():
    return make_graph("reddit", scale=0.2, seed=7)


@pytest.fixture(scope="session")
def small_shards(small_graph):
    part = bfs_partition(small_graph, 4, seed=0)
    return make_client_shards(small_graph, part), part
