"""Live TCP exchange: wire protocol, TcpTransport parity, calibration
fit, and the trainer compositions the unit tests never exercised
(codec × delta × shards through run_round; TCP end-to-end)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (EmbeddingServer, FederatedGNNTrainer, NetworkModel,
                        default_strategies)
from repro.core.cost_model import fit_network_model
from repro.exchange import (ExchangeClient, InProcessTransport, TcpTransport,
                            available_codecs, get_codec, make_transport,
                            parse_address, wire)
from repro.graphs import make_graph
from repro.launch.embed_server import serve_in_thread


@pytest.fixture
def two_shards():
    handles = [serve_in_thread(3, 16), serve_in_thread(3, 16)]
    yield handles
    for h in handles:
        h.stop()


# -- wire protocol ------------------------------------------------------------

def test_wire_request_roundtrip():
    gids = np.array([3, 11, 42], np.int64)
    op, req = wire.parse_request(wire.build_register(gids))
    assert op == wire.OP_REGISTER
    np.testing.assert_array_equal(req["global_ids"], gids)

    blocks = [wire.encode_block("fp32", np.ones((3, 4), np.float32))] * 2
    op, req = wire.parse_request(wire.build_write("fp32", gids, blocks))
    assert op == wire.OP_WRITE
    assert req["codec"] == "fp32" and req["num_blocks"] == 2
    np.testing.assert_array_equal(req["global_ids"], gids)
    got = wire.decode_block("fp32", req["payload"][:3 * 4 * 4], 3, 4)
    np.testing.assert_array_equal(got, 1.0)

    op, req = wire.parse_request(wire.build_gather("int8", gids, [1, 2]))
    assert op == wire.OP_GATHER
    assert req["codec"] == "int8" and req["layers"] == [1, 2]
    np.testing.assert_array_equal(req["global_ids"], gids)


@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8"])
def test_wire_block_bytes_match_network_model(codec):
    """Every codec's wire block is byte-for-byte what the analytic model
    charges: payload_nbytes == embedding_bytes per layer."""
    net = NetworkModel()
    cdc = get_codec(codec)
    for n, hidden in [(1, 8), (57, 32), (300, 128)]:
        x = np.random.default_rng(n).standard_normal(
            (n, hidden)).astype(np.float32)
        blob = wire.encode_block(codec, cdc.encode(x))
        assert len(blob) == wire.payload_nbytes(codec, n, hidden)
        assert len(blob) == net.embedding_bytes(
            n, hidden, 1, bytes_per_scalar=cdc.bytes_per_scalar(hidden))
        back = cdc.decode(wire.decode_block(codec, memoryview(blob),
                                            n, hidden))
        np.testing.assert_array_equal(np.asarray(back, np.float32),
                                      cdc.roundtrip(x))


def test_parse_address_forms():
    assert parse_address(("10.0.0.1", 7040)) == ("10.0.0.1", 7040)
    assert parse_address("10.0.0.1:7040") == ("10.0.0.1", 7040)
    assert parse_address(":7040") == ("127.0.0.1", 7040)


# -- TcpTransport vs InProcessTransport ---------------------------------------

@pytest.mark.parametrize("codec", sorted(available_codecs()))
def test_tcp_client_parity_every_codec(two_shards, codec):
    """Acceptance: a full ExchangeClient pipeline (push → peek) over a
    live 2-shard TCP wire is bit-identical to the in-process transport
    for every codec, across delta-filtered rounds."""
    tcp = TcpTransport(3, 16, [h.address for h in two_shards], codec=codec)
    inp = InProcessTransport(3, 16)
    ex_t = ExchangeClient(tcp, codec, delta_threshold=0.05)
    ex_i = ExchangeClient(inp, codec, delta_threshold=0.05)
    gids = np.random.default_rng(0).permutation(500)[:123]
    rng = np.random.default_rng(1)
    for _ in range(2):
        vals = [rng.standard_normal((123, 16)).astype(np.float32)
                for _ in range(2)]
        for ex in (ex_t, ex_i):
            ex.register(gids)
            ex.push(gids, vals)
        for a, b in zip(ex_t.peek(gids), ex_i.peek(gids)):
            np.testing.assert_array_equal(a, b)
    tcp.close()


def test_tcp_raw_write_gather_lossless_codecs(two_shards):
    """fp32/fp16 cross the wire losslessly once values are
    codec-representable: raw transport gather == in-process gather."""
    for codec in ("fp32", "fp16"):
        tcp = TcpTransport(3, 16, [h.address for h in two_shards],
                           codec=codec)
        inp = InProcessTransport(3, 16)
        gids = np.arange(100, 180)
        vals = [get_codec(codec).roundtrip(
            np.random.default_rng(l).standard_normal(
                (80, 16)).astype(np.float32)) for l in range(2)]
        for t in (tcp, inp):
            t.register(gids)
            t.write(gids, vals)
        for a, b in zip(tcp.gather(gids), inp.gather(gids)):
            np.testing.assert_array_equal(a, b)
        tcp.close()


@pytest.mark.parametrize("codec", ["fp32", "int8"])
def test_tcp_wire_bytes_equal_embedding_bytes(two_shards, codec):
    """Acceptance: measured on-wire payload bytes == the analytic
    NetworkModel.embedding_bytes, exactly, for fp32 and int8."""
    tcp = TcpTransport(3, 16, [h.address for h in two_shards], codec=codec)
    gids = np.arange(257)
    vals = [np.random.default_rng(l).standard_normal(
        (257, 16)).astype(np.float32) for l in range(2)]
    tcp.register(gids)
    tcp.write(gids, vals)
    tcp.gather(gids)
    bps = get_codec(codec).bytes_per_scalar(16)
    expect = NetworkModel().embedding_bytes(257, 16, 2,
                                            bytes_per_scalar=bps)
    wl = tcp.wire_log
    assert wl.bytes == 2 * expect          # one write + one gather
    # per-RPC: each shard's sample is exactly its row share
    for s in tcp.rpc_samples:
        if s.op in ("write", "gather"):
            assert s.payload_bytes == NetworkModel().embedding_bytes(
                s.n_rows, 16, s.layers, bytes_per_scalar=bps)
    assert wl.measured_seconds > 0 and wl.seconds > 0
    tcp.close()


def test_tcp_unregistered_gid_error_names_gids(two_shards):
    tcp = TcpTransport(3, 16, [h.address for h in two_shards])
    tcp.register(np.arange(10))
    with pytest.raises(RuntimeError, match="9999"):
        tcp.gather(np.array([2, 9999]))
    tcp.close()


def test_embedding_server_rows_error_is_actionable():
    srv = EmbeddingServer(3, 8)
    srv.register(np.arange(5))
    with pytest.raises(KeyError) as ei:
        srv.gather(np.array([1, 77, 88]))
    msg = str(ei.value)
    assert "77" in msg and "88" in msg and "5 registered" in msg


def test_tcp_reconnect_after_connection_drop(two_shards):
    """Dead pooled connections are dropped and the whole idempotent
    fan-out retried once — covering both send-time failures and
    recv-time failures (a send into a dead socket can still succeed
    into the kernel buffer)."""
    tcp = TcpTransport(3, 16, [h.address for h in two_shards])
    gids = np.arange(40)
    tcp.register(gids)
    for s in range(tcp.num_shards):          # kill the pooled sockets
        tcp._socks[s].close()
    vals = [np.ones((40, 16), np.float32) for _ in range(2)]
    tcp.write(gids, vals)
    np.testing.assert_array_equal(tcp.gather(gids)[0], 1.0)
    # recv-side failure: socket half-closed for reading only, so the
    # next send succeeds but the response read hits EOF
    for s in range(tcp.num_shards):
        tcp._socks[s].shutdown(__import__("socket").SHUT_RD)
    tcp.write(gids, [np.full((40, 16), 3.0, np.float32)] * 2)
    np.testing.assert_array_equal(tcp.gather(gids)[0], 3.0)
    tcp.close()


def test_tcp_mismatched_server_shape_fails_fast(two_shards):
    with pytest.raises(ValueError, match="hidden"):
        TcpTransport(3, 64, [h.address for h in two_shards])
    with pytest.raises(ValueError, match="--num-layers"):
        TcpTransport(5, 16, [h.address for h in two_shards])


# -- make_transport kind switch ----------------------------------------------

def test_make_transport_kind_switch(two_shards):
    from repro.exchange import ShardedTransport
    assert isinstance(make_transport(3, 8, kind="inprocess"),
                      InProcessTransport)
    assert isinstance(make_transport(3, 8, kind="sharded", num_shards=4),
                      ShardedTransport)
    t = make_transport(3, 16, kind="tcp",
                       addrs=[h.address for h in two_shards])
    assert isinstance(t, TcpTransport) and t.num_shards == 2
    t.close()
    # auto keeps the historical inference
    assert isinstance(make_transport(3, 8), InProcessTransport)
    assert isinstance(make_transport(3, 8, num_shards=2), ShardedTransport)
    with pytest.raises(ValueError):
        make_transport(3, 8, kind="tcp")                 # no addrs
    with pytest.raises(ValueError):
        make_transport(3, 8, kind="inprocess", num_shards=2)
    with pytest.raises(ValueError):
        make_transport(3, 8, kind="redis")
    with pytest.raises(ValueError):
        make_transport(3, 8, kind="sharded", addrs=[("h", 1)])


def test_client_codec_must_match_real_wire_codec(two_shards):
    tcp = TcpTransport(3, 16, [h.address for h in two_shards], codec="int8")
    with pytest.raises(ValueError, match="codec"):
        ExchangeClient(tcp, "fp32")
    tcp.close()


# -- calibration fit ----------------------------------------------------------

def test_fit_network_model_recovers_params():
    true = NetworkModel(bandwidth_bytes_per_s=1e8, rpc_overhead_s=2e-3,
                        per_embedding_overhead_s=5e-6)
    rng = np.random.default_rng(0)
    samples = []
    for n in (32, 128, 512, 2048):
        for hidden in (16, 64):
            b = n * hidden * 2 * 4
            e = n * 2
            t = b / true.bandwidth_bytes_per_s + true.rpc_overhead_s \
                + e * true.per_embedding_overhead_s
            samples.append((b, 1, e, t * (1 + 1e-3 * rng.standard_normal())))
    fit = fit_network_model(samples, relative=True)
    assert fit.bandwidth_bytes_per_s == pytest.approx(1e8, rel=0.1)
    assert fit.rpc_overhead_s == pytest.approx(2e-3, rel=0.1)
    assert fit.per_embedding_overhead_s == pytest.approx(5e-6, rel=0.1)


def test_fit_network_model_nonnegative_and_minimum_samples():
    with pytest.raises(ValueError):
        fit_network_model([(1.0, 1, 1, 0.1)])
    # pathological anti-correlated bytes: coefficient clamps to zero
    samples = [(1e6, 1, 10, 0.001), (2e6, 1, 20, 0.0009),
               (4e6, 1, 40, 0.0008), (8e6, 1, 80, 0.0007)]
    fit = fit_network_model(samples)
    assert fit.rpc_overhead_s >= 0 and fit.per_embedding_overhead_s >= 0


def test_quantize_numpy_path_matches_jnp_oracle():
    """The host-array fast path the codec hits must stay bit-identical
    to the jnp oracle (and hence to the Pallas kernel)."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(7)
    for n, h in [(1, 1), (63, 32), (300, 129), (0, 16)]:
        x = (rng.standard_normal((n, h)) * 3).astype(np.float32)
        qn, sn = ops.quantize_int8(x)                   # numpy path
        qj, sj = ref.quantize_int8(jnp.asarray(x))      # jnp oracle
        np.testing.assert_array_equal(qn, np.asarray(qj))
        np.testing.assert_array_equal(sn, np.asarray(sj))
        np.testing.assert_array_equal(
            ops.dequantize_int8(qn, sn), np.asarray(
                ref.dequantize_int8(qj, sj)))


# -- trainer compositions -----------------------------------------------------

def test_trainer_opp_int8_delta_sharded_e2e():
    """Composition coverage: OPP (overlap + pruning + prefetch) with
    codec=int8, τ=0.05 delta pushes and 2 server shards, end-to-end
    through run_round — previously only unit-tested in isolation.
    Sharding must not change numerics even composed with everything."""
    g = make_graph("reddit", scale=0.05, seed=3)
    base = default_strategies()["OPP"]
    accs = []
    for shards in (1, 2):
        strat = dataclasses.replace(base, codec="int8",
                                    delta_threshold=0.05,
                                    num_server_shards=shards)
        tr = FederatedGNNTrainer(g, 2, strat, batch_size=64, seed=0)
        stats = tr.train(2)
        accs.append([s.accuracy for s in stats])
        assert all(np.isfinite(s.accuracy) for s in stats)
        assert all(np.isfinite(s.train_loss) for s in stats)
        assert stats[-1].embeddings_stored > 0
        assert tr.server.log.rpcs > 0 and tr.server.log.bytes > 0
        trackers = [ex.delta for ex in tr.ex_clients if ex is not None]
        assert all(t is not None for t in trackers)
        assert sum(t.total_rows for t in trackers) > 0
    assert accs[0] == accs[1]


def test_trainer_tcp_smoke_bit_identical():
    """Acceptance: a 2-client, 2-shard trainer over live loopback TCP
    reaches accuracy bit-identical to the in-process transports with
    the same seed and codec."""
    g = make_graph("reddit", scale=0.05, seed=3)
    base = default_strategies()["E"]
    st_ref = dataclasses.replace(base, num_server_shards=2, codec="int8")
    tr_ref = FederatedGNNTrainer(g, 2, st_ref, batch_size=64, seed=0)
    accs_ref = [s.accuracy for s in tr_ref.train(2)]

    handles = [serve_in_thread(3, 32), serve_in_thread(3, 32)]
    try:
        st_tcp = dataclasses.replace(base, num_server_shards=2,
                                     codec="int8", transport="tcp")
        tr_tcp = FederatedGNNTrainer(
            g, 2, st_tcp, batch_size=64, seed=0,
            transport_addrs=[h.address for h in handles])
        accs_tcp = [s.accuracy for s in tr_tcp.train(2)]
        assert accs_ref == accs_tcp
        wl = tr_tcp.exchange.wire_log
        assert wl.rpcs > 0 and wl.bytes > 0 and wl.measured_seconds > 0
        tr_tcp.exchange.close()
    finally:
        for h in handles:
            h.stop()


def test_trainer_push_rows_cached_consistent():
    """The hoisted push-row indices must equal a fresh g2l lookup."""
    g = make_graph("reddit", scale=0.05, seed=3)
    tr = FederatedGNNTrainer(g, 3, default_strategies()["E"],
                             batch_size=64, seed=0)
    for ci, sh in enumerate(tr.shards):
        g2l = {int(v): i
               for i, v in enumerate(sh.global_ids[:sh.num_local])}
        expect = np.array([g2l[int(v)] for v in sh.push_nodes], np.int64)
        np.testing.assert_array_equal(tr.push_rows[ci], expect)


def test_tcp_device_tables_parity_int8():
    """Acceptance: a device-table TCP server (fused gather+encode /
    decode+scatter on resident jax tables) answers int8 pushes and
    pulls bit-identically to numpy-table servers, through a full
    ExchangeClient pipeline across delta-filtered rounds."""
    handles = [serve_in_thread(3, 16, device_tables=True),
               serve_in_thread(3, 16, device_tables=True)]
    try:
        assert all(h.store.device_tables for h in handles)
        tcp = TcpTransport(3, 16, [h.address for h in handles],
                           codec="int8")
        inp = InProcessTransport(3, 16)
        ex_t = ExchangeClient(tcp, "int8", delta_threshold=0.05)
        ex_i = ExchangeClient(inp, "int8", delta_threshold=0.05)
        gids = np.random.default_rng(0).permutation(500)[:123]
        rng = np.random.default_rng(1)
        for _ in range(2):
            vals = [rng.standard_normal((123, 16)).astype(np.float32)
                    for _ in range(2)]
            for ex in (ex_t, ex_i):
                ex.register(gids)
                ex.push(gids, vals)
            for a, b in zip(ex_t.peek(gids), ex_i.peek(gids)):
                np.testing.assert_array_equal(a, b)
        tcp.close()
    finally:
        for h in handles:
            h.stop()
