from .graph import Graph, from_edges, induced_subgraph
from .partition import (ClientShard, bfs_partition, edge_cut, hash_partition,
                        make_client_shards)
from .sampler import Block, MiniBatch, NeighborSampler
from .synthetic import PRESETS, make_graph

__all__ = [
    "Graph", "from_edges", "induced_subgraph", "ClientShard",
    "bfs_partition", "hash_partition", "edge_cut", "make_client_shards",
    "Block", "MiniBatch", "NeighborSampler", "PRESETS", "make_graph",
]
