"""Immutable CSR graph store.

Numpy-backed compressed-sparse-row graphs used by the federated GNN
substrate.  Adjacency is stored as *in-edges*: ``indices[indptr[u]:
indptr[u+1]]`` are the in-neighbours of ``u`` — the set aggregated by a
GNN layer (Eqn. 2.1 of the paper).  Generators in this package produce
symmetric graphs, so in == out unless stated otherwise.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """A CSR graph with optional node features / labels / train mask."""

    indptr: np.ndarray            # (V+1,) int64
    indices: np.ndarray           # (E,)  int32 — in-neighbours, sorted per row
    features: Optional[np.ndarray] = None   # (V, F) float32
    labels: Optional[np.ndarray] = None     # (V,)  int32
    train_mask: Optional[np.ndarray] = None  # (V,) bool
    num_classes: int = 0
    name: str = "graph"

    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feat_dim(self) -> int:
        return 0 if self.features is None else int(self.features.shape[1])

    def in_degree(self, u: Optional[np.ndarray] = None) -> np.ndarray:
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbours(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]: self.indptr[u + 1]]

    def avg_degree(self) -> float:
        return self.num_edges / max(1, self.num_vertices)

    def validate(self) -> None:
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        if self.num_edges:
            assert self.indices.min() >= 0
            assert self.indices.max() < self.num_vertices
        if self.features is not None:
            assert self.features.shape[0] == self.num_vertices
        if self.labels is not None:
            assert self.labels.shape[0] == self.num_vertices

    def train_vertices(self) -> np.ndarray:
        if self.train_mask is None:
            return np.arange(self.num_vertices)
        return np.nonzero(self.train_mask)[0].astype(np.int64)


def from_edges(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    *,
    symmetric: bool = True,
    dedup: bool = True,
    **node_data,
) -> Graph:
    """Build a CSR :class:`Graph` from a (src → dst) edge list.

    ``symmetric=True`` adds the reverse edges; ``dedup`` removes parallel
    edges and self-loops.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if dedup:
        keep = src != dst
        src, dst = src[keep], dst[keep]
        key = dst * num_vertices + src
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    # CSR over in-edges: group by dst.
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(indptr=indptr, indices=src.astype(np.int32), **node_data)


def induced_subgraph(g: Graph, nodes: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Vertex-induced subgraph; returns (subgraph, global_ids) where
    ``global_ids[i]`` is the global id of local vertex ``i``."""
    nodes = np.asarray(nodes, dtype=np.int64)
    g2l = np.full(g.num_vertices, -1, dtype=np.int64)
    g2l[nodes] = np.arange(len(nodes))
    src_all, dst_all = [], []
    for li, u in enumerate(nodes):
        nbrs = g.neighbours(u)
        loc = g2l[nbrs]
        keep = loc >= 0
        src_all.append(loc[keep])
        dst_all.append(np.full(int(keep.sum()), li, dtype=np.int64))
    src = np.concatenate(src_all) if src_all else np.zeros(0, np.int64)
    dst = np.concatenate(dst_all) if dst_all else np.zeros(0, np.int64)
    sub = from_edges(
        len(nodes), src, dst, symmetric=False, dedup=False,
        features=None if g.features is None else g.features[nodes],
        labels=None if g.labels is None else g.labels[nodes],
        train_mask=None if g.train_mask is None else g.train_mask[nodes],
        num_classes=g.num_classes, name=f"{g.name}/induced",
    )
    return sub, nodes
