"""Graph partitioning for cross-silo federated subgraph learning.

The paper uses METIS with vertex balancing and minimised edge cuts.  METIS
is not installable offline, so we provide a multilevel-lite equivalent:
BFS-grown balanced partitions followed by greedy Kernighan-Lin-style
boundary refinement.  A ``hash`` baseline is included for ablations.

``ClientShard`` is the per-client view the federated runtime consumes:
the *expanded* subgraph (local ∪ retained remote pull nodes, CSR over
local destinations), the pull/push node sets, and local→global maps.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .graph import Graph


def bfs_partition(g: Graph, k: int, *, seed: int = 0) -> np.ndarray:
    """BFS-grow ``k`` balanced parts, then greedily refine the edge cut."""
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    target = (n + k - 1) // k
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    order = rng.permutation(n)
    seeds = iter(order)

    for p in range(k):
        # find an unassigned seed
        for s in seeds:
            if part[s] < 0:
                break
        else:
            break
        frontier = [int(s)]
        while frontier and sizes[p] < target:
            u = frontier.pop()
            if part[u] >= 0:
                continue
            part[u] = p
            sizes[p] += 1
            for v in g.neighbours(u):
                if part[v] < 0:
                    frontier.append(int(v))
    # leftovers → smallest part
    for u in np.nonzero(part < 0)[0]:
        p = int(np.argmin(sizes))
        part[u] = p
        sizes[p] += 1

    # one refinement sweep: move boundary vertices if it reduces the cut
    # without unbalancing (size stays within ±10% of target).
    lo, hi = int(0.9 * target), int(1.1 * target) + 1
    for u in rng.permutation(n):
        nbrs = g.neighbours(u)
        if len(nbrs) == 0:
            continue
        counts = np.bincount(part[nbrs], minlength=k)
        best = int(np.argmax(counts))
        cur = int(part[u])
        if best != cur and counts[best] > counts[cur] and \
                sizes[best] < hi and sizes[cur] > lo:
            part[u] = best
            sizes[cur] -= 1
            sizes[best] += 1
    return part


def hash_partition(g: Graph, k: int, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=g.num_vertices).astype(np.int32)


def edge_cut(g: Graph, part: np.ndarray) -> int:
    dst = np.repeat(np.arange(g.num_vertices), np.diff(g.indptr))
    return int((part[g.indices] != part[dst]).sum())


@dataclasses.dataclass
class ClientShard:
    """Per-client expanded subgraph + federation metadata.

    Local vertices occupy indices ``[0, num_local)``; retained remote
    (pull) vertices occupy ``[num_local, num_local + num_remote)``.
    Remote vertices have no in-edges here (their neighbourhoods are on
    other clients), matching the sampler rule that a remote node
    terminates a sampling path.
    """

    client_id: int
    indptr: np.ndarray          # (num_local+1,) in-edges of LOCAL vertices only
    indices: np.ndarray         # (E_local,) local indices into [0, n_total)
    global_ids: np.ndarray      # (n_total,) local→global
    num_local: int
    features: np.ndarray        # (num_local, F) — remotes have NO h^0
    labels: np.ndarray          # (num_local,)
    train_mask: np.ndarray      # (num_local,)
    pull_nodes: np.ndarray      # global ids of retained remote vertices
    push_nodes: np.ndarray      # global ids of local vertices other clients pull
    all_pull_nodes: np.ndarray  # global ids of remote in-neighbours pre-pruning
    num_classes: int = 0

    @property
    def num_remote(self) -> int:
        return int(len(self.global_ids) - self.num_local)

    def is_remote(self, local_idx: np.ndarray) -> np.ndarray:
        return np.asarray(local_idx) >= self.num_local

    def train_vertices(self) -> np.ndarray:
        return np.nonzero(self.train_mask)[0].astype(np.int64)


def _retention_edge_mask(e_dst: np.ndarray, remote_mask: np.ndarray,
                         limit: int, rng: np.random.Generator) -> np.ndarray:
    """§4.1.1 uniform random pruning with retention limit, at EDGE level:
    each local destination keeps at most ``limit`` of its remote in-edges
    (uniformly at random).  Edges arrive grouped by dst."""
    keep = ~remote_mask
    if limit > 0:
        prio = rng.random(len(e_dst))
        # rank of each remote edge among its (dst)'s remote edges by prio
        order = np.lexsort((prio, ~remote_mask, e_dst))
        ranked = np.zeros(len(e_dst), np.int64)
        pos = np.arange(len(e_dst))
        # position within each (dst, remote=True) run
        sorted_dst = e_dst[order]
        sorted_rem = remote_mask[order]
        grp_start = np.r_[0, 1 + np.nonzero(np.diff(sorted_dst))[0]]
        run_id = np.zeros(len(e_dst), np.int64)
        run_id[grp_start] = 1
        run_id = np.cumsum(run_id) - 1
        within = pos - grp_start[run_id]
        ranked[order] = within
        keep = keep | (remote_mask & (ranked < limit))
    return keep


def make_client_shards(
    g: Graph,
    part: np.ndarray,
    *,
    retained_remote: Optional[dict[int, np.ndarray]] = None,
    retention_limit: Optional[int] = None,
    seed: int = 0,
) -> list[ClientShard]:
    """Split ``g`` by ``part`` into :class:`ClientShard` views.

    ``retention_limit`` applies §4.1.1 uniform random pruning (each local
    boundary vertex keeps ≤ limit remote in-edges; 0 ⇒ default federated
    GNN, None ⇒ P_inf / EmbC).  ``retained_remote`` optionally maps
    client → global ids of remote vertices to retain (score-based pruning,
    §4.1.2); both compose (limit first, then the vertex set filter).
    """
    k = int(part.max()) + 1
    deg = np.diff(g.indptr)
    dst_of_edge = np.repeat(np.arange(g.num_vertices), deg)
    src_of_edge = g.indices.astype(np.int64)
    shards = []
    for c in range(k):
        rng = np.random.default_rng(seed + 104729 * c)
        local = np.nonzero(part == c)[0].astype(np.int64)
        e_mask = part[dst_of_edge] == c
        e_src, e_dst = src_of_edge[e_mask], dst_of_edge[e_mask]
        remote_mask = part[e_src] != c
        all_pull = np.unique(e_src[remote_mask])
        if retention_limit is not None:
            keep = _retention_edge_mask(e_dst, remote_mask,
                                        retention_limit, rng)
            e_src, e_dst = e_src[keep], e_dst[keep]
            remote_mask = remote_mask[keep]
        if retained_remote is not None:
            keep_set = np.asarray(retained_remote.get(c, all_pull),
                                  dtype=np.int64)
            keep = np.isin(e_src, keep_set) | ~remote_mask
            e_src, e_dst = e_src[keep], e_dst[keep]
            remote_mask = remote_mask[keep]
        pull = np.unique(e_src[remote_mask])
        # push nodes: local vertices that appear as in-neighbours on other
        # clients (symmetric graphs ⇒ out-edges mirror in-edges).
        other_dst = part[dst_of_edge] != c
        push = np.unique(src_of_edge[other_dst & (part[src_of_edge] == c)])

        g2l = np.full(g.num_vertices, -1, dtype=np.int64)
        g2l[local] = np.arange(len(local))
        g2l[pull] = len(local) + np.arange(len(pull))
        order = np.argsort(e_dst, kind="stable")
        e_src, e_dst = g2l[e_src[order]], g2l[e_dst[order]]
        indptr = np.zeros(len(local) + 1, dtype=np.int64)
        np.add.at(indptr, e_dst + 1, 1)
        indptr = np.cumsum(indptr)
        shards.append(ClientShard(
            client_id=c,
            indptr=indptr,
            indices=e_src.astype(np.int32),
            global_ids=np.concatenate([local, pull]),
            num_local=len(local),
            features=g.features[local],
            labels=g.labels[local],
            train_mask=g.train_mask[local],
            pull_nodes=pull,
            push_nodes=push,
            all_pull_nodes=all_pull,
            num_classes=g.num_classes,
        ))
    return shards
