"""Graph partitioning for cross-silo federated subgraph learning.

The paper uses METIS with vertex balancing and minimised edge cuts.  METIS
is not installable offline, so we provide a multilevel-lite equivalent:
BFS-grown balanced partitions followed by greedy Kernighan-Lin-style
boundary refinement.  A ``hash`` baseline is included for ablations.

``ClientShard`` is the per-client view the federated runtime consumes:
the *expanded* subgraph (local ∪ retained remote pull nodes, CSR over
local destinations), the pull/push node sets, and local→global maps.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .graph import Graph


def neighbours_of(indptr: np.ndarray, indices: np.ndarray,
                  frontier: np.ndarray) -> np.ndarray:
    """CSR range-gather: the concatenated in-neighbour lists of every
    vertex in ``frontier``, without a per-vertex Python loop."""
    starts = indptr[frontier]
    cnt = indptr[frontier + 1] - starts
    total = int(cnt.sum())
    if total == 0:
        return np.zeros(0, indices.dtype)
    offs = np.cumsum(cnt) - cnt
    pos = np.arange(total, dtype=np.int64) \
        - np.repeat(offs, cnt) + np.repeat(starts, cnt)
    return indices[pos]


def ranks_within(groups: np.ndarray) -> np.ndarray:
    """Rank of each element within its group value, preserving list
    order — the ranked-admission primitive shared by the refinement
    sweep here and the streaming LDG partitioner."""
    order = np.argsort(groups, kind="stable")
    gs = groups[order]
    starts = np.r_[0, 1 + np.nonzero(np.diff(gs))[0]] \
        if len(gs) else np.zeros(0, np.int64)
    run = np.zeros(len(groups), np.int64)
    run[starts] = 1
    run = np.cumsum(run) - 1
    r = np.empty(len(groups), dtype=np.int64)
    r[order] = np.arange(len(groups)) - starts[run]
    return r


def _water_fill(sizes: np.ndarray, m: int) -> np.ndarray:
    """Distribute ``m`` extra slots over parts, always topping up the
    currently-smallest part (ties → lowest part index).  Returns the
    per-part fill counts; the vectorized equivalent of ``m`` sequential
    ``argmin(sizes)`` assignments (fuzz-pinned against that loop in
    tests/test_graphs.py)."""
    k = len(sizes)
    fills = np.zeros(k, dtype=np.int64)
    if m <= 0:
        return fills
    order = np.argsort(sizes, kind="stable")
    s = sizes[order].astype(np.int64)
    # raise the lowest j+1 parts to the level of part j+1: cumulative
    # cost.  Equal sizes have zero diff, so searchsorted(side="right")
    # pulls every part tied at the final level into the receiver set.
    lift = np.cumsum(np.arange(1, k) * np.diff(s))
    j = int(np.searchsorted(lift, m, side="right"))   # parts 0..j receive
    base = m - (lift[j - 1] if j > 0 else 0)
    level = s[j]
    f = np.zeros(k, dtype=np.int64)
    f[: j + 1] = level - s[: j + 1]
    # `base` slots remain once everyone is level: sequential argmin now
    # round-robins the receivers in PART-INDEX order (its tie-break),
    # so whole extra laps go to all of them and the remainder to the
    # lowest part ids among them — not to the previously-smallest.
    nrecv = j + 1
    f[:nrecv] += base // nrecv
    rem = int(base % nrecv)
    if rem:
        lowest_ids = np.sort(order[:nrecv])[:rem]
        fills[lowest_ids] += 1
    fills[order] += f
    return fills


def bfs_partition(g: Graph, k: int, *, seed: int = 0) -> np.ndarray:
    """Level-synchronous BFS-grown balanced parts + one vectorized
    boundary-refinement sweep.

    Fully CSR-sliced numpy: each part grows a whole BFS frontier per
    step (capped at the balance target), leftovers are water-filled onto
    the smallest parts, and the refinement pass moves every profitable
    boundary vertex against a frozen snapshot of the partition, with
    per-part in/out capacity enforced by ranked admission.  ~100×
    faster than the per-vertex flood it replaces at 100k+ vertices;
    ``tests/test_graphs.py`` pins it against a pure-Python reference of
    the same algorithm."""
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    target = (n + k - 1) // k
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    order = rng.permutation(n)
    cursor = 0   # next seed candidate in `order`

    for p in range(k):
        while cursor < n and part[order[cursor]] >= 0:
            cursor += 1
        if cursor >= n:
            break
        frontier = order[cursor: cursor + 1].astype(np.int64)
        while len(frontier) and sizes[p] < target:
            room = int(target - sizes[p])
            take, rest = frontier[:room], frontier[room:]
            part[take] = p
            sizes[p] += len(take)
            if len(rest) or sizes[p] >= target:
                break
            nxt = np.unique(neighbours_of(g.indptr, g.indices, take))
            frontier = nxt[part[nxt] < 0].astype(np.int64)

    # leftovers → water-fill onto the smallest parts (vertex-id order)
    left = np.nonzero(part < 0)[0]
    if len(left):
        fills = _water_fill(sizes, len(left))
        recv = np.argsort(sizes, kind="stable")
        part[left] = np.repeat(recv, fills[recv]).astype(np.int32)
        sizes += fills

    # one vectorized refinement sweep against a frozen snapshot: move a
    # boundary vertex to its majority-neighbour part when that strictly
    # beats its current part, admitting moves in seeded-permutation
    # order until the ±10% balance band (dest inflow / source outflow
    # capacity) is exhausted.
    lo, hi = int(0.9 * target), int(1.1 * target) + 1
    deg = np.diff(g.indptr)
    e_dst = np.repeat(np.arange(n, dtype=np.int64), deg)
    # fused-index bincount: ~10× the throughput of an np.add.at scatter
    cnt = np.bincount(e_dst * k + part[g.indices],
                      minlength=n * k).reshape(n, k)
    best = np.argmax(cnt, axis=1)
    cur = part.astype(np.int64)
    ar = np.arange(n)
    cand = (best != cur) & (cnt[ar, best] > cnt[ar, cur]) \
        & (sizes[best] < hi) & (sizes[cur] > lo) & (deg > 0)
    prio = np.empty(n, dtype=np.int64)
    prio[rng.permutation(n)] = np.arange(n)    # sweep order of the old loop
    cand_idx = np.nonzero(cand)[0]
    if len(cand_idx):
        cand_idx = cand_idx[np.argsort(prio[cand_idx], kind="stable")]
        dest, src = best[cand_idx], cur[cand_idx]
        admit = (ranks_within(dest) < (hi - sizes)[dest]) \
            & (ranks_within(src) < (sizes - lo)[src])
        moved = cand_idx[admit]
        part[moved] = best[moved].astype(np.int32)
    return part


def hash_partition(g: Graph, k: int, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=g.num_vertices).astype(np.int32)


def edge_cut(g: Graph, part: np.ndarray) -> int:
    dst = np.repeat(np.arange(g.num_vertices), np.diff(g.indptr))
    return int((part[g.indices] != part[dst]).sum())


@dataclasses.dataclass
class ClientShard:
    """Per-client expanded subgraph + federation metadata.

    Local vertices occupy indices ``[0, num_local)``; retained remote
    (pull) vertices occupy ``[num_local, num_local + num_remote)``.
    Remote vertices have no in-edges here (their neighbourhoods are on
    other clients), matching the sampler rule that a remote node
    terminates a sampling path.
    """

    client_id: int
    indptr: np.ndarray          # (num_local+1,) in-edges of LOCAL vertices only
    indices: np.ndarray         # (E_local,) local indices into [0, n_total)
    global_ids: np.ndarray      # (n_total,) local→global
    num_local: int
    features: np.ndarray        # (num_local, F) — remotes have NO h^0
    labels: np.ndarray          # (num_local,)
    train_mask: np.ndarray      # (num_local,)
    pull_nodes: np.ndarray      # global ids of retained remote vertices
    push_nodes: np.ndarray      # global ids of local vertices other clients pull
    all_pull_nodes: np.ndarray  # global ids of remote in-neighbours pre-pruning
    num_classes: int = 0

    @property
    def num_remote(self) -> int:
        return int(len(self.global_ids) - self.num_local)

    def is_remote(self, local_idx: np.ndarray) -> np.ndarray:
        return np.asarray(local_idx) >= self.num_local

    def train_vertices(self) -> np.ndarray:
        return np.nonzero(self.train_mask)[0].astype(np.int64)


def _retention_edge_mask(e_dst: np.ndarray, remote_mask: np.ndarray,
                         limit: int, rng: np.random.Generator) -> np.ndarray:
    """§4.1.1 uniform random pruning with retention limit, at EDGE level:
    each local destination keeps at most ``limit`` of its remote in-edges
    (uniformly at random).  Edges arrive grouped by dst."""
    keep = ~remote_mask
    if limit > 0:
        prio = rng.random(len(e_dst))
        # rank of each remote edge among its (dst)'s remote edges by prio
        order = np.lexsort((prio, ~remote_mask, e_dst))
        ranked = np.zeros(len(e_dst), np.int64)
        pos = np.arange(len(e_dst))
        # position within each (dst, remote=True) run
        sorted_dst = e_dst[order]
        sorted_rem = remote_mask[order]
        grp_start = np.r_[0, 1 + np.nonzero(np.diff(sorted_dst))[0]]
        run_id = np.zeros(len(e_dst), np.int64)
        run_id[grp_start] = 1
        run_id = np.cumsum(run_id) - 1
        within = pos - grp_start[run_id]
        ranked[order] = within
        keep = keep | (remote_mask & (ranked < limit))
    return keep


def assemble_shard(
    g,
    part: np.ndarray,
    c: int,
    e_src: np.ndarray,
    e_dst: np.ndarray,
    push: np.ndarray,
    *,
    retention_limit: Optional[int] = None,
    retained_remote: Optional[dict[int, np.ndarray]] = None,
    seed: int = 0,
) -> ClientShard:
    """Assemble one :class:`ClientShard` from the client's in-edge list.

    ``e_src``/``e_dst`` are the global (src → dst) in-edges of client
    ``c``'s local vertices in global CSR order (grouped by dst); the
    full-graph path and the out-of-core streaming extractor
    (``repro.graphstore``) both land here, so the shard bytes can never
    diverge between the two graph planes.  ``g`` only needs the node
    arrays (features/labels/train_mask) and ``num_classes`` — a
    :class:`Graph` or an mmap-backed store both work.
    """
    rng = np.random.default_rng(seed + 104729 * c)
    local = np.nonzero(part == c)[0].astype(np.int64)
    remote_mask = part[e_src] != c
    all_pull = np.unique(e_src[remote_mask])
    if retention_limit is not None:
        keep = _retention_edge_mask(e_dst, remote_mask,
                                    retention_limit, rng)
        e_src, e_dst = e_src[keep], e_dst[keep]
        remote_mask = remote_mask[keep]
    if retained_remote is not None:
        keep_set = np.asarray(retained_remote.get(c, all_pull),
                              dtype=np.int64)
        keep = np.isin(e_src, keep_set) | ~remote_mask
        e_src, e_dst = e_src[keep], e_dst[keep]
        remote_mask = remote_mask[keep]
    pull = np.unique(e_src[remote_mask])

    g2l = np.full(len(part), -1, dtype=np.int64)
    g2l[local] = np.arange(len(local))
    g2l[pull] = len(local) + np.arange(len(pull))
    order = np.argsort(e_dst, kind="stable")
    e_src, e_dst = g2l[e_src[order]], g2l[e_dst[order]]
    indptr = np.zeros(len(local) + 1, dtype=np.int64)
    np.add.at(indptr, e_dst + 1, 1)
    indptr = np.cumsum(indptr)
    return ClientShard(
        client_id=c,
        indptr=indptr,
        indices=e_src.astype(np.int32),
        global_ids=np.concatenate([local, pull]),
        num_local=len(local),
        features=np.asarray(g.features[local]),
        labels=np.asarray(g.labels[local]),
        train_mask=np.asarray(g.train_mask[local]),
        pull_nodes=pull,
        push_nodes=push,
        all_pull_nodes=all_pull,
        num_classes=g.num_classes,
    )


def make_client_shards(
    g: Graph,
    part: np.ndarray,
    *,
    retained_remote: Optional[dict[int, np.ndarray]] = None,
    retention_limit: Optional[int] = None,
    seed: int = 0,
) -> list[ClientShard]:
    """Split ``g`` by ``part`` into :class:`ClientShard` views.

    ``retention_limit`` applies §4.1.1 uniform random pruning (each local
    boundary vertex keeps ≤ limit remote in-edges; 0 ⇒ default federated
    GNN, None ⇒ P_inf / EmbC).  ``retained_remote`` optionally maps
    client → global ids of remote vertices to retain (score-based pruning,
    §4.1.2); both compose (limit first, then the vertex set filter).

    Materializes the full O(E) edge array — right for in-memory graphs;
    an mmap :class:`repro.graphstore.GraphStore` should go through
    ``repro.graphstore.stream_client_shards`` (bit-identical output,
    bounded memory).
    """
    k = int(part.max()) + 1
    deg = np.diff(g.indptr)
    dst_of_edge = np.repeat(np.arange(g.num_vertices), deg)
    src_of_edge = g.indices.astype(np.int64)
    shards = []
    for c in range(k):
        e_mask = part[dst_of_edge] == c
        e_src, e_dst = src_of_edge[e_mask], dst_of_edge[e_mask]
        # push nodes: local vertices that appear as in-neighbours on other
        # clients (symmetric graphs ⇒ out-edges mirror in-edges).
        other_dst = part[dst_of_edge] != c
        push = np.unique(src_of_edge[other_dst & (part[src_of_edge] == c)])
        shards.append(assemble_shard(
            g, part, c, e_src, e_dst, push,
            retention_limit=retention_limit,
            retained_remote=retained_remote, seed=seed))
    return shards


def filter_shard_remote(sh: ClientShard,
                        keep_gids: np.ndarray) -> ClientShard:
    """Shard-local §4.1.2 filter: drop remote in-edges whose source is
    not in ``keep_gids`` and compact the pull slots.

    Equivalent to rebuilding the shard with ``retained_remote`` (the
    edge order, pull ordering and local→global maps all match the
    full-graph rebuild), but needs only the shard itself — the
    out-of-core plane uses it so a worker holding one mmap'd shard can
    apply score-based pruning without re-scanning the graph."""
    keep_set = np.asarray(keep_gids, dtype=np.int64)
    e_dst = np.repeat(np.arange(sh.num_local), np.diff(sh.indptr))
    e_src = sh.indices.astype(np.int64)
    remote = e_src >= sh.num_local
    src_gid = sh.global_ids[e_src]
    keep = ~remote | np.isin(src_gid, keep_set)
    e_src, e_dst = e_src[keep], e_dst[keep]
    remote = remote[keep]
    pull = np.unique(sh.global_ids[e_src[remote]])
    # remap: locals keep their slots, surviving pulls compact after them
    g2l = np.full(int(sh.global_ids.max()) + 1, -1, dtype=np.int64)
    g2l[sh.global_ids[: sh.num_local]] = np.arange(sh.num_local)
    g2l[pull] = sh.num_local + np.arange(len(pull))
    e_src = g2l[sh.global_ids[e_src]]
    indptr = np.zeros(sh.num_local + 1, dtype=np.int64)
    np.add.at(indptr, e_dst + 1, 1)
    indptr = np.cumsum(indptr)
    return dataclasses.replace(
        sh, indptr=indptr, indices=e_src.astype(np.int32),
        global_ids=np.concatenate([sh.global_ids[: sh.num_local], pull]),
        pull_nodes=pull)
