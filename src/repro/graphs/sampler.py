"""Mini-batch neighbourhood sampler with federated boundary rules.

Builds DGL-style bipartite *blocks* for an L-layer GNN, enforcing the
paper's §3.2.2 custom-sampler rules:

  (1) only LOCAL vertices are sampled at the root level;
  (2) a remote vertex sampled at hop l ≤ L-1 terminates its path (its
      neighbourhood lives on another client);
  (3) no remote vertices appear at the L-th hop (their h^0 features are
      unavailable at the embedding server for privacy).

Blocks are padded to static shapes so the JAX training step compiles
once per (shard, batch size).  Remote destination nodes are *not*
computed by the GNN layer — the runtime overwrites their rows from the
client's local embedding cache (h^l pulled from the embedding server).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from .partition import ClientShard


@dataclasses.dataclass
class Block:
    """One bipartite sampling layer.  dst nodes are a prefix of src nodes."""

    src_ids: np.ndarray          # (P_src,) shard-local node ids (padded w/ 0)
    n_src: int
    n_dst: int
    edge_src: np.ndarray         # (P_e,) indices into src_ids
    edge_dst: np.ndarray         # (P_e,) indices into [0, n_dst)
    edge_mask: np.ndarray        # (P_e,) bool
    dst_remote_mask: np.ndarray  # (P_dst,) bool — dst rows served from cache
    dst_remote_slot: np.ndarray  # (P_dst,) int32 — row in the remote cache
    dst_mask: np.ndarray         # (P_dst,) bool

    @property
    def p_src(self) -> int:
        return int(self.src_ids.shape[0])

    @property
    def p_dst(self) -> int:
        return int(self.dst_remote_mask.shape[0])


@dataclasses.dataclass
class MiniBatch:
    blocks: list[Block]          # blocks[0] consumes hop-L nodes (h^0 input)
    seeds: np.ndarray            # root training vertices (shard-local ids)
    seed_mask: np.ndarray        # (P_seed,) bool
    input_ids: np.ndarray        # == blocks[0].src_ids (hop-L nodes, all local)
    # remote cache rows touched at each layer l (1..L-1): used by the
    # dynamic-pull runtime (§4.3) and the cost model.
    remote_slots_used: list[np.ndarray]


def _pad_to(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: len(x)] = x
    return out


def _round_up(n: int, m: int = 128) -> int:
    return max(m, ((n + m - 1) // m) * m)


class NeighborSampler:
    """Uniform fanout sampler over a :class:`ClientShard`."""

    def __init__(
        self,
        shard: ClientShard,
        fanout: int,
        num_layers: int,
        batch_size: int,
        *,
        seed: int = 0,
    ):
        self.shard = shard
        self.fanout = fanout
        self.L = num_layers
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed + 7919 * shard.client_id)
        n_total = len(shard.global_ids)
        # Static pads per hop: B*(f+1)^h capped by shard size.
        self._p_nodes = [
            _round_up(min(batch_size * (fanout + 1) ** h, n_total))
            for h in range(num_layers + 1)
        ]
        self._p_edges = [
            _round_up(min(batch_size * (fanout + 1) ** h, n_total) * fanout)
            for h in range(num_layers)
        ]
        self._train = shard.train_vertices()

    # -- sampling --------------------------------------------------------

    def _sample_neighbors(self, frontier: np.ndarray, local_only: bool):
        """Sample ≤fanout in-neighbours for each LOCAL node in frontier.

        Returns (edge_src_ids, edge_dst_ids) in shard-local node ids.
        Remote frontier nodes are skipped (rule 2)."""
        sh = self.shard
        srcs, dsts = [], []
        for u in frontier:
            if u >= sh.num_local:      # remote: path terminates
                continue
            nbrs = sh.indices[sh.indptr[u]: sh.indptr[u + 1]]
            if local_only:
                nbrs = nbrs[nbrs < sh.num_local]
            if len(nbrs) == 0:
                continue
            if len(nbrs) > self.fanout:
                nbrs = self.rng.choice(nbrs, size=self.fanout, replace=False)
            srcs.append(nbrs.astype(np.int64))
            dsts.append(np.full(len(nbrs), u, dtype=np.int64))
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(srcs), np.concatenate(dsts)

    def sample_batch(self, seeds: np.ndarray) -> MiniBatch:
        sh, L = self.shard, self.L
        layers: list[np.ndarray] = [np.asarray(seeds, dtype=np.int64)]
        layer_edges: list[tuple[np.ndarray, np.ndarray]] = []
        for hop in range(1, L + 1):
            cur = layers[-1]
            e_src, e_dst = self._sample_neighbors(cur, local_only=(hop == L))
            new = np.setdiff1d(np.unique(e_src), cur)
            layers.append(np.concatenate([cur, new]))   # dst-prefix ordering
            layer_edges.append((e_src, e_dst))

        blocks: list[Block] = []
        remote_used: list[np.ndarray] = []
        # GNN layer l (1-indexed) consumes node set layers[L-l+1], produces
        # layers[L-l]; edges are layer_edges[L-l].
        for l in range(1, L + 1):
            src_nodes = layers[L - l + 1]
            dst_nodes = layers[L - l]
            e_src, e_dst = layer_edges[L - l]
            pos = {int(u): i for i, u in enumerate(src_nodes)}
            es = np.fromiter((pos[int(u)] for u in e_src), dtype=np.int64,
                             count=len(e_src))
            ed = np.fromiter((pos[int(u)] for u in e_dst), dtype=np.int64,
                             count=len(e_dst))
            p_src = self._p_nodes[L - l + 1]
            p_dst = self._p_nodes[L - l]
            p_e = self._p_edges[L - l]
            remote = dst_nodes >= sh.num_local
            slot = np.where(remote, dst_nodes - sh.num_local, 0)
            blocks.append(Block(
                src_ids=_pad_to(src_nodes, p_src),
                n_src=len(src_nodes),
                n_dst=len(dst_nodes),
                edge_src=_pad_to(es, p_e),
                edge_dst=_pad_to(ed, p_e),
                edge_mask=_pad_to(np.ones(len(es), bool), p_e, False),
                dst_remote_mask=_pad_to(remote, p_dst, False),
                dst_remote_slot=_pad_to(slot.astype(np.int32), p_dst),
                dst_mask=_pad_to(np.ones(len(dst_nodes), bool), p_dst, False),
            ))
            if l < L:   # layer l output = h^l; remote rows read cache[l]
                remote_used.append(np.unique(slot[remote]).astype(np.int64))

        p_seed = self._p_nodes[0]
        # Rule 3: h^0 (features) are never aggregated for remote vertices —
        # the first block's edge sources must all be local.  (The cumulative
        # src node set MAY contain remote nodes from earlier hops; their
        # feature rows are never read as edge sources and their outputs are
        # overwritten from the embedding cache.)
        b0 = blocks[0]
        src_of_edges = b0.src_ids[b0.edge_src[b0.edge_mask]]
        assert np.all(src_of_edges < sh.num_local)
        return MiniBatch(
            blocks=blocks,
            seeds=_pad_to(layers[0], p_seed),
            seed_mask=_pad_to(np.ones(len(layers[0]), bool), p_seed, False),
            input_ids=blocks[0].src_ids,
            remote_slots_used=remote_used,
        )

    def epoch(self, *, shuffle: bool = True) -> Iterator[MiniBatch]:
        order = self._train.copy()
        if shuffle:
            self.rng.shuffle(order)
        for i in range(0, len(order), self.batch_size):
            yield self.sample_batch(order[i: i + self.batch_size])

    def num_batches(self) -> int:
        return (len(self._train) + self.batch_size - 1) // self.batch_size
