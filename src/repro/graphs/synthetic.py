"""Synthetic graph generators calibrated to the paper's Table 1.

The container is offline, so OGB downloads are unavailable.  We generate
degree-corrected stochastic-block-model (DC-SBM) graphs whose *relative*
statistics mirror the four evaluation graphs (density ordering, class
counts, train fraction), scaled down to a CPU budget.  Labels are the SBM
blocks and features are noisy label projections, so that neighbourhood
aggregation — including across partition boundaries — carries real signal:
this is the property that makes the paper's D-vs-E accuracy gap
reproducible (§5.3, Fig. 6).
"""

from __future__ import annotations

import numpy as np

from .graph import Graph, from_edges

# name: (vertices, avg_degree, classes, feat_dim, train_frac, homophily,
#        feature_noise).  Degree ordering mirrors Table 1:
# reddit ≫ products > papers > arxiv.  feature_noise is calibrated so the
# paper's D-vs-E accuracy ordering reproduces (dense graphs depend on
# cross-client neighbourhoods; see EXPERIMENTS.md §Repro).
PRESETS: dict[str, tuple[int, float, int, int, float, float, float]] = {
    "arxiv": (6_000, 7.0, 40, 64, 0.54, 0.82, 1.5),
    "reddit": (4_000, 120.0, 41, 96, 0.66, 0.90, 3.0),
    "products": (10_000, 50.0, 47, 64, 0.08, 0.85, 2.0),
    "papers": (20_000, 14.0, 64, 64, 0.011, 0.80, 2.0),
}


def make_graph(
    name: str,
    *,
    seed: int = 0,
    scale: float = 1.0,
    feature_noise: float | None = None,
) -> Graph:
    """Generate a DC-SBM graph for one of the presets (or a custom tuple).

    ``scale`` multiplies the vertex count (degree is preserved) so tests
    can run tiny instances of the same family.
    """
    if name not in PRESETS:
        raise KeyError(f"unknown synthetic graph {name!r}; options {list(PRESETS)}")
    n_v, avg_deg, n_cls, feat_dim, train_frac, homophily, preset_noise = \
        PRESETS[name]
    if feature_noise is None:
        feature_noise = preset_noise
    n_v = max(4 * n_cls, int(n_v * scale))
    rng = np.random.default_rng(seed)

    labels = rng.integers(0, n_cls, size=n_v).astype(np.int32)
    # Degree correction: lognormal weights give a heavy-ish tail like
    # real social/citation graphs.
    theta = rng.lognormal(mean=0.0, sigma=0.9, size=n_v)
    theta /= theta.mean()

    n_e = int(n_v * avg_deg / 2)  # undirected edge count before symmetrize
    # Sample endpoints proportional to theta; route `homophily` fraction
    # within the same block.
    p = theta / theta.sum()
    src = rng.choice(n_v, size=n_e, p=p)
    same = rng.random(n_e) < homophily
    dst = np.empty(n_e, dtype=np.int64)
    # Cross-block edges: uniform theta-weighted endpoint.
    dst[~same] = rng.choice(n_v, size=int((~same).sum()), p=p)
    # Same-block edges: pick theta-weighted endpoint within src's block.
    order = np.argsort(labels, kind="stable")
    block_start = np.searchsorted(labels[order], np.arange(n_cls))
    block_end = np.searchsorted(labels[order], np.arange(n_cls), side="right")
    for c in np.unique(labels[src[same]]):
        members = order[block_start[c]: block_end[c]]
        pc = theta[members] / theta[members].sum()
        sel = same & (labels[src] == c)
        dst[sel] = rng.choice(members, size=int(sel.sum()), p=pc)

    # Features: one-hot label signal projected to feat_dim + Gaussian noise.
    proj = rng.standard_normal((n_cls, feat_dim)).astype(np.float32)
    feats = proj[labels] + feature_noise * rng.standard_normal(
        (n_v, feat_dim)).astype(np.float32)

    train_mask = rng.random(n_v) < train_frac
    train_mask[: n_cls] = True  # every class has at least one train vertex

    g = from_edges(
        n_v, src, dst, symmetric=True, dedup=True,
        features=feats.astype(np.float32), labels=labels,
        train_mask=train_mask, num_classes=n_cls, name=name,
    )
    g.validate()
    return g
