"""Functional optimizers (optax-style, no external deps).

An :class:`Optimizer` is a pair of pure functions

    state  = opt.init(params)
    params, state = opt.step(params, grads, state)

so it jits and shards transparently under pjit.  ``adafactor`` factors the
second moment of matrices (rows+cols instead of full), which is what makes
the 340B-parameter dry-run configuration fit HBM (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Any]
    step: Callable[[Params, Params, Any], tuple[Params, Any]]


# -- SGD (+momentum) ---------------------------------------------------------

def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def step(params, grads, state):
        if momentum == 0.0:
            new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
            return new, state
        vel = jax.tree_util.tree_map(lambda v, g: momentum * v + g, state, grads)
        new = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, vel)
        return new, vel

    return Optimizer(f"sgd(lr={lr})", init, step)


# -- Adam / AdamW -------------------------------------------------------------

class AdamState(NamedTuple):
    mu: Params
    nu: Params
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamState(jax.tree_util.tree_map(z, params),
                         jax.tree_util.tree_map(z, params),
                         jnp.zeros((), jnp.int32))

    def step(params, grads, state):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(u.dtype)
            return (p - lr * u.astype(p.dtype)).astype(p.dtype)

        new = jax.tree_util.tree_map(upd, params, mu, nu)
        return new, AdamState(mu, nu, count)

    tag = "adamw" if weight_decay else "adam"
    return Optimizer(f"{tag}(lr={lr})", init, step)


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


# -- Adafactor (factored second moment) ----------------------------------------

class AdafactorState(NamedTuple):
    vr: Params    # row stats for matrices, full for vectors
    vc: Params    # col stats for matrices, () for vectors
    count: jax.Array


def adafactor(lr: float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Simplified Adafactor: factored v for rank≥2 leaves (last two dims),
    full v otherwise.  O(rows+cols) state for the big weight matrices."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
                else jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _factored(p) else jnp.zeros((), jnp.float32)

        return AdafactorState(jax.tree_util.tree_map(vr, params),
                              jax.tree_util.tree_map(vc, params),
                              jnp.zeros((), jnp.int32))

    def step(params, grads, state):
        count = state.count + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd_core(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                nvr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                nvc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = nvr.mean(axis=-1, keepdims=True)
                r = (nvr / jnp.maximum(denom, eps))[..., None]
                c = nvc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(r * c, eps))
            else:
                nvr = beta * vr + (1 - beta) * g2
                nvc = vc
                u = g * jax.lax.rsqrt(jnp.maximum(nvr, eps))
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p - lr * u.astype(p.dtype)).astype(p.dtype), nvr, nvc

        def upd(p, g, vr, vc):
            # layer-stacked leaves (leading scan dim) update one layer at a
            # time: bounds the f32 transients to 1/L of the leaf instead of
            # materialising (L, ...) f32 copies of 340B-class weights.
            if p.ndim >= 3:
                return jax.lax.map(lambda t: upd_core(*t), (p, g, vr, vc))
            return upd_core(p, g, vr, vc)

        flat_p, tree = jax.tree_util.tree_flatten(params)
        flat_g = tree.flatten_up_to(grads)
        flat_vr = tree.flatten_up_to(state.vr)
        flat_vc = tree.flatten_up_to(state.vc)
        outs = [upd(p, g, vr, vc) for p, g, vr, vc
                in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_p = tree.unflatten([o[0] for o in outs])
        new_vr = tree.unflatten([o[1] for o in outs])
        new_vc = tree.unflatten([o[2] for o in outs])
        return new_p, AdafactorState(new_vr, new_vc, count)

    return Optimizer(f"adafactor(lr={lr})", init, step)
