from .optimizer import Optimizer, adafactor, adam, adamw, sgd

__all__ = ["Optimizer", "sgd", "adam", "adamw", "adafactor"]
