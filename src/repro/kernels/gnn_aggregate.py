"""Pallas TPU kernel: ELL neighbour mean-aggregation.

The per-minibatch forward hot spot of federated GNN training (§3.2.2)
is gather(neighbour embeddings) → segment-mean.  TPU adaptation (see
DESIGN.md): the sampled computation graphs are mini-batch sized, so the
*whole* source embedding table of a block fits VMEM (≤ a few thousand
rows × 32–256 features).  We therefore tile over destinations and
feature columns, keep `src_feats` resident in VMEM, and do the gather +
masked mean per (dst_tile, feat_tile) block — the irregular access stays
on-chip, HBM traffic is one linear read of the table + one linear write
of the output.

Layout: adjacency in ELL format (N_dst, K) — fixed fanout K matches the
paper's sampler (fanout 5), so ELL padding is tiny.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DST_TILE = 128
FEAT_TILE = 128


def _kernel(src_ref, idx_ref, mask_ref, out_ref):
    """One (dst_tile, feat_tile) block.

    src_ref:  (N_src, FEAT_TILE) — the feature column-slab, whole table
    idx_ref:  (DST_TILE, K)
    mask_ref: (DST_TILE, K)
    out_ref:  (DST_TILE, FEAT_TILE)
    """
    idx = idx_ref[...]                                   # (D, K)
    mask = mask_ref[...]
    feats = src_ref[...]                                 # (N_src, Ft)
    gathered = jnp.take(feats, idx.reshape(-1), axis=0)  # (D*K, Ft) VMEM gather
    gathered = gathered.reshape(idx.shape[0], idx.shape[1], -1)
    w = mask.astype(feats.dtype)[..., None]
    s = (gathered * w).sum(axis=1)
    cnt = mask.sum(axis=1).astype(feats.dtype)
    out_ref[...] = s / jnp.maximum(cnt, 1.0)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gnn_aggregate(src_feats: jax.Array, ell_idx: jax.Array,
                  ell_mask: jax.Array, *, interpret: bool = True
                  ) -> jax.Array:
    """ELL mean-aggregation.  Shapes as in ref.gnn_aggregate.

    Pads N_dst to DST_TILE and F to FEAT_TILE; N_src stays whole (VMEM
    resident — mini-batch scale by construction)."""
    n_dst, k = ell_idx.shape
    n_src, f = src_feats.shape
    pd = -n_dst % DST_TILE
    pf = -f % FEAT_TILE
    if pd:
        ell_idx = jnp.pad(ell_idx, [(0, pd), (0, 0)])
        ell_mask = jnp.pad(ell_mask, [(0, pd), (0, 0)])
    if pf:
        src_feats = jnp.pad(src_feats, [(0, 0), (0, pf)])
    D, F = n_dst + pd, f + pf

    grid = (D // DST_TILE, F // FEAT_TILE)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src, FEAT_TILE), lambda i, j: (0, j)),
            pl.BlockSpec((DST_TILE, k), lambda i, j: (i, 0)),
            pl.BlockSpec((DST_TILE, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((DST_TILE, FEAT_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((D, F), src_feats.dtype),
        interpret=interpret,
    )(src_feats, ell_idx, ell_mask)
    return out[:n_dst, :f]


def _dequant_kernel(v_ref, s_ref, idx_ref, mask_ref, out_ref):
    """One (dst_tile, feat_tile) block, int8 source table.

    v_ref:    (N_src, FEAT_TILE) int8 — quantized feature column-slab
    s_ref:    (N_src, 1) fp32 — per-row scales, whole column
    idx_ref:  (DST_TILE, K); mask_ref: (DST_TILE, K)
    out_ref:  (DST_TILE, FEAT_TILE) fp32

    The dequantize (int8 × per-row scale, exact in fp32) fuses into the
    VMEM gather, so the fp32 source table never materializes: HBM reads
    are the int8 slab + one fp32 scale per row — a 4× cut on the
    dominant stream of the aggregation."""
    idx = idx_ref[...]
    mask = mask_ref[...]
    flat = idx.reshape(-1)
    q = jnp.take(v_ref[...], flat, axis=0)               # (D*K, Ft) int8
    sc = jnp.take(s_ref[...], flat, axis=0)              # (D*K, 1) fp32
    gathered = (q.astype(jnp.float32) * sc).reshape(
        idx.shape[0], idx.shape[1], -1)
    w = mask.astype(jnp.float32)[..., None]
    s = (gathered * w).sum(axis=1)
    cnt = mask.sum(axis=1).astype(jnp.float32)
    out_ref[...] = s / jnp.maximum(cnt, 1.0)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_aggregate(src_values: jax.Array, src_scales: jax.Array,
                      ell_idx: jax.Array, ell_mask: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """ELL mean-aggregation over an int8-quantized source table.

    src_values: (N_src, F) int8; src_scales: (N_src, 1) fp32 (the wire
    form of a pulled block — see repro.kernels.quantize); ell_idx /
    ell_mask as in :func:`gnn_aggregate`.  Returns (N_dst, F) fp32,
    bit-identical to ``gnn_aggregate(dequantize_int8(values, scales),
    idx, mask)`` — the per-element int8×scale product is exact in fp32
    and the reduction order matches, so pulled int8 rows can feed the
    GNN layer without ever materializing the fp32 table on the host."""
    n_dst, k = ell_idx.shape
    n_src, f = src_values.shape
    pd = -n_dst % DST_TILE
    pf = -f % FEAT_TILE
    if pd:
        ell_idx = jnp.pad(ell_idx, [(0, pd), (0, 0)])
        ell_mask = jnp.pad(ell_mask, [(0, pd), (0, 0)])
    if pf:
        src_values = jnp.pad(src_values, [(0, 0), (0, pf)])
    D, F = n_dst + pd, f + pf

    grid = (D // DST_TILE, F // FEAT_TILE)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_src, FEAT_TILE), lambda i, j: (0, j)),
            pl.BlockSpec((n_src, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((DST_TILE, k), lambda i, j: (i, 0)),
            pl.BlockSpec((DST_TILE, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((DST_TILE, FEAT_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((D, F), jnp.float32),
        interpret=interpret,
    )(src_values, src_scales, ell_idx, ell_mask)
    return out[:n_dst, :f]
