"""Pallas TPU kernels: per-row symmetric int8 quantize / dequantize.

The exchange subsystem's int8 wire codec (repro.exchange.codec) makes
encode/decode a per-round compute hot path: every push and pull of the
embedding tables quantizes (n, hidden) fp32 rows to int8 plus one fp32
scale per row.  At TPU scale (Papers: ~40M boundary rows × 128 features
per round) that is a pure bandwidth-bound streaming kernel, so we tile
over rows, keep the full (padded) feature width per block, and fuse
absmax → scale → round/clip in VMEM — one linear read of the table, one
linear write of values + scales, no HBM round-trips for the reduction.

Scheme (row-independent by construction — this is what keeps sharded
transports bit-identical to single-shard ones):

  scale_i = max_j |x_ij| / 127          (0 for all-zero rows)
  q_ij    = clip(round(x_ij / scale_i), -127, 127)   int8
  x'_ij   = q_ij * scale_i

Round-to-nearest (ties-to-even, matching jnp.round in the oracle) keeps
the kernel deterministic, so encode(decode(encode(x))) is stable and
Pallas-vs-ref parity is exact, not approximate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROW_TILE = 256
LANE = 128


def _quantize_kernel(x_ref, v_ref, s_ref):
    """One (ROW_TILE, H_padded) block: fused absmax + scale + round/clip.

    x_ref: (R, H) fp32; v_ref: (R, H) int8; s_ref: (R, 1) fp32."""
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)        # (R, 1)
    # multiply by the fp32 reciprocal (not a divide): XLA folds /127 into
    # a reciprocal-mul under jit but not in the eager oracle — writing the
    # mul explicitly keeps kernel and oracle bit-identical.
    scale = absmax * jnp.float32(1.0 / 127.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    v_ref[...] = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    s_ref[...] = scale


def _dequantize_kernel(v_ref, s_ref, out_ref):
    """out = values × per-row scale (zero-scale rows stay exactly zero)."""
    out_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _quantize_padded(xp: jax.Array, *, interpret: bool):
    """Pallas call over ROW_TILE/LANE-aligned input."""
    R, H = xp.shape
    return pl.pallas_call(
        _quantize_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((R, H), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)),
        interpret=interpret,
    )(xp)


def quantize_int8(x: jax.Array, *, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization.

    x: (n, hidden) fp32.  Returns (values (n, hidden) int8,
    scales (n, 1) fp32).  Rows pad to ROW_TILE, features to the 128-lane
    boundary; zero padding cannot raise a row's absmax, so padded results
    slice back exactly.  Padding happens OUTSIDE the jit boundary so
    delta-filtered pushes (a different n every round) retrace only once
    per ROW_TILE bucket, not once per row count."""
    n, h = x.shape
    if n == 0:  # zero-row grid is illegal in pallas_call; nothing to do
        return (jnp.zeros((0, h), jnp.int8), jnp.zeros((0, 1), jnp.float32))
    # pad/slice on the host: a fresh n then costs data movement only,
    # never a new XLA compile (eager pad/slice compile per exact shape)
    xp = np.zeros((n + (-n % ROW_TILE), h + (-h % LANE)), np.float32)
    xp[:n, :h] = np.asarray(x, np.float32)
    values, scales = _quantize_padded(jnp.asarray(xp), interpret=interpret)
    return (jnp.asarray(np.asarray(values)[:n, :h]),
            jnp.asarray(np.asarray(scales)[:n]))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dequantize_padded(vp: jax.Array, sp: jax.Array, *, interpret: bool):
    R, H = vp.shape
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, H), jnp.float32),
        interpret=interpret,
    )(vp, sp)


def dequantize_int8(values: jax.Array, scales: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """Inverse of :func:`quantize_int8`: (n, hidden) int8 × (n, 1) fp32
    scales → (n, hidden) fp32.  Same bucketed-padding contract."""
    n, h = values.shape
    if n == 0:
        return jnp.zeros((0, h), jnp.float32)
    R, H = n + (-n % ROW_TILE), h + (-h % LANE)
    vp = np.zeros((R, H), np.int8)
    vp[:n, :h] = np.asarray(values)
    sp = np.zeros((R, 1), np.float32)
    sp[:n] = np.asarray(scales, np.float32)
    out = _dequantize_padded(jnp.asarray(vp), jnp.asarray(sp),
                             interpret=interpret)
    return jnp.asarray(np.asarray(out)[:n, :h])
