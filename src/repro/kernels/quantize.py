"""Pallas TPU kernels: per-row symmetric int8 quantize / dequantize.

The exchange subsystem's int8 wire codec (repro.exchange.codec) makes
encode/decode a per-round compute hot path: every push and pull of the
embedding tables quantizes (n, hidden) fp32 rows to int8 plus one fp32
scale per row.  At TPU scale (Papers: ~40M boundary rows × 128 features
per round) that is a pure bandwidth-bound streaming kernel, so we tile
over rows, keep the full (padded) feature width per block, and fuse
absmax → scale → round/clip in VMEM — one linear read of the table, one
linear write of values + scales, no HBM round-trips for the reduction.

Scheme (row-independent by construction — this is what keeps sharded
transports bit-identical to single-shard ones):

  scale_i = max_j |x_ij| / 127          (0 for all-zero rows)
  q_ij    = clip(round(x_ij / scale_i), -127, 127)   int8
  x'_ij   = q_ij * scale_i

Round-to-nearest (ties-to-even, matching jnp.round in the oracle) keeps
the kernel deterministic, so encode(decode(encode(x))) is stable and
Pallas-vs-ref parity is exact, not approximate.

Bucketed padding
----------------
Delta-filtered pushes hand this module a different row count every
round.  Rows therefore pad to a small static set of power-of-two
buckets (``ROW_BUCKETS``, multiples of cap above it), not to the exact
ROW_TILE multiple: the quantize/dequantize programs are keyed on the
*bucket* shape, so an arbitrary stream of row counts compiles at most
``len(row_buckets(...))`` distinct programs per hidden width — the
bound ``tests/test_kernels.py`` pins with a compile counter.

Where the pad runs depends on where the data lives:

  * numpy input — the rows are host-resident (a socket payload, a
    trainer batch), so the bucket pad is one host copy into the pinned
    staging buffer that the host→device transfer needs anyway.
  * jax.Array input — the rows never leave the device: a jitted
    ``jnp`` scatter (:func:`pad_rows`) pads in-place-shape, and the
    bucket-keyed program runs on the result.  The pad itself is a
    trivial per-shape copy program; the fused quantize program stays
    bucket-keyed.

Zero padding cannot raise a row's absmax, so padded results slice back
exactly — all-zero pad rows quantize to (0, scale 0) and never leak.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROW_TILE = 256
LANE = 128
#: largest power-of-two row bucket; row counts beyond it round up to a
#: multiple of the cap (one extra program per cap multiple, amortized).
BUCKET_CAP = 16384


def row_buckets(cap: int = BUCKET_CAP) -> tuple[int, ...]:
    """The static bucket ladder: ROW_TILE, then doublings up to ``cap``."""
    out, b = [], ROW_TILE
    while b <= cap:
        out.append(b)
        b *= 2
    return tuple(out)


def bucket_rows(n: int) -> int:
    """Smallest bucket holding ``n`` rows (cap multiples past the cap)."""
    if n <= 0:
        return ROW_TILE
    if n > BUCKET_CAP:
        return n + (-n % BUCKET_CAP)
    b = ROW_TILE
    while b < n:
        b *= 2
    return b


def pad_hidden(h: int) -> int:
    """Feature width padded to the 128-lane boundary."""
    return h + (-h % LANE)


@functools.partial(jax.jit, static_argnames=("bucket", "hp"))
def _pad_rows_dev(x: jax.Array, *, bucket: int, hp: int) -> jax.Array:
    """Device-side bucket pad: zeros(bucket, hp) with x scattered in.
    A per-(n, h) copy program — cheap glue; the fused kernels it feeds
    stay keyed on (bucket, hp)."""
    n, h = x.shape
    return jnp.zeros((bucket, hp), x.dtype).at[:n, :h].set(x)


def pad_rows(x, *, dtype=None, width: int | None = None
             ) -> tuple[jax.Array, int, int]:
    """Bucket-pad an (n, h) block → (padded (B, Hp) device array, n, h).

    ``width`` overrides the padded feature width (default: ``h``
    rounded to the 128-lane boundary; scale columns pass ``width=1``).

    numpy input pads on the host (the rows must cross host→device
    anyway — one staging copy, zero extra round-trips); device input
    pads in-jit and never touches the host."""
    n, h = x.shape
    B = bucket_rows(n)
    Hp = pad_hidden(h) if width is None else width
    if isinstance(x, np.ndarray):
        dt = np.dtype(dtype or x.dtype)
        xp = np.zeros((B, Hp), dt)
        xp[:n, :h] = x
        return jnp.asarray(xp), n, h
    xd = x if dtype is None else x.astype(dtype)
    if xd.shape == (B, Hp):
        return xd, n, h
    return _pad_rows_dev(xd, bucket=B, hp=Hp), n, h


def _quantize_kernel(x_ref, v_ref, s_ref):
    """One (ROW_TILE, H_padded) block: fused absmax + scale + round/clip.

    x_ref: (R, H) fp32; v_ref: (R, H) int8; s_ref: (R, 1) fp32."""
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)        # (R, 1)
    # multiply by the fp32 reciprocal (not a divide): XLA folds /127 into
    # a reciprocal-mul under jit but not in the eager oracle — writing the
    # mul explicitly keeps kernel and oracle bit-identical.
    scale = absmax * jnp.float32(1.0 / 127.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    v_ref[...] = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    s_ref[...] = scale


def _dequantize_kernel(v_ref, s_ref, out_ref):
    """out = values × per-row scale (zero-scale rows stay exactly zero)."""
    out_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_padded(xp: jax.Array, *, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """Pallas call over a bucket-aligned (B, Hp) block → (values int8
    (B, Hp), scales fp32 (B, 1)), both still bucket-shaped.  This is the
    program the compile-count bound covers: one compile per (bucket,
    Hp), never per row count."""
    R, H = xp.shape
    return pl.pallas_call(
        _quantize_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((R, H), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)),
        interpret=interpret,
    )(xp)


def quantize_int8(x: jax.Array, *, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization.

    x: (n, hidden) fp32.  Returns (values (n, hidden) int8,
    scales (n, 1) fp32).  Input bucket-pads per the module contract
    (host copy for numpy, in-jit scatter for device arrays); the Pallas
    program compiles once per bucket, not once per row count."""
    n, h = x.shape
    if n == 0:  # zero-row grid is illegal in pallas_call; nothing to do
        return (jnp.zeros((0, h), jnp.int8), jnp.zeros((0, 1), jnp.float32))
    if isinstance(x, np.ndarray):
        xp, _, _ = pad_rows(x, dtype=np.float32)
        values, scales = quantize_padded(xp, interpret=interpret)
        return (jnp.asarray(np.asarray(values)[:n, :h]),
                jnp.asarray(np.asarray(scales)[:n]))
    xp, _, _ = pad_rows(x.astype(jnp.float32))
    values, scales = quantize_padded(xp, interpret=interpret)
    return values[:n, :h], scales[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_padded(vp: jax.Array, sp: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """Pallas call over bucket-aligned int8 values + scales → fp32,
    bucket-shaped.  Same compile-count contract as
    :func:`quantize_padded`."""
    R, H = vp.shape
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, H), jnp.float32),
        interpret=interpret,
    )(vp, sp)


def dequantize_int8(values: jax.Array, scales: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """Inverse of :func:`quantize_int8`: (n, hidden) int8 × (n, 1) fp32
    scales → (n, hidden) fp32.  Same bucketed-padding contract."""
    n, h = values.shape
    if n == 0:
        return jnp.zeros((0, h), jnp.float32)
    if isinstance(values, np.ndarray):
        vp, _, _ = pad_rows(values, dtype=np.int8)
        sp, _, _ = pad_rows(np.asarray(scales, np.float32), width=1)
        out = dequantize_padded(vp, sp, interpret=interpret)
        return jnp.asarray(np.asarray(out)[:n, :h])
    vp, _, _ = pad_rows(values)
    sp, _, _ = pad_rows(scales.astype(jnp.float32), width=1)
    out = dequantize_padded(vp, sp, interpret=interpret)
    return out[:n, :h]


# -- kernel-compile telemetry -------------------------------------------------
# jax.jit re-traces per distinct bucket shape; the bucketed-padding
# contract (tests/test_kernels.py) bounds these at O(log rows) per
# kernel.  Exposed as fn-backed gauges so an OP_METRICS scrape shows
# live compile-cache sizes without importing jax internals anywhere
# else.
from repro.obsv.metrics import REGISTRY as _REGISTRY  # noqa: E402

_REGISTRY.gauge("kernels.quantize_padded.compiles",
                fn=quantize_padded._cache_size)
_REGISTRY.gauge("kernels.dequantize_padded.compiles",
                fn=dequantize_padded._cache_size)
