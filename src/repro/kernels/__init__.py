"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships three parts:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ref.py    — pure-jnp oracles
  ops.py    — jit'd dispatchers (use_pallas flag; interpret=True on CPU)

Kernels:
  gnn_aggregate — ELL-format neighbour mean-aggregation (the forward-pass
                  hot spot of every mini-batch, §3.2.2)
  swa_attention — sliding-window decode attention (long_500k serve path)
  topk_mask     — sort-free top-k selection for frequency-score pruning /
                  prefetch (§4.1.2, §4.3) at TPU scale
  quantize      — per-row symmetric int8 quantize/dequantize for the
                  remote-embedding wire codecs (repro.exchange.codec)
"""
