"""Pallas TPU kernels: fused exchange-plane ops (gather+quantize,
dequantize+scatter).

The exchange hot path moves (rows → wire → rows) through three steps
that the numpy plane runs as separate passes with host staging between
them: gather rows out of the server table, int8-encode them (pull
responses), and decode+store pushed rows back into the table.  These
kernels fuse each pair so the table never leaves the device and the
intermediate fp32 block never exists in HBM:

  gather_quantize    — row-index gather from a device-resident
      (R, H) table fused with the per-row symmetric int8 encode of
      :mod:`repro.kernels.quantize`; one linear read of the touched
      rows, int8 values + fp32 scales written directly.
  dequant_scatter    — int8 decode fused with a scatter-write (push
      apply) or scatter-accumulate into the table, in place via
      ``input_output_aliases`` so the table is updated without a copy.

Both kernels share the bucketed-padding contract of
:mod:`repro.kernels.quantize`: row counts pad to the static power-of-two
bucket ladder, so a stream of delta-sized pushes compiles a bounded
number of programs.  Row *indices* pad with an out-of-range sentinel
(== R) and scatter in ``mode='drop'`` — a padded lane can never touch a
real row, which is what keeps the padded path bit-identical to the
unpadded oracle.

Quantization math is copied op-for-op from ``quantize._quantize_kernel``
(reciprocal-mul, round-ties-to-even, clip) so
``gather_quantize(table, rows) == quantize_int8(table[rows])`` holds
bit-exactly — the row-independent codec property the sharded transports
rely on survives the fusion.

Scatter semantics: valid ``rows`` must be unique for ``accumulate=False``
(a push's row set is — gids are unique per RPC); duplicates are allowed
for ``accumulate=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .quantize import ROW_TILE, bucket_rows, pad_hidden, pad_rows


def _pad_idx(rows, n: int, sentinel: int) -> jax.Array:
    """Bucket-pad a row-index vector to (B, 1) int32, padding with
    ``sentinel`` (callers pass the table row count R: out-of-range, so
    ``mode='drop'`` scatters and clamped gathers can never alias a real
    row... gathers use 0 instead — see call sites)."""
    B = bucket_rows(n)
    if isinstance(rows, np.ndarray) or not isinstance(rows, jax.Array):
        idx = np.full((B, 1), sentinel, np.int32)
        idx[:n, 0] = np.asarray(rows, np.int32)
        return jnp.asarray(idx)
    return jnp.full((B, 1), sentinel, jnp.int32).at[:n, 0].set(
        rows.astype(jnp.int32))


# -- gather + quantize --------------------------------------------------------

def _quantize_math(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The shared per-row symmetric int8 encode — op-for-op the math of
    ``quantize._quantize_kernel``, used by the Pallas body and the
    jitted jnp fallback so both stay bit-identical to the oracle."""
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax * jnp.float32(1.0 / 127.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    v = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    return v, scale


def _gather_quantize_kernel(tbl_ref, idx_ref, v_ref, s_ref):
    """One (ROW_TILE, Hp) output block: table gather fused with the
    per-row symmetric int8 encode.

    tbl_ref: (R, Hp) fp32 (whole table, VMEM-resident);
    idx_ref: (T, 1) int32; v_ref: (T, Hp) int8; s_ref: (T, 1) fp32."""
    idx = idx_ref[...][:, 0]
    # padded lanes carry index 0 (clamped): they quantize row 0 and are
    # sliced away by the caller — never scattered anywhere.
    x = jnp.take(tbl_ref[...], idx, axis=0)
    v, scale = _quantize_math(x)
    v_ref[...] = v
    s_ref[...] = scale


@jax.jit
def _gather_quantize_padded_jnp(table: jax.Array, idx: jax.Array
                                ) -> tuple[jax.Array, jax.Array]:
    """Jitted jnp twin of the Pallas program: same bucket-padded shapes,
    same math — the fused device path off-TPU (ops dispatch)."""
    return _quantize_math(jnp.take(table, idx[:, 0], axis=0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gather_quantize_padded(table: jax.Array, idx: jax.Array, *,
                            interpret: bool):
    R, H = table.shape
    B = idx.shape[0]
    return pl.pallas_call(
        _gather_quantize_kernel,
        grid=(B // ROW_TILE,),
        in_specs=[pl.BlockSpec((R, H), lambda i: (0, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, H), jnp.int8),
                   jax.ShapeDtypeStruct((B, 1), jnp.float32)),
        interpret=interpret,
    )(table, idx)


def gather_quantize(table: jax.Array, rows, *, interpret: bool = True,
                    via: str = "pallas") -> tuple[jax.Array, jax.Array]:
    """table (R, hidden) fp32 × rows (n,) int → (values (n, hidden) int8,
    scales (n, 1) fp32), bit-identical to ``quantize_int8(table[rows])``.

    The table stays whole (one lane-padded column block — the server's
    device tables are stored pre-aligned, so no per-call copy); rows
    bucket-pad with index 0.  ``via='jnp'`` runs the jitted jnp twin over
    the same padded shapes (the off-TPU device path)."""
    n = len(rows)
    R, h = table.shape
    if n == 0:
        return (jnp.zeros((0, h), jnp.int8), jnp.zeros((0, 1), jnp.float32))
    tbl, _, _ = pad_rows(np.asarray(table, np.float32)
                         if isinstance(table, np.ndarray) else table)
    # pad_rows bucket-pads table rows too — harmless (indices only ever
    # address real rows) and it keeps the program keyed on the table's
    # bucket, not its exact row count.
    idx = _pad_idx(rows, n, sentinel=0)
    if via == "jnp":
        vp, sp = _gather_quantize_padded_jnp(tbl, idx)
    else:
        vp, sp = _gather_quantize_padded(tbl, idx, interpret=interpret)
    return vp[:n, :h], sp[:n]


# -- dequantize + scatter -----------------------------------------------------

def _make_scatter_kernel(accumulate: bool):
    def kernel(_tbl_in_ref, idx_ref, v_ref, s_ref, out_ref):
        """One (T,)-row update tile scattered into the whole aliased
        table block.  Padded lanes carry the sentinel index R and are
        dropped by the scatter."""
        idx = idx_ref[...][:, 0]
        new = v_ref[...].astype(jnp.float32) * s_ref[...]
        tbl = out_ref[...]
        if accumulate:
            out_ref[...] = tbl.at[idx].add(new, mode="drop")
        else:
            out_ref[...] = tbl.at[idx].set(new, mode="drop")
    return kernel


@functools.partial(jax.jit, static_argnames=("accumulate",))
def _dequant_scatter_padded_jnp(table: jax.Array, idx: jax.Array,
                                values: jax.Array, scales: jax.Array, *,
                                accumulate: bool) -> jax.Array:
    """Jitted jnp twin of the Pallas scatter program — same padded
    shapes, same sentinel-drop semantics."""
    new = values.astype(jnp.float32) * scales
    i = idx[:, 0]
    if accumulate:
        return table.at[i].add(new, mode="drop")
    return table.at[i].set(new, mode="drop")


@functools.partial(jax.jit, static_argnames=("accumulate", "interpret"))
def _dequant_scatter_padded(table: jax.Array, idx: jax.Array,
                            values: jax.Array, scales: jax.Array, *,
                            accumulate: bool, interpret: bool) -> jax.Array:
    R, H = table.shape
    B = idx.shape[0]
    return pl.pallas_call(
        _make_scatter_kernel(accumulate),
        grid=(B // ROW_TILE,),
        in_specs=[pl.BlockSpec((R, H), lambda i: (0, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, H), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((R, H), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, H), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(table, idx, values, scales)


def dequant_scatter(table: jax.Array, rows, values, scales, *,
                    accumulate: bool = False, interpret: bool = True,
                    via: str = "pallas") -> jax.Array:
    """Decode int8 rows and scatter them into ``table`` at ``rows``.

    table (R, hidden) fp32; rows (n,) int; values (n, hidden) int8;
    scales (n, 1) fp32.  Returns the updated table as a fresh array
    (``input_output_aliases`` keeps the update in place *inside* the
    program; callers rebind their handle to the result).
    ``accumulate=False`` overwrites rows (push apply; valid rows must be
    unique), ``accumulate=True`` adds into them (partial aggregation).
    Bit-identical to ``table.at[rows].set/add(values * scales)``."""
    n = len(rows)
    R, h = table.shape
    if n == 0:
        return table if isinstance(table, jax.Array) else jnp.asarray(table)
    Hp = pad_hidden(h)
    padded_cols = Hp != h
    if isinstance(table, np.ndarray):
        tbl = np.zeros((R, Hp), np.float32)
        tbl[:, :h] = table
        tbl = jnp.asarray(tbl)
    elif padded_cols:
        tbl = jnp.zeros((R, Hp), jnp.float32).at[:, :h].set(table)
    else:
        tbl = table
    idx = _pad_idx(rows, n, sentinel=R)
    vp, _, _ = pad_rows(values if not isinstance(values, np.ndarray)
                        else np.asarray(values, np.int8))
    sp, _, _ = pad_rows(scales if not isinstance(scales, np.ndarray)
                        else np.asarray(scales, np.float32), width=1)
    if via == "jnp":
        out = _dequant_scatter_padded_jnp(tbl, idx, vp, sp,
                                          accumulate=accumulate)
    else:
        out = _dequant_scatter_padded(tbl, idx, vp, sp,
                                      accumulate=accumulate,
                                      interpret=interpret)
    return out[:, :h] if padded_cols else out
