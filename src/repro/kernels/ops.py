"""Jit'd dispatchers over the Pallas kernels and their jnp oracles.

``use_pallas='auto'`` picks the Pallas path on TPU backends and the pure
jnp oracle elsewhere; tests force ``use_pallas=True`` with interpret mode
to validate the kernel bodies on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import exchange_fused as _fused
from . import gnn_aggregate as _agg
from . import quantize as _quant
from . import ref
from . import swa_attention as _swa
from . import topk_mask as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas) -> tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if use_pallas == "auto":
        return (True, False) if _on_tpu() else (False, True)
    return bool(use_pallas), not _on_tpu()


def gnn_aggregate(src_feats, ell_idx, ell_mask, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _agg.gnn_aggregate(src_feats, ell_idx, ell_mask,
                                  interpret=interp)
    return ref.gnn_aggregate(src_feats, ell_idx, ell_mask)


def swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, *, window,
                         use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _swa.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                         window=window, interpret=interp)
    return ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                    window)


def _np_quantize_int8(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Numpy mirror of ref.quantize_int8, op-for-op (same fp32 ops in
    the same order, round-half-even), so results stay bit-identical."""
    x = np.asarray(x, np.float32)
    absmax = np.max(np.abs(x), axis=1, keepdims=True) \
        if x.size else np.zeros((x.shape[0], 1), np.float32)
    scale = (absmax * np.float32(1.0 / 127.0)).astype(np.float32)
    safe = np.where(scale > 0, scale, np.float32(1.0)).astype(np.float32)
    q = np.clip(np.rint(x / safe), -127.0, 127.0).astype(np.int8)
    return q, scale


def _np_dequantize_int8(values: np.ndarray,
                        scales: np.ndarray) -> np.ndarray:
    return values.astype(np.float32) * scales.astype(np.float32)


def quantize_int8(x, *, use_pallas="auto"):
    """Per-row symmetric int8 quantize → (values int8, scales fp32 (n,1)).

    Host arrays off-TPU take a pure-numpy fast path: the exchange codec
    calls this per push/pull with delta-sized (varying-shape) batches,
    where eager jnp pays ~ms dispatch per call and jit would retrace
    per shape (see ROADMAP: device-resident codec path)."""
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.quantize_int8(x, interpret=interp)
    if isinstance(x, np.ndarray):
        return _np_quantize_int8(x)
    return ref.quantize_int8(x)


def dequantize_int8(values, scales, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.dequantize_int8(values, scales, interpret=interp)
    if isinstance(values, np.ndarray):
        return _np_dequantize_int8(values, np.asarray(scales))
    return ref.dequantize_int8(values, scales)


def _np_gather_quantize(table: np.ndarray, rows
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy fused gather+quantize (host tables): fancy-index then the
    op-for-op numpy encode — bit-identical to the device paths."""
    rows = np.asarray(rows, np.int64)
    return _np_quantize_int8(np.asarray(table, np.float32)[rows])


def _np_dequant_scatter(table: np.ndarray, rows, values, scales, *,
                        accumulate: bool = False) -> np.ndarray:
    """Numpy fused dequant+scatter.  Functional (returns a fresh table)
    to match the device paths — callers rebind."""
    out = np.array(table, np.float32, copy=True)
    rows = np.asarray(rows, np.int64)
    new = np.asarray(values).astype(np.float32) \
        * np.asarray(scales, np.float32)
    if accumulate:
        np.add.at(out, rows, new)
    else:
        out[rows] = new
    return out


def gather_quantize(table, rows, *, use_pallas="auto"):
    """Fused row-gather + int8 encode (pull responses): bit-identical to
    ``quantize_int8(table[rows])``.  Numpy tables take the numpy fused
    path; device tables run the jitted bucket-padded jnp twin off-TPU
    and the Pallas kernel on TPU (interpret mode when forced on CPU)."""
    use, interp = _resolve(use_pallas)
    if use:
        return _fused.gather_quantize(table, rows, interpret=interp)
    if isinstance(table, np.ndarray):
        return _np_gather_quantize(table, rows)
    return _fused.gather_quantize(table, rows, via="jnp")


def dequant_scatter(table, rows, values, scales, *, accumulate=False,
                    use_pallas="auto"):
    """Fused int8 decode + scatter-write/accumulate (push apply).
    Functional: returns the updated table; callers rebind.  Valid rows
    must be unique for ``accumulate=False``."""
    use, interp = _resolve(use_pallas)
    if use:
        return _fused.dequant_scatter(table, rows, values, scales,
                                      accumulate=accumulate,
                                      interpret=interp)
    if isinstance(table, np.ndarray):
        return _np_dequant_scatter(table, rows, values, scales,
                                   accumulate=accumulate)
    return _fused.dequant_scatter(table, rows, values, scales,
                                  accumulate=accumulate, via="jnp")


def dequant_aggregate(src_values, src_scales, ell_idx, ell_mask, *,
                      use_pallas="auto"):
    """ELL mean-aggregation over an int8 source table, bit-identical to
    ``gnn_aggregate(dequantize_int8(values, scales), idx, mask)``.  The
    non-Pallas path routes to the jnp oracle (not a numpy mirror) so the
    reduction order matches :func:`gnn_aggregate`'s dispatch exactly."""
    use, interp = _resolve(use_pallas)
    if use:
        return _agg.dequant_aggregate(src_values, src_scales, ell_idx,
                                      ell_mask, interpret=interp)
    return ref.dequant_aggregate(jnp.asarray(src_values),
                                 jnp.asarray(src_scales),
                                 jnp.asarray(ell_idx),
                                 jnp.asarray(ell_mask))


def topk_mask(scores, k, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _topk.topk_mask(scores, k, interpret=interp)
    return ref.topk_mask(scores, k)


def ell_from_csr(indptr: np.ndarray, indices: np.ndarray, max_deg: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """CSR → ELL (idx, mask), truncating rows past ``max_deg`` (the
    sampler's fanout bound makes truncation a no-op in practice).

    Fully vectorized — a repeat/cumcount construction instead of the
    per-row python loop, which was O(V) interpreter time on the
    minibatch path for store-scale graphs."""
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices)
    n = len(indptr) - 1
    idx = np.zeros((n, max_deg), np.int32)
    mask = np.zeros((n, max_deg), bool)
    deg = np.minimum(np.diff(indptr), max_deg)
    rows = np.repeat(np.arange(n), deg)
    if rows.size:
        # cumcount: position of each kept entry within its row
        col = np.arange(rows.size) - np.repeat(np.cumsum(deg) - deg, deg)
        src = indices[np.repeat(indptr[:-1], deg) + col]
        idx[rows, col] = src
        mask[rows, col] = True
    return idx, mask
