"""Jit'd dispatchers over the Pallas kernels and their jnp oracles.

``use_pallas='auto'`` picks the Pallas path on TPU backends and the pure
jnp oracle elsewhere; tests force ``use_pallas=True`` with interpret mode
to validate the kernel bodies on CPU.
"""

from __future__ import annotations

import jax
import numpy as np

from . import gnn_aggregate as _agg
from . import quantize as _quant
from . import ref
from . import swa_attention as _swa
from . import topk_mask as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas) -> tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if use_pallas == "auto":
        return (True, False) if _on_tpu() else (False, True)
    return bool(use_pallas), not _on_tpu()


def gnn_aggregate(src_feats, ell_idx, ell_mask, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _agg.gnn_aggregate(src_feats, ell_idx, ell_mask,
                                  interpret=interp)
    return ref.gnn_aggregate(src_feats, ell_idx, ell_mask)


def swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, *, window,
                         use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _swa.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                         window=window, interpret=interp)
    return ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                    window)


def _np_quantize_int8(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Numpy mirror of ref.quantize_int8, op-for-op (same fp32 ops in
    the same order, round-half-even), so results stay bit-identical."""
    x = np.asarray(x, np.float32)
    absmax = np.max(np.abs(x), axis=1, keepdims=True) \
        if x.size else np.zeros((x.shape[0], 1), np.float32)
    scale = (absmax * np.float32(1.0 / 127.0)).astype(np.float32)
    safe = np.where(scale > 0, scale, np.float32(1.0)).astype(np.float32)
    q = np.clip(np.rint(x / safe), -127.0, 127.0).astype(np.int8)
    return q, scale


def _np_dequantize_int8(values: np.ndarray,
                        scales: np.ndarray) -> np.ndarray:
    return values.astype(np.float32) * scales.astype(np.float32)


def quantize_int8(x, *, use_pallas="auto"):
    """Per-row symmetric int8 quantize → (values int8, scales fp32 (n,1)).

    Host arrays off-TPU take a pure-numpy fast path: the exchange codec
    calls this per push/pull with delta-sized (varying-shape) batches,
    where eager jnp pays ~ms dispatch per call and jit would retrace
    per shape (see ROADMAP: device-resident codec path)."""
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.quantize_int8(x, interpret=interp)
    if isinstance(x, np.ndarray):
        return _np_quantize_int8(x)
    return ref.quantize_int8(x)


def dequantize_int8(values, scales, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.dequantize_int8(values, scales, interpret=interp)
    if isinstance(values, np.ndarray):
        return _np_dequantize_int8(values, np.asarray(scales))
    return ref.dequantize_int8(values, scales)


def topk_mask(scores, k, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _topk.topk_mask(scores, k, interpret=interp)
    return ref.topk_mask(scores, k)


def ell_from_csr(indptr: np.ndarray, indices: np.ndarray, max_deg: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """CSR → ELL (idx, mask), truncating rows past ``max_deg`` (the
    sampler's fanout bound makes truncation a no-op in practice)."""
    n = len(indptr) - 1
    idx = np.zeros((n, max_deg), np.int32)
    mask = np.zeros((n, max_deg), bool)
    for u in range(n):
        row = indices[indptr[u]: indptr[u + 1]][:max_deg]
        idx[u, : len(row)] = row
        mask[u, : len(row)] = True
    return idx, mask
