"""Jit'd dispatchers over the Pallas kernels and their jnp oracles.

``use_pallas='auto'`` picks the Pallas path on TPU backends and the pure
jnp oracle elsewhere; tests force ``use_pallas=True`` with interpret mode
to validate the kernel bodies on CPU.
"""

from __future__ import annotations

import jax
import numpy as np

from . import gnn_aggregate as _agg
from . import quantize as _quant
from . import ref
from . import swa_attention as _swa
from . import topk_mask as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas) -> tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if use_pallas == "auto":
        return (True, False) if _on_tpu() else (False, True)
    return bool(use_pallas), not _on_tpu()


def gnn_aggregate(src_feats, ell_idx, ell_mask, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _agg.gnn_aggregate(src_feats, ell_idx, ell_mask,
                                  interpret=interp)
    return ref.gnn_aggregate(src_feats, ell_idx, ell_mask)


def swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, *, window,
                         use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _swa.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                         window=window, interpret=interp)
    return ref.swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos,
                                    window)


def quantize_int8(x, *, use_pallas="auto"):
    """Per-row symmetric int8 quantize → (values int8, scales fp32 (n,1))."""
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.quantize_int8(x, interpret=interp)
    return ref.quantize_int8(x)


def dequantize_int8(values, scales, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _quant.dequantize_int8(values, scales, interpret=interp)
    return ref.dequantize_int8(values, scales)


def topk_mask(scores, k, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _topk.topk_mask(scores, k, interpret=interp)
    return ref.topk_mask(scores, k)


def ell_from_csr(indptr: np.ndarray, indices: np.ndarray, max_deg: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """CSR → ELL (idx, mask), truncating rows past ``max_deg`` (the
    sampler's fanout bound makes truncation a no-op in practice)."""
    n = len(indptr) - 1
    idx = np.zeros((n, max_deg), np.int32)
    mask = np.zeros((n, max_deg), bool)
    for u in range(n):
        row = indices[indptr[u]: indptr[u + 1]][:max_deg]
        idx[u, : len(row)] = row
        mask[u, : len(row)] = True
    return idx, mask
