"""Pallas TPU kernel: sliding-window decode attention.

Serves the long_500k path: one query token against a ring-buffer KV
cache of `window` slots.  Per grid cell (batch b, kv-head h) the whole
window of K and V for that head lives in VMEM (8192 × 256 × bf16 ≈ 4 MiB
— within the 16 MiB v5e VMEM), scores and softmax stay on-chip, and the
two matmuls hit the MXU with a 128-aligned window dimension.

This is the TPU-native replacement for the generic jnp decode path;
`ref.swa_attention_decode` is the oracle.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, pos_ref, valid_ref, qpos_ref, out_ref,
            *, window: int):
    """Blocks: q (1,1,G,dh); k/v (1,1,T,dh); pos/valid (1,T); qpos (1,1);
    out (1,1,G,dh)."""
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (T, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    dh = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / math.sqrt(dh)
    qp = qpos_ref[0, 0]
    pos = pos_ref[0, :]
    ok = valid_ref[0, :] & (pos <= qp) & (pos > qp - window)
    s = jnp.where(ok[None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    out_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32
                            ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_attention_decode(q, k, v, kv_pos, kv_valid, q_pos, *, window: int,
                         interpret: bool = True):
    """Shapes as in ref.swa_attention_decode:
    q (B, H, dh); k/v (B, T, Hkv, dh); kv_pos/kv_valid (B, T); q_pos (B,)."""
    B, H, dh = q.shape
    _, T, Hkv, _ = k.shape
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, dh)
    kk = k.transpose(0, 2, 1, 3)          # (B, Hkv, T, dh)
    vv = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, window=window),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, T), lambda b, h: (b, 0)),
            pl.BlockSpec((1, T), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(qg, kk, vv, kv_pos, kv_valid, q_pos.reshape(B, 1))
    return out.reshape(B, H, dh)
