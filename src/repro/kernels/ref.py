"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gnn_aggregate(src_feats: jax.Array, ell_idx: jax.Array,
                  ell_mask: jax.Array) -> jax.Array:
    """Mean aggregation over an ELL adjacency.

    src_feats: (N_src, F); ell_idx: (N_dst, K) int32 rows into src_feats;
    ell_mask: (N_dst, K) bool.  Returns (N_dst, F) mean of valid rows
    (zeros for isolated vertices).
    """
    gathered = src_feats[ell_idx]                       # (N_dst, K, F)
    w = ell_mask.astype(src_feats.dtype)[..., None]
    s = (gathered * w).sum(axis=1)
    cnt = ell_mask.sum(axis=1).astype(src_feats.dtype)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def swa_attention_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_pos: jax.Array, kv_valid: jax.Array,
                         q_pos: jax.Array, window: int) -> jax.Array:
    """Single-token sliding-window attention.

    q: (B, H, dh); k/v: (B, T, Hkv, dh); kv_pos/kv_valid: (B, T);
    q_pos: (B,).  Returns (B, H, dh)."""
    B, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k) / np.sqrt(dh)
    mask = kv_valid & (kv_pos <= q_pos[:, None]) \
        & (kv_pos > q_pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v)
    return out.reshape(B, H, dh)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization (wire codec, exchange subsystem).

    x: (n, hidden) fp32.  Returns (values int8 (n, hidden),
    scales fp32 (n, 1)) with scale = row absmax / 127 (0 for zero rows)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    # reciprocal-mul, not divide — bit-identical to the Pallas kernel
    scale = absmax * jnp.float32(1.0 / 127.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(values: jax.Array, scales: jax.Array) -> jax.Array:
    """values (n, hidden) int8 × scales (n, 1) fp32 → (n, hidden) fp32."""
    return values.astype(jnp.float32) * scales.astype(jnp.float32)


# -- fused exchange-plane ops (oracles for kernels.exchange_fused) ------------

def gather_quantize(table: jax.Array, rows: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Row gather fused with the int8 encode: the unfused two-step
    ``quantize_int8(table[rows])``, which the fused kernel must match
    bit-exactly (per-row quantization sees identical fp32 inputs)."""
    return quantize_int8(jnp.take(table.astype(jnp.float32),
                                  jnp.asarray(rows), axis=0))


def dequant_scatter(table: jax.Array, rows: jax.Array, values: jax.Array,
                    scales: jax.Array, *, accumulate: bool = False
                    ) -> jax.Array:
    """int8 decode fused with scatter into ``table`` at ``rows``:
    overwrite (push apply) or accumulate.  Returns the updated table."""
    new = dequantize_int8(values, scales)
    tbl = table.astype(jnp.float32)
    rows = jnp.asarray(rows)
    if accumulate:
        return tbl.at[rows].add(new)
    return tbl.at[rows].set(new)


def dequant_aggregate(src_values: jax.Array, src_scales: jax.Array,
                      ell_idx: jax.Array, ell_mask: jax.Array) -> jax.Array:
    """Mean aggregation straight off the wire form: dequantize the int8
    source table, then :func:`gnn_aggregate` — the two-step host path
    the fused kernel replaces."""
    return gnn_aggregate(dequantize_int8(src_values, src_scales),
                         ell_idx, ell_mask)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest entries (ties broken towards keeping
    ≥ k entries — the threshold semantics the bisection kernel provides)."""
    if k <= 0:
        return jnp.zeros(scores.shape, bool)
    if k >= scores.shape[0]:
        return jnp.ones(scores.shape, bool)
    kth = jnp.sort(scores)[-k]
    return scores >= kth
