"""Pallas TPU kernel: sort-free top-k selection via threshold bisection.

Scored pruning (§4.1.2) and prefetch (§4.3) need "keep the top-f% of
remote-vertex scores".  At the paper's CPU scale a sort is fine; at TPU
scale (40M boundary vertices on Papers) a full sort is the wrong tool —
the selection threshold can be found with a fixed number of *counting*
passes, each a pure VMEM reduction:

  repeat 24×:  mid = (lo+hi)/2;  c = #(scores ≥ mid)
               c > k ? lo = mid : hi = mid
  mask = scores ≥ lo

Each pass tiles the score vector through VMEM (grid over tiles,
sequential accumulation into an SMEM-like (1,1) partial), so the whole
selection is O(24·N) streaming reads with no data movement — bandwidth
bound at roofline, no sort network.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
ITERS = 24


def _count_kernel(scores_ref, thr_ref, out_ref):
    """Count entries ≥ thr within one tile; accumulate across the grid.
    scores (1, TILE); thr (1, 1); out (1, 1) running count."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    c = (scores_ref[0, :] >= thr_ref[0, 0]).sum().astype(jnp.int32)
    out_ref[0, 0] += c


def _count_ge(scores2d: jax.Array, thr: jax.Array, *, interpret: bool):
    n = scores2d.shape[1]
    return pl.pallas_call(
        _count_kernel,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (0, i)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(scores2d, thr.reshape(1, 1))[0, 0]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask(scores: jax.Array, k: int, *, interpret: bool = True
              ) -> jax.Array:
    """Boolean mask selecting (at least) the k largest scores.

    Threshold semantics: ties at the k-th value are all kept — identical
    to ref.topk_mask."""
    n = scores.shape[0]
    if k <= 0:
        return jnp.zeros((n,), bool)
    if k >= n:
        return jnp.ones((n,), bool)
    pad = -n % TILE
    s2 = jnp.pad(scores.astype(jnp.float32), (0, pad),
                 constant_values=-jnp.inf).reshape(1, -1)

    lo = jnp.float32(scores.min())
    hi = jnp.float32(scores.max()) + 1e-6

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        c = _count_ge(s2, mid, interpret=interpret)
        return jax.lax.cond(c > k, lambda: (mid, hi), lambda: (lo, mid))

    lo, hi = jax.lax.fori_loop(0, ITERS, body, (lo, hi))
    # lo is the tightest threshold with count > k (or the initial min);
    # use the count at hi to decide which side matches "at least k".
    c_hi = _count_ge(s2, hi, interpret=interpret)
    thr = jnp.where(c_hi >= k, hi, lo)
    return scores >= thr
