"""Named sharding rules for the architecture zoo.

Philosophy (MaxText-style logical axes, resolved per architecture):

* ``model`` mesh axis: tensor parallelism — attention heads, FFN hidden,
  vocab, experts.
* ``data`` mesh axis: batch parallelism; for LARGE architectures (param
  count over ``fsdp_threshold``) it additionally shards the weights'
  non-model dimension (ZeRO-3/FSDP) so 340B-class params fit v5e HBM.
* ``pod`` mesh axis (multi-pod): pure data parallelism across pods.
  Under the paper's federated mapping each pod is a silo running local
  steps; cross-pod aggregation is the FedAvg collective (repro.core.fedopt).

A dimension is only sharded when divisible by the axis size — otherwise it
stays replicated (e.g. kv_heads=8 on a 16-way model axis shards the cache
along sequence instead).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    fsdp: bool                      # shard weight non-model dims over data
    seq_parallel: bool = False      # residual stream seq dim over model

    @property
    def dp_axes(self) -> tuple:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    def axis_size(self, name) -> int:
        if isinstance(name, tuple):
            return int(np.prod([self.axis_size(n) for n in name]))
        return self.mesh.shape[name] if name in self.mesh.axis_names else 1

    def div(self, dim: int, axis) -> Optional[Any]:
        """axis if dim divides evenly, else None (replicate)."""
        return axis if dim % self.axis_size(axis) == 0 else None


def make_rules(mesh: Mesh, cfg: ModelConfig, *,
               fsdp_threshold: float = 5e9,
               seq_parallel: Optional[bool] = None) -> ShardingRules:
    big = cfg.param_count() > fsdp_threshold
    # §Perf finding (command-r train_4k): sequence-parallel residuals cost
    # 4.4x in per-layer seq all-gather/reduce-scatter traffic and only pay
    # off when the saved activations simply cannot fit otherwise — so it
    # defaults ON only for the 340B-class (d_model >= 16384).
    sp = seq_parallel if seq_parallel is not None \
        else cfg.d_model >= 16384
    return ShardingRules(mesh=mesh, fsdp=big, seq_parallel=sp)


# -- parameter specs -----------------------------------------------------------

def _leaf_spec(rules: ShardingRules, path: tuple[str, ...],
               shape: tuple[int, ...]) -> P:
    """PartitionSpec for one param leaf, identified by its tree path.

    Leading stacked-layer dims (from scanned stacks) are never sharded;
    rules below refer to the *trailing* dims of each kind of tensor.
    """
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    fsdp = "data" if rules.fsdp else None

    def spec(*trailing):
        lead = (None,) * (len(shape) - len(trailing))
        # drop axes that don't divide
        fixed = tuple(rules.div(shape[len(lead) + i], ax)
                      if ax is not None else None
                      for i, ax in enumerate(trailing))
        return P(*(lead + fixed))

    if name == "embed":
        return spec("model", fsdp)
    if name == "lm_head":
        return spec(fsdp, "model")
    if name == "vis_proj":
        return spec(None, fsdp)
    # attention projections (trailing dims include head axes)
    if name == "wq":
        return spec(fsdp, "model", None)
    if name in ("wk", "wv"):
        return spec(fsdp, "model", None)
    if name == "wo":
        return spec("model", None, fsdp)
    if name in ("w_uk", "w_uv"):               # MLA up-projections (r, H, d)
        return spec(fsdp, "model", None)
    if name == "w_dkv":
        return spec(None, fsdp)
    if name == "w_kr":
        return spec(None, None)
    # MoE experts: expert-parallel over model axis.  Expert weights live
    # under the "moe" dict — rank is NOT a discriminator because stacked
    # dense MLP weights also carry a leading layer dim.
    if parent == "moe":
        if name in ("w_in", "w_gate"):      # (E, D, F)
            return spec("model", None, fsdp)
        if name == "w_out":                 # (E, F, D)
            return spec("model", None, fsdp)
    # dense MLP
    if name in ("w_in", "w_gate"):
        return spec(fsdp, "model")
    if name == "w_out":
        return spec("model", fsdp)
    if name == "b_in":
        return spec("model")
    if name == "router":
        return spec(None, None)
    # SSM (§Perf: shard-aligned split projections replace the fused
    # in_proj whose ragged output dim forced full replication)
    if name == "in_zx":                    # (D, 2·d_in), z|x shard-aligned
        return spec(fsdp, "model")
    if name in ("conv_x",):                # (W, d_in) depthwise
        return spec(None, "model")
    if name in ("conv_x_b", "norm_w"):     # (d_in,)
        return spec("model")
    if name in ("A_log", "dt_bias") or (parent == "ssm" and name == "D"):
        return spec("model")               # (H,) — replicated if H∤16
    if name == "out_proj":                 # (d_in, D)
        return spec("model", fsdp)
    if name in ("in_BC", "in_dt", "conv_BC", "conv_BC_b"):
        return P(*((None,) * len(shape)))
    # norms, biases, gates, scalars
    return P(*((None,) * len(shape)))


def _tree_paths_specs(rules, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        names = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        specs.append(_leaf_spec(rules, names, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_specs(rules: ShardingRules, params_shapes) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree."""
    return _tree_paths_specs(rules, params_shapes)


def opt_specs(rules: ShardingRules, opt_state_shapes, pspecs) -> Any:
    """Optimizer-state specs.  Adam mirrors params; Adafactor's factored
    stats drop the last (vr) / second-to-last (vc) dim's spec; scalars
    replicate."""
    params_flat = jax.tree_util.tree_leaves(pspecs)

    def assign(state_tree):
        flat, treedef = jax.tree_util.tree_flatten(state_tree)
        out = []
        # state trees that mirror params have the same number of leaves
        if len(flat) == len(params_flat):
            for leaf, ps in zip(flat, params_flat):
                out.append(ps if len(ps) == len(leaf.shape)
                           else P(*list(ps)[: len(leaf.shape)]))
        else:
            out = [P(*((None,) * len(l.shape))) for l in flat]
        return jax.tree_util.tree_unflatten(treedef, out)

    # NamedTuple states: map each field that mirrors params
    if hasattr(opt_state_shapes, "_fields"):
        fields = {}
        for fname in opt_state_shapes._fields:
            sub = getattr(opt_state_shapes, fname)
            leaves = jax.tree_util.tree_leaves(sub)
            if not leaves or all(l.ndim == 0 for l in leaves):
                fields[fname] = jax.tree_util.tree_map(
                    lambda l: P(), sub)
            else:
                fields[fname] = assign(sub)
        return type(opt_state_shapes)(**fields)
    return assign(opt_state_shapes)


# -- activation / input specs -----------------------------------------------------

def batch_specs(rules: ShardingRules, cfg: ModelConfig,
                shape: InputShape) -> dict:
    dp = rules.dp_axes
    b = shape.global_batch
    bspec = dp if b % rules.axis_size(dp) == 0 else None
    out = {"tokens": P(bspec, None)}
    if shape.kind == "train":
        out["labels"] = P(bspec, None)
    if cfg.family == "vlm":
        out["vision"] = P(bspec, None, None)
    if cfg.family == "audio":
        out["frames"] = P(bspec, None, None)
    return out


def cache_specs(rules: ShardingRules, cfg: ModelConfig, cache_shapes,
                global_batch: int) -> Any:
    """Decode-cache specs: batch on data axes when divisible; kv-heads on
    model when divisible, else cache sequence dim on model."""
    dp = rules.dp_axes
    bs = dp if global_batch % rules.axis_size(dp) == 0 else None
    kv_on_model = cfg.num_kv_heads % rules.axis_size("model") == 0

    # trailing rank of each leaf kind (leading dims = stacked layer axes,
    # possibly two of them for the VLM's nested super-block stacks)
    trailing_rank = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4,
                     "c_kv": 3, "k_rope": 3, "conv_x": 3, "conv_BC": 3,
                     "state": 4, "pos": 2, "valid": 2, "index": 1,
                     "length": 1}

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        name = next((p.key for p in reversed(path) if hasattr(p, "key")),
                    "?")
        tr = trailing_rank.get(name, leaf.ndim)
        lead = (None,) * (leaf.ndim - tr)
        shp = leaf.shape[leaf.ndim - tr:]
        if name in ("k", "v"):                    # (B, T, Hkv, dh)
            s = (bs, None, "model", None) if kv_on_model \
                else (bs, rules.div(shp[1], "model"), None, None)
        elif name in ("cross_k", "cross_v"):
            s = (bs, None, "model" if kv_on_model else None, None)
        elif name in ("c_kv", "k_rope"):          # MLA latent (B, T, r)
            s = (bs, rules.div(shp[1], "model"), None)
        elif name == "conv_x":                    # (B, W-1, d_in)
            s = (bs, None, rules.div(shp[2], "model"))
        elif name == "conv_BC":                   # (B, W-1, 2N)
            s = (bs, None, None)
        elif name == "state":                     # (B, H, P, N)
            s = (bs, rules.div(shp[1], "model"), None, None)
        elif tr >= 1:                             # pos/valid/index/length
            s = (bs,) + (None,) * (tr - 1)
        else:
            s = ()
        specs.append(P(*(lead + s)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def logical_constraint(rules: ShardingRules, x, kind: str):
    """with_sharding_constraint helper for activations."""
    dp = rules.dp_axes
    if kind == "residual":
        seq = "model" if rules.seq_parallel else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, P(dp, seq, None)))
    if kind == "logits":
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, P(dp, None, "model")))
    return x
