from .sharding import (ShardingRules, batch_specs, cache_specs, opt_specs,
                       param_specs)

__all__ = ["ShardingRules", "param_specs", "opt_specs", "batch_specs",
           "cache_specs"]
