"""Single-pass streaming partitioner (LDG) + streaming shard extraction.

``ldg_partition`` is a chunk-vectorized Linear Deterministic Greedy
streaming partitioner (Stanton & Kliot): vertices arrive in id order in
blocks, each block scores every candidate part as

    |N(v) ∩ P_i| · (1 − |P_i| / cap)

against the partition state frozen at block start (the restreaming-LDG
BSP relaxation — what makes the block assignable with one argmax
instead of a per-vertex Python loop), admits winners under per-part
capacity by ranked admission, and water-fills the rest (vertices with
no assigned neighbours yet) onto the least-loaded parts.  One pass over
the CSR, O(V + chunk·k) memory: the partitioner never sees more than a
block of the edge array, so it runs unchanged over a million-vertex
mmap store.

``stream_client_shards`` replaces the O(E)-materializing halo/boundary
extraction of ``make_client_shards`` for stores: it streams CSR blocks,
scatters each client's in-edges (and reciprocal push candidates) into
per-client accumulators, and hands them to the *same*
``assemble_shard`` the in-memory path uses — output bit-identical,
peak memory bounded by the shard sizes requested, not the graph.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graphs.partition import (ClientShard, _water_fill,
                                    assemble_shard, ranks_within)


def ldg_partition(g, k: int, *, seed: int = 0, slack: float = 1.05,
                  chunk_vertices: int = 1 << 16) -> np.ndarray:
    """Streaming LDG over ``g``'s CSR (an in-memory ``Graph`` or an mmap
    ``GraphStore``).  Deterministic for a ``(graph, k, seed,
    chunk_vertices)`` key; ``slack`` bounds every part at
    ``ceil(V/k)·slack`` vertices."""
    n = g.num_vertices
    cap = int(np.ceil(n / k) * slack)
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    rng = np.random.default_rng(seed)
    # seeded per-part jitter breaks score ties without biasing part 0
    jitter = rng.random(k) * 1e-9

    for lo in range(0, n, chunk_vertices):
        hi = min(lo + chunk_vertices, n)
        B = hi - lo
        ptr = np.asarray(g.indptr[lo: hi + 1]).astype(np.int64)
        e_src = np.asarray(g.indices[ptr[0]: ptr[-1]]).astype(np.int64)
        e_dst_local = np.repeat(np.arange(B, dtype=np.int64),
                                np.diff(ptr))
        src_part = part[e_src]
        known = src_part >= 0
        counts = np.bincount(
            e_dst_local[known] * k + src_part[known],
            minlength=B * k).reshape(B, k)
        penalty = np.maximum(0.0, 1.0 - sizes / cap)
        scores = counts * penalty[None, :] + jitter[None, :]
        best = np.argmax(scores, axis=1)
        has_affinity = counts[np.arange(B), best] > 0

        # ranked admission against the frozen sizes: part p accepts at
        # most (cap - sizes[p]) of this block's affinity winners, in
        # block order
        idx = np.nonzero(has_affinity)[0]
        admit = np.zeros(B, dtype=bool)
        if len(idx):
            dest = best[idx]
            ok = ranks_within(dest) < np.maximum(0, cap - sizes)[dest]
            admit[idx[ok]] = True
        part[lo:hi][admit] = best[admit].astype(np.int32)
        sizes += np.bincount(best[admit], minlength=k)

        # the rest (no assigned neighbours, or their part was full)
        # water-fill onto the least-loaded parts
        rest = np.nonzero(~admit)[0]
        if len(rest):
            fills = _water_fill(sizes, len(rest))
            recv = np.argsort(sizes, kind="stable")
            part[lo:hi][rest] = np.repeat(
                recv, fills[recv]).astype(np.int32)
            sizes += fills
    return part


def iter_edge_chunks(g, chunk_edges: int):
    """Yield ``(lo, hi)`` vertex ranges whose in-edge lists stay near
    ``chunk_edges`` — edge-budgeted so a power-law hub range cannot
    blow the per-chunk working set the way fixed vertex strides do."""
    indptr = g.indptr
    V = g.num_vertices
    lo = 0
    while lo < V:
        hi = int(np.searchsorted(indptr, int(indptr[lo]) + chunk_edges,
                                 side="right")) - 1
        hi = min(max(hi, lo + 1), V)
        yield lo, hi
        lo = hi


def stream_client_shards(
    g,
    part: np.ndarray,
    *,
    client_ids: Optional[list[int]] = None,
    retention_limit: Optional[int] = None,
    retained_remote: Optional[dict[int, np.ndarray]] = None,
    seed: int = 0,
    chunk_edges: int = 1 << 21,
) -> list[ClientShard]:
    """Bit-identical ``make_client_shards`` over a streamed CSR.

    ``client_ids`` restricts extraction (a fed_worker asks only for the
    shards it owns); edges arrive grouped by destination in ascending
    order — exactly the global CSR order the in-memory path sees — and
    each shard is assembled by the shared ``assemble_shard``.  The
    chunking granularity never changes the output, only the transient
    working set.
    """
    part = np.asarray(part)
    k = int(part.max()) + 1
    wanted = list(range(k)) if client_ids is None else sorted(client_ids)
    e_src_acc: dict[int, list[np.ndarray]] = {c: [] for c in wanted}
    e_dst_acc: dict[int, list[np.ndarray]] = {c: [] for c in wanted}
    push_acc: dict[int, list[np.ndarray]] = {c: [] for c in wanted}

    for lo, hi in iter_edge_chunks(g, chunk_edges):
        ptr = np.asarray(g.indptr[lo: hi + 1]).astype(np.int64)
        e_src = np.asarray(g.indices[ptr[0]: ptr[-1]]).astype(np.int64)
        e_dst = np.repeat(np.arange(lo, hi, dtype=np.int64),
                          np.diff(ptr))
        dst_part = part[e_dst]
        src_part = part[e_src]
        for c in wanted:
            mine = dst_part == c
            if np.any(mine):
                e_src_acc[c].append(e_src[mine])
                e_dst_acc[c].append(e_dst[mine])
            # reciprocal push candidates: my locals feeding other clients
            out = (src_part == c) & (dst_part != c)
            if np.any(out):
                push_acc[c].append(np.unique(e_src[out]))

    shards = []
    for c in wanted:
        e_src = np.concatenate(e_src_acc[c]) if e_src_acc[c] \
            else np.zeros(0, np.int64)
        e_dst = np.concatenate(e_dst_acc[c]) if e_dst_acc[c] \
            else np.zeros(0, np.int64)
        push = np.unique(np.concatenate(push_acc[c])) if push_acc[c] \
            else np.zeros(0, np.int64)
        shards.append(assemble_shard(
            g, part, c, e_src, e_dst, push,
            retention_limit=retention_limit,
            retained_remote=retained_remote, seed=seed))
    return shards


def build_client_shards(g, part: np.ndarray, **kw) -> list[ClientShard]:
    """Dispatch: stream for an mmap store, materialize for a Graph.

    Both paths produce bit-identical shards (gated in
    ``tests/test_graphstore.py``); the split is purely about peak
    memory — ``make_client_shards`` repeats the O(E) destination array,
    which is exactly what an out-of-core graph cannot afford.
    """
    if getattr(g, "is_store", False):
        return stream_client_shards(g, part, **kw)
    from repro.graphs.partition import make_client_shards
    client_ids = kw.pop("client_ids", None)
    shards = make_client_shards(g, part, **kw)
    if client_ids is not None:
        shards = [shards[c] for c in sorted(client_ids)]
    return shards
