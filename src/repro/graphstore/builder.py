"""Chunked edge-stream → mmap CSR builder (external bucket sort by dst).

The in-memory path (``graphs.graph.from_edges`` with ``symmetric=True,
dedup=True``) produces a *canonical* CSR: per destination row, the
sorted unique source ids with self-loops removed.  That canonical form
is what makes an out-of-core builder possible without ever holding the
edge list: edges arrive in chunks, each chunk is scattered (plus its
reverse edges) into destination-range bucket files on disk, and each
bucket is then independently deduped + sorted and appended to the
``indices`` array.  Peak memory is one bucket (+ its sort
temporaries), not the graph: ``tests/test_graphstore.py`` pins the
output bit-identical to ``from_edges`` and ``bench_scaling.py``
reports builder RSS at the 1M-vertex scale.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from .store import META_NAME, GraphStore

# target pairs resident per bucket while deduping (~16 B/pair on disk,
# a few transient copies of that in RAM during np.unique)
DEFAULT_BUCKET_PAIRS = 2_000_000


class _BucketSpill:
    """Append-only (dst, src) int64 pair files, one per dst range."""

    def __init__(self, tmp_dir: str, num_vertices: int, num_buckets: int):
        self.width = -(-num_vertices // num_buckets)   # ceil
        self.num_buckets = num_buckets
        self.paths = [os.path.join(tmp_dir, f"bucket{b}.pairs")
                      for b in range(num_buckets)]
        self._fh = [open(p, "wb") for p in self.paths]

    def append(self, src: np.ndarray, dst: np.ndarray) -> None:
        if len(src) == 0:
            return
        b = dst // self.width
        order = np.argsort(b, kind="stable")
        b_sorted = b[order]
        bounds = np.searchsorted(b_sorted, np.arange(self.num_buckets + 1))
        pair = np.empty((len(src), 2), dtype=np.int64)
        pair[:, 0] = dst[order]
        pair[:, 1] = src[order]
        for bi in range(self.num_buckets):
            lo, hi = bounds[bi], bounds[bi + 1]
            if hi > lo:
                pair[lo:hi].tofile(self._fh[bi])

    def close(self) -> None:
        for f in self._fh:
            f.close()

    def load(self, b: int) -> np.ndarray:
        return np.fromfile(self.paths[b], dtype=np.int64).reshape(-1, 2)


def build_csr_store(
    edge_chunks: Iterable[tuple[np.ndarray, np.ndarray]],
    num_vertices: int,
    path: str,
    *,
    symmetric: bool = True,
    dedup: bool = True,
    est_pairs: int,
    bucket_pairs: int = DEFAULT_BUCKET_PAIRS,
    node_writer: Optional[Callable[[str], dict]] = None,
    num_classes: int = 0,
    name: str = "store",
    meta_extra: Optional[dict] = None,
) -> GraphStore:
    """Stream ``(src, dst)`` chunks into a canonical mmap CSR store.

    ``symmetric`` adds reverse edges, ``dedup`` removes self-loops and
    parallel edges — exactly the semantics (and exact output bytes) of
    ``from_edges(num_vertices, src, dst, symmetric=True, dedup=True)``.
    ``node_writer(path)`` is called after the CSR lands to emit the node
    arrays (features/labels/train_mask) and may return extra meta keys.
    ``est_pairs`` (directed pairs before symmetrization) is required —
    it sizes the bucket fan-out so each bucket stays near
    ``bucket_pairs`` resident; an understated estimate degrades the
    memory bound proportionally, never correctness.
    """
    if est_pairs <= 0:
        raise ValueError("est_pairs must be positive: the bucket fan-out "
                         "(and with it the memory bound) is sized from it")
    os.makedirs(path, exist_ok=True)
    total_pairs = est_pairs * (2 if symmetric else 1)
    num_buckets = max(1, -(-total_pairs // bucket_pairs))
    num_buckets = min(num_buckets, max(1, num_vertices))
    tmp_dir = tempfile.mkdtemp(prefix="csrbuild_", dir=path)
    try:
        spill = _BucketSpill(tmp_dir, num_vertices, num_buckets)
        for src, dst in edge_chunks:
            src = np.asarray(src, dtype=np.int64)
            dst = np.asarray(dst, dtype=np.int64)
            spill.append(src, dst)
            if symmetric:
                spill.append(dst, src)
        spill.close()

        indptr = np.zeros(num_vertices + 1, dtype=np.int64)
        idx_tmp = os.path.join(tmp_dir, "indices.raw")
        with open(idx_tmp, "wb") as out:
            for b in range(num_buckets):
                pairs = spill.load(b)
                os.unlink(spill.paths[b])
                if dedup:
                    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
                    # canonical order = sorted unique (dst, src): encode
                    # as one int64 key (dst, src < V so key < V², which
                    # fits int64 up to V ≈ 3e9)
                    key = pairs[:, 0] * num_vertices + pairs[:, 1]
                    key = np.unique(key)
                    dst_b = key // num_vertices
                    src_b = key % num_vertices
                else:
                    order = np.lexsort((pairs[:, 1], pairs[:, 0]))
                    dst_b, src_b = pairs[order, 0], pairs[order, 1]
                np.add.at(indptr, dst_b + 1, 1)
                src_b.astype(np.int32).tofile(out)
        indptr = np.cumsum(indptr)
        num_edges = int(indptr[-1])

        np.save(os.path.join(path, "indptr.npy"), indptr)
        out_idx = np.lib.format.open_memmap(
            os.path.join(path, "indices.npy"), mode="w+",
            dtype=np.int32, shape=(num_edges,))
        with open(idx_tmp, "rb") as f:
            off = 0
            while True:
                blk = np.fromfile(f, dtype=np.int32, count=1 << 22)
                if len(blk) == 0:
                    break
                out_idx[off: off + len(blk)] = blk
                off += len(blk)
        out_idx.flush()
        del out_idx
    finally:
        shutil.rmtree(tmp_dir, ignore_errors=True)

    meta = {"num_vertices": int(num_vertices), "num_edges": num_edges,
            "num_classes": int(num_classes), "name": name}
    if node_writer is not None:
        meta.update(node_writer(path) or {})
    meta.update(meta_extra or {})
    with open(os.path.join(path, META_NAME), "w") as f:
        json.dump(meta, f)
    return GraphStore(path)


def chunked(src: np.ndarray, dst: np.ndarray,
            chunk_edges: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Adapt a materialized edge list to the chunk-iterator interface."""
    for lo in range(0, len(src), chunk_edges):
        yield src[lo: lo + chunk_edges], dst[lo: lo + chunk_edges]
