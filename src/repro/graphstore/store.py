"""Mmap-backed CSR graph store — the out-of-core twin of ``graphs.Graph``.

A store is a directory of ``.npy`` arrays plus a ``meta.json``:

    meta.json        {num_vertices, num_edges, num_classes, name, ...}
    indptr.npy       (V+1,) int64
    indices.npy      (E,)   int32   in-neighbours, sorted per row
    features.npy     (V, F) float32
    labels.npy       (V,)   int32
    train_mask.npy   (V,)   bool
    part_k{K}_s{S}.npy            optional partition labels
    shards_k{K}_s{S}_r{R}/        optional prebuilt per-client shards

:class:`GraphStore` opens every array with ``mmap_mode="r"`` and exposes
the exact accessor protocol of :class:`repro.graphs.graph.Graph`
(``num_vertices`` / ``indptr`` / ``neighbours`` / ``train_vertices`` /
...), so samplers, pruning, and the federated trainer are agnostic to
which plane a graph lives on.  Pages fault in on access: opening a
111M-vertex store costs metadata only, and a worker that touches one
client shard never reads the rest of the file.
"""

from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.graphs.partition import ClientShard

META_NAME = "meta.json"
NODE_ARRAYS = ("features", "labels", "train_mask")
_SHARD_ARRAYS = ("indptr", "indices", "global_ids", "features", "labels",
                 "train_mask", "pull_nodes", "push_nodes", "all_pull_nodes")


class GraphStore:
    """An on-disk CSR graph with the :class:`Graph` accessor protocol."""

    is_store = True   # duck-type marker (isinstance needs no import)

    def __init__(self, path: str):
        self.path = os.path.abspath(path)
        with open(os.path.join(self.path, META_NAME)) as f:
            self.meta = json.load(f)
        self.name = self.meta.get("name", os.path.basename(self.path))
        self.num_classes = int(self.meta.get("num_classes", 0))
        self.indptr = self._load("indptr")
        self.indices = self._load("indices")
        self.features = self._load("features", optional=True)
        self.labels = self._load("labels", optional=True)
        self.train_mask = self._load("train_mask", optional=True)

    def _load(self, name: str, *, optional: bool = False):
        p = os.path.join(self.path, name + ".npy")
        if not os.path.exists(p):
            if optional:
                return None
            raise FileNotFoundError(f"graph store {self.path} missing {name}.npy")
        return np.load(p, mmap_mode="r")

    # -- Graph accessor protocol -------------------------------------------

    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def feat_dim(self) -> int:
        return 0 if self.features is None else int(self.features.shape[1])

    def in_degree(self, u: Optional[np.ndarray] = None) -> np.ndarray:
        deg = np.diff(self.indptr)
        return deg if u is None else deg[u]

    def neighbours(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]: self.indptr[u + 1]]

    def avg_degree(self) -> float:
        return self.num_edges / max(1, self.num_vertices)

    def train_vertices(self) -> np.ndarray:
        if self.train_mask is None:
            return np.arange(self.num_vertices)
        return np.nonzero(self.train_mask)[0].astype(np.int64)

    def validate(self, *, chunk_vertices: int = 1 << 18) -> None:
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        for lo in range(0, self.num_vertices, chunk_vertices):
            hi = min(lo + chunk_vertices, self.num_vertices)
            ptr = np.asarray(self.indptr[lo: hi + 1])
            assert np.all(np.diff(ptr) >= 0)
            if ptr[-1] > ptr[0]:
                idx = np.asarray(self.indices[ptr[0]: ptr[-1]])
                assert idx.min() >= 0 and idx.max() < self.num_vertices
        if self.features is not None:
            assert self.features.shape[0] == self.num_vertices
        if self.labels is not None:
            assert self.labels.shape[0] == self.num_vertices

    # -- partitions / prebuilt shards ---------------------------------------

    def partition_path(self, k: int, seed: int) -> str:
        return os.path.join(self.path, f"part_k{k}_s{seed}.npy")

    def load_partition(self, k: int, seed: int) -> Optional[np.ndarray]:
        p = self.partition_path(k, seed)
        return np.load(p) if os.path.exists(p) else None

    def save_partition(self, part: np.ndarray, k: int, seed: int) -> str:
        p = self.partition_path(k, seed)
        np.save(p, np.asarray(part, np.int32))
        return p

    def shards_dir(self, k: int, seed: int,
                   retention_limit: Optional[int]) -> str:
        r = "inf" if retention_limit is None else str(int(retention_limit))
        return os.path.join(self.path, f"shards_k{k}_s{seed}_r{r}")

    def has_shards(self, k: int, seed: int,
                   retention_limit: Optional[int]) -> bool:
        return os.path.exists(os.path.join(
            self.shards_dir(k, seed, retention_limit), "done"))

    def save_shard(self, sh: ClientShard, k: int, seed: int,
                   retention_limit: Optional[int]) -> str:
        """Write one client shard's arrays (no completion marker — call
        :meth:`finalize_shards` once every shard landed)."""
        root = self.shards_dir(k, seed, retention_limit)
        d = os.path.join(root, f"shard{sh.client_id}")
        os.makedirs(d, exist_ok=True)
        for name in _SHARD_ARRAYS:
            np.save(os.path.join(d, name + ".npy"), getattr(sh, name))
        with open(os.path.join(d, META_NAME), "w") as f:
            json.dump({"client_id": sh.client_id,
                       "num_local": int(sh.num_local),
                       "num_classes": int(sh.num_classes)}, f)
        return root

    def finalize_shards(self, k: int, seed: int,
                        retention_limit: Optional[int],
                        count: int) -> None:
        root = self.shards_dir(k, seed, retention_limit)
        with open(os.path.join(root, "done"), "w") as f:
            f.write(f"{count}\n")

    def save_shards(self, shards: list[ClientShard], k: int, seed: int,
                    retention_limit: Optional[int]) -> str:
        for sh in shards:
            root = self.save_shard(sh, k, seed, retention_limit)
        self.finalize_shards(k, seed, retention_limit, len(shards))
        return root

    def load_shard(self, c: int, k: int, seed: int,
                   retention_limit: Optional[int],
                   *, mmap: bool = True) -> ClientShard:
        """One prebuilt client shard, arrays mmap'd from disk — a worker
        that owns client ``c`` never touches the other shards."""
        d = os.path.join(self.shards_dir(k, seed, retention_limit),
                         f"shard{c}")
        with open(os.path.join(d, META_NAME)) as f:
            meta = json.load(f)
        kw = {"mmap_mode": "r"} if mmap else {}
        arrs = {name: np.load(os.path.join(d, name + ".npy"), **kw)
                for name in _SHARD_ARRAYS}
        return ClientShard(client_id=int(meta["client_id"]),
                           num_local=int(meta["num_local"]),
                           num_classes=int(meta["num_classes"]), **arrs)

    def load_pull_nodes(self, k: int, seed: int,
                        retention_limit: Optional[int]) -> list[np.ndarray]:
        """Every client's pull set (tiny arrays) — the reciprocal push
        recompute needs them without loading full shards."""
        root = self.shards_dir(k, seed, retention_limit)
        return [np.load(os.path.join(root, f"shard{c}", "pull_nodes.npy"))
                for c in range(k)]


def open_store(path: str) -> GraphStore:
    return GraphStore(path)


def store_from_graph(g, path: str, *, name: Optional[str] = None) -> GraphStore:
    """Write an in-memory :class:`Graph` out as a store (small graphs /
    tests; million-vertex stores come from ``builder.build_csr_store``)."""
    os.makedirs(path, exist_ok=True)
    np.save(os.path.join(path, "indptr.npy"), np.asarray(g.indptr, np.int64))
    np.save(os.path.join(path, "indices.npy"), np.asarray(g.indices, np.int32))
    for arr_name in NODE_ARRAYS:
        arr = getattr(g, arr_name, None)
        if arr is not None:
            np.save(os.path.join(path, arr_name + ".npy"), np.asarray(arr))
    meta = {"num_vertices": int(g.num_vertices),
            "num_edges": int(g.num_edges),
            "num_classes": int(g.num_classes),
            "name": name or g.name}
    with open(os.path.join(path, META_NAME), "w") as f:
        json.dump(meta, f)
    return GraphStore(path)
