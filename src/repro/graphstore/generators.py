"""Streaming graph generators: chunked R-MAT and chunked DC-SBM.

Both emit ``(src, dst)`` edge chunks for ``builder.build_csr_store`` so
million-vertex graphs build without ever materializing the edge list.

**R-MAT** (`build_rmat_store`): the Graph500 kernel-1 recursive-matrix
sampler, vectorized per chunk — each edge walks ``scale`` quadrant
levels drawn from one sequential PCG64 stream, so the output depends
only on ``(scale, edge_factor, seed)``, not on the chunk size.  Node
data (labels / noisy label-projection features / train mask, same
family as the DC-SBM presets) streams to the store row-chunk by
row-chunk.

**DC-SBM** (`build_sbm_store`): a chunk-by-chunk *replay* of
``graphs.synthetic.make_graph``'s exact RNG stream.  numpy draws fill
sequentially (``random``/``standard_normal``/``choice(p=...)`` consume
the bit stream per element), so drawing the same quantities in chunks
yields bit-identical values; the only state this needs in RAM is the
O(V) node arrays — per-edge arrays (src / homophily mask / dst) spill
to temp files.  ``tests/test_graphstore.py`` gates bit-identity against
``make_graph`` for every preset at small scale: same
``(preset, scale, seed)`` key ⇒ same graph, whichever plane built it.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from repro.graphs.synthetic import PRESETS

from .builder import build_csr_store
from .store import GraphStore

# -- R-MAT ------------------------------------------------------------------

# Graph500 quadrant probabilities
RMAT_A, RMAT_B, RMAT_C = 0.57, 0.19, 0.19
# fixed generation block: each block draws from its own (seed, block)
# child stream, so the emitted edges depend only on (scale,
# edge_factor, seed) — never on how a consumer sizes its chunks
RMAT_BLOCK = 1 << 16


def rmat_chunks(scale: int, edge_factor: int, seed: int):
    """Yield (src, dst) blocks of ``edge_factor · 2**scale`` R-MAT edges."""
    n_e = edge_factor << scale
    p_src1 = 1.0 - (RMAT_A + RMAT_B)            # P(src bit = 1)
    p_dst1_src0 = RMAT_B / (RMAT_A + RMAT_B)    # P(dst bit = 1 | src bit 0)
    p_dst1_src1 = 1.0 - RMAT_C / (1.0 - (RMAT_A + RMAT_B)) \
        if (1.0 - (RMAT_A + RMAT_B)) > 0 else 0.0
    for block, lo in enumerate(range(0, n_e, RMAT_BLOCK)):
        rng = np.random.default_rng((seed, block))
        m = min(RMAT_BLOCK, n_e - lo)
        src = np.zeros(m, dtype=np.int64)
        dst = np.zeros(m, dtype=np.int64)
        for level in range(scale):
            u = rng.random(m)
            v = rng.random(m)
            sbit = u < p_src1
            dbit = np.where(sbit, v < p_dst1_src1, v < p_dst1_src0)
            src = (src << 1) | sbit
            dst = (dst << 1) | dbit
        yield src, dst


def _write_node_arrays(path: str, rng: np.random.Generator,
                       labels: np.ndarray, n_cls: int, feat_dim: int,
                       feature_noise: float, train_frac: float,
                       row_chunk: int) -> dict:
    """Shared node-data body: noisy label-projection features written
    row-chunk by row-chunk to an open_memmap, plus the train mask with
    every class guaranteed a train vertex — drawn from the *caller's*
    generator, so the SBM path can keep replaying make_graph's stream
    while R-MAT uses its own."""
    n_v = len(labels)
    np.save(os.path.join(path, "labels.npy"), labels)
    proj = rng.standard_normal((n_cls, feat_dim)).astype(np.float32)
    feats = np.lib.format.open_memmap(
        os.path.join(path, "features.npy"), mode="w+",
        dtype=np.float32, shape=(n_v, feat_dim))
    for lo in range(0, n_v, row_chunk):
        hi = min(lo + row_chunk, n_v)
        feats[lo:hi] = proj[labels[lo:hi]] + feature_noise * \
            rng.standard_normal((hi - lo, feat_dim)).astype(np.float32)
    feats.flush()
    del feats
    mask = rng.random(n_v) < train_frac
    mask[:n_cls] = True
    np.save(os.path.join(path, "train_mask.npy"), mask)
    return {"num_classes": n_cls}


def _node_writer(n_v: int, n_cls: int, feat_dim: int, train_frac: float,
                 feature_noise: float, seed: int, chunk: int = 1 << 17):
    """Label/feature/mask writer for generated stores (R-MAT): labels are
    uniform blocks, features a noisy label projection — the same signal
    family the DC-SBM presets use, so cross-client aggregation still
    carries information at any scale."""

    def write(path: str) -> dict:
        rng = np.random.default_rng(seed + 0x5EED)
        labels = rng.integers(0, n_cls, size=n_v).astype(np.int32)
        return _write_node_arrays(path, rng, labels, n_cls, feat_dim,
                                  feature_noise, train_frac, chunk)

    return write


def build_rmat_store(path: str, scale: int, *, edge_factor: int = 8,
                     seed: int = 0, num_classes: int = 16,
                     feat_dim: int = 32, train_frac: float = 0.01,
                     feature_noise: float = 2.0) -> GraphStore:
    n_v = 1 << scale
    return build_csr_store(
        rmat_chunks(scale, edge_factor, seed),
        n_v, path,
        est_pairs=edge_factor << scale,
        node_writer=_node_writer(n_v, num_classes, feat_dim, train_frac,
                                 feature_noise, seed),
        name=f"rmat{scale}",
        meta_extra={"generator": "rmat", "scale": scale,
                    "edge_factor": edge_factor, "seed": seed})


# -- DC-SBM (bit-identical streaming replay of synthetic.make_graph) --------

def build_sbm_store(path: str, preset: str, *, seed: int = 0,
                    scale: float = 1.0,
                    feature_noise: float | None = None,
                    chunk_edges: int = 1 << 18) -> GraphStore:
    """Build ``make_graph(preset, scale=..., seed=...)`` as an mmap store
    without materializing the edge list, bit-identical to the in-memory
    generator (same RNG stream, replayed in chunks)."""
    if preset not in PRESETS:
        raise KeyError(f"unknown synthetic graph {preset!r}; "
                       f"options {list(PRESETS)}")
    n_v, avg_deg, n_cls, feat_dim, train_frac, homophily, preset_noise = \
        PRESETS[preset]
    if feature_noise is None:
        feature_noise = preset_noise
    n_v = max(4 * n_cls, int(n_v * scale))
    rng = np.random.default_rng(seed)

    labels = rng.integers(0, n_cls, size=n_v).astype(np.int32)
    theta = rng.lognormal(mean=0.0, sigma=0.9, size=n_v)
    theta /= theta.mean()

    n_e = int(n_v * avg_deg / 2)
    p = theta / theta.sum()
    chunks = [(lo, min(lo + chunk_edges, n_e))
              for lo in range(0, n_e, chunk_edges)]

    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix="sbm_", dir=path)
    try:
        # pass 1 — src endpoints, chunk-replayed from the single stream
        src_paths = []
        for i, (lo, hi) in enumerate(chunks):
            s = rng.choice(n_v, size=hi - lo, p=p)
            sp = os.path.join(tmp, f"src{i}.raw")
            s.tofile(sp)
            src_paths.append(sp)

        # pass 2 — homophily mask + per-(chunk, block) same-edge counts
        same_paths, block_counts = [], np.zeros((len(chunks), n_cls),
                                                dtype=np.int64)
        for i, (lo, hi) in enumerate(chunks):
            same = rng.random(hi - lo) < homophily
            mp = os.path.join(tmp, f"same{i}.raw")
            same.astype(np.uint8).tofile(mp)
            same_paths.append(mp)
            s = np.fromfile(src_paths[i], dtype=np.int64)
            block_counts[i] = np.bincount(labels[s[same]], minlength=n_cls)

        # pass 3 — cross-block dst: make_graph draws them in one call in
        # edge order, so chunked draws of the per-chunk cross counts land
        # on the identical stream positions
        dst_paths = []
        for i, (lo, hi) in enumerate(chunks):
            same = np.fromfile(same_paths[i], dtype=np.uint8).astype(bool)
            d = np.empty(hi - lo, dtype=np.int64)
            n_cross = int((~same).sum())
            if n_cross:
                d[~same] = rng.choice(n_v, size=n_cross, p=p)
            dp = os.path.join(tmp, f"dst{i}.raw")
            d.tofile(dp)
            dst_paths.append(dp)

        # pass 4 — same-block dst, block-major (make_graph's loop order):
        # for each present block ascending, the one big choice() call is
        # replayed as per-chunk draws in edge order within the block.
        # Draws are spilled per (block, chunk) and applied in a single
        # chunk-major pass afterwards, so every chunk file is rewritten
        # once — not once per class (O(E) I/O, not O(n_cls · E)).
        order = np.argsort(labels, kind="stable")
        block_start = np.searchsorted(labels[order], np.arange(n_cls))
        block_end = np.searchsorted(labels[order], np.arange(n_cls),
                                    side="right")
        present = np.nonzero(block_counts.sum(axis=0) > 0)[0]
        for c in present:
            members = order[block_start[c]: block_end[c]]
            pc = theta[members] / theta[members].sum()
            for i in range(len(chunks)):
                cnt = int(block_counts[i, c])
                if cnt == 0:
                    continue
                rng.choice(members, size=cnt, p=pc).tofile(
                    os.path.join(tmp, f"draw{c}_{i}.raw"))
        for i in range(len(chunks)):
            if not block_counts[i].sum():
                continue
            s = np.fromfile(src_paths[i], dtype=np.int64)
            same = np.fromfile(same_paths[i], dtype=np.uint8).astype(bool)
            d = np.fromfile(dst_paths[i], dtype=np.int64)
            lab_s = labels[s]
            for c in present:
                if block_counts[i, c]:
                    d[same & (lab_s == c)] = np.fromfile(
                        os.path.join(tmp, f"draw{c}_{i}.raw"),
                        dtype=np.int64)
            d.tofile(dst_paths[i])

        def edge_chunks():
            for i in range(len(chunks)):
                yield (np.fromfile(src_paths[i], dtype=np.int64),
                       np.fromfile(dst_paths[i], dtype=np.int64))

        def node_writer(out: str) -> dict:
            # continues the SAME generator the edge passes consumed, so
            # the replay stays aligned with make_graph's stream
            row_chunk = max(1, (chunk_edges * 8) // max(1, feat_dim))
            return _write_node_arrays(out, rng, labels, n_cls, feat_dim,
                                      feature_noise, train_frac,
                                      row_chunk)

        store = build_csr_store(
            edge_chunks(), n_v, path,
            est_pairs=n_e, node_writer=node_writer, name=preset,
            meta_extra={"generator": "sbm", "preset": preset,
                        "scale": scale, "seed": seed})
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return store
