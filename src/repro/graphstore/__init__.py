"""Out-of-core graph plane: mmap CSR stores, streaming builders and
generators, and single-pass streaming partitioning — interchangeable
with the in-memory ``repro.graphs`` substrate (same accessor protocol,
bit-identical outputs at any scale that fits both planes)."""

from .builder import build_csr_store, chunked
from .generators import build_rmat_store, build_sbm_store, rmat_chunks
from .partition_stream import (build_client_shards, ldg_partition,
                               stream_client_shards)
from .store import GraphStore, open_store, store_from_graph

__all__ = [
    "GraphStore", "open_store", "store_from_graph",
    "build_csr_store", "chunked",
    "build_rmat_store", "build_sbm_store", "rmat_chunks",
    "ldg_partition", "stream_client_shards", "build_client_shards",
]
