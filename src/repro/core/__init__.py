"""OptimES core: federated GNN training with an optimized embedding server.

The paper's primary contribution — embedding-server mediated federated
subgraph learning plus the OptimES strategy family (pruning, push overlap,
pull prefetch) — lives here.  Substrates (graphs, models, optim, data,
distributed, launch) are sibling subpackages.
"""

from .cost_model import NetworkModel, TransferLog
from .embedding_server import EmbeddingServer
from .federated import (ClientRoundResult, FederatedGNNTrainer, PhaseTimes,
                        RoundStats, peak_accuracy, time_to_accuracy)
from .pruning import (bridge_scores, degree_scores, frequency_scores,
                      retention_pruned_sets, score_remote_nodes, top_fraction)
from .strategies import Strategy, default_strategies

__all__ = [
    "NetworkModel", "TransferLog", "EmbeddingServer", "FederatedGNNTrainer",
    "ClientRoundResult", "PhaseTimes", "RoundStats", "peak_accuracy",
    "time_to_accuracy",
    "retention_pruned_sets", "frequency_scores", "degree_scores",
    "bridge_scores", "score_remote_nodes", "top_fraction", "Strategy",
    "default_strategies",
]
