"""Network cost model for the federated runtime.

The container is CPU-only with no real cluster, so *compute* is measured
(wall-clock of the jitted steps) while *network* is modelled after the
paper's testbed: clients and the embedding/aggregation servers connected
by 1 Gbps Ethernet, Redis-style batched+pipelined RPCs (§5.1–5.2).  Both
components are recorded separately in every RoundStats so the modelling
assumption is auditable.

Calibration targets from the paper (§5.4): pushing ≈100k embeddings takes
≈1.8 s on Reddit/GraphConv (hidden=32 ⇒ 128 B payload/embedding/layer,
2 layers shared for L=3) — 100k · 2 · 128 B = 25.6 MB ⇒ ≈0.2 s of pure
wire time on 1 Gbps; the remaining ≈1.6 s is serialization + Redis
pipeline overhead, which we fold into ``per_embedding_overhead``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    bandwidth_bytes_per_s: float = 125e6      # 1 Gbps
    rpc_overhead_s: float = 1.5e-3            # per round-trip (LAN + Redis)
    per_embedding_overhead_s: float = 6.0e-6  # ser/deser + pipeline cost
    bytes_per_scalar: float = 4               # fp32 wire default (no codec)

    def embedding_bytes(self, n: int, hidden: int, layers: int,
                        *, bytes_per_scalar: float | None = None) -> int:
        """Wire bytes for n embeddings × layers tables.  The exchange
        subsystem's codecs drive ``bytes_per_scalar`` (e.g. int8 rows pay
        1 B/scalar + an amortized 4 B/row scale); default is the model's
        own fp32 value."""
        bps = self.bytes_per_scalar if bytes_per_scalar is None \
            else bytes_per_scalar
        return int(round(n * hidden * layers * bps))

    def transfer_time(self, n_embeddings: int, hidden: int, layers: int,
                      *, n_rpcs: int = 1,
                      bytes_per_scalar: float | None = None) -> float:
        """Time for a batched+pipelined transfer of n embeddings ×
        ``layers`` embedding-table namespaces."""
        if n_embeddings <= 0:
            return 0.0
        wire = self.embedding_bytes(n_embeddings, hidden, layers,
                                    bytes_per_scalar=bytes_per_scalar) \
            / self.bandwidth_bytes_per_s
        return wire + n_rpcs * self.rpc_overhead_s \
            + n_embeddings * layers * self.per_embedding_overhead_s

    def model_transfer_time(self, n_params: int) -> float:
        """Client↔aggregation-server model exchange (one direction)."""
        return n_params * self.bytes_per_scalar / self.bandwidth_bytes_per_s \
            + self.rpc_overhead_s


@dataclasses.dataclass
class TransferLog:
    """Accumulated traffic statistics for one phase/entity."""
    bytes: int = 0
    rpcs: int = 0
    embeddings: int = 0
    seconds: float = 0.0

    def add(self, *, bytes: int = 0, rpcs: int = 0, embeddings: int = 0,
            seconds: float = 0.0) -> None:
        self.bytes += bytes
        self.rpcs += rpcs
        self.embeddings += embeddings
        self.seconds += seconds
