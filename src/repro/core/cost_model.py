"""Network cost model for the federated runtime.

The container is CPU-only with no real cluster, so *compute* is measured
(wall-clock of the jitted steps) while *network* is modelled after the
paper's testbed: clients and the embedding/aggregation servers connected
by 1 Gbps Ethernet, Redis-style batched+pipelined RPCs (§5.1–5.2).  Both
components are recorded separately in every RoundStats so the modelling
assumption is auditable.

Calibration targets from the paper (§5.4): pushing ≈100k embeddings takes
≈1.8 s on Reddit/GraphConv (hidden=32 ⇒ 128 B payload/embedding/layer,
2 layers shared for L=3) — 100k · 2 · 128 B = 25.6 MB ⇒ ≈0.2 s of pure
wire time on 1 Gbps; the remaining ≈1.6 s is serialization + Redis
pipeline overhead, which we fold into ``per_embedding_overhead``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    bandwidth_bytes_per_s: float = 125e6      # 1 Gbps
    rpc_overhead_s: float = 1.5e-3            # per round-trip (LAN + Redis)
    per_embedding_overhead_s: float = 6.0e-6  # ser/deser + pipeline cost
    bytes_per_scalar: float = 4               # fp32 wire default (no codec)

    def embedding_bytes(self, n: int, hidden: int, layers: int,
                        *, bytes_per_scalar: float | None = None) -> int:
        """Wire bytes for n embeddings × layers tables.  The exchange
        subsystem's codecs drive ``bytes_per_scalar`` (e.g. int8 rows pay
        1 B/scalar + an amortized 4 B/row scale); default is the model's
        own fp32 value."""
        bps = self.bytes_per_scalar if bytes_per_scalar is None \
            else bytes_per_scalar
        return int(round(n * hidden * layers * bps))

    def transfer_time(self, n_embeddings: int, hidden: int, layers: int,
                      *, n_rpcs: int = 1,
                      bytes_per_scalar: float | None = None) -> float:
        """Time for a batched+pipelined transfer of n embeddings ×
        ``layers`` embedding-table namespaces."""
        if n_embeddings <= 0:
            return 0.0
        wire = self.embedding_bytes(n_embeddings, hidden, layers,
                                    bytes_per_scalar=bytes_per_scalar) \
            / self.bandwidth_bytes_per_s
        return wire + n_rpcs * self.rpc_overhead_s \
            + n_embeddings * layers * self.per_embedding_overhead_s

    def model_transfer_time(self, n_params: int, *,
                            bytes_per_scalar: float | None = None) -> float:
        """Client↔aggregation-server model exchange (one direction).

        ``bytes_per_scalar`` makes the weight wire codec-aware, same as
        :meth:`embedding_bytes`: the coordinator passes the *effective*
        bytes/param of what it actually framed (int8 deltas ≈ 1 B/param
        + per-leaf scales), so the modelled ledger tracks the measured
        one across weight codecs; default is the raw fp32 value."""
        bps = self.bytes_per_scalar if bytes_per_scalar is None \
            else bytes_per_scalar
        return n_params * bps / self.bandwidth_bytes_per_s \
            + self.rpc_overhead_s


@dataclasses.dataclass
class TransferLog:
    """Accumulated traffic statistics for one phase/entity.

    ``seconds`` is always the *modelled* time.  Transports that move
    real bytes (TcpTransport) additionally accumulate the measured wall
    time of the same RPCs into ``measured_seconds``, so the two can be
    compared on one ledger; purely modelled transports leave it 0."""
    bytes: int = 0
    rpcs: int = 0
    embeddings: int = 0
    seconds: float = 0.0
    measured_seconds: float = 0.0

    def add(self, *, bytes: int = 0, rpcs: int = 0, embeddings: int = 0,
            seconds: float = 0.0, measured_seconds: float = 0.0) -> None:
        self.bytes += bytes
        self.rpcs += rpcs
        self.embeddings += embeddings
        self.seconds += seconds
        self.measured_seconds += measured_seconds


def fit_network_model(samples, *, base: NetworkModel | None = None,
                      relative: bool = False) -> NetworkModel:
    """Least-squares calibration of the analytic wire model from
    measured RPCs.

    ``samples`` is an iterable of ``(payload_bytes, n_rpcs,
    n_embeddings, measured_seconds)`` rows (e.g. unpacked from
    :class:`repro.exchange.socket_transport.RpcSample`).  Fits

        t  ≈  bytes / bandwidth + rpcs · rpc_overhead
              + embeddings · per_embedding_overhead

    with all three coefficients constrained non-negative (a negative
    unconstrained coefficient is dropped and the rest refit — a tiny
    active-set pass, fine for 3 columns).  ``relative=True`` weights
    each row by 1/t, minimising *relative* residuals so small RPCs are
    not drowned out by large ones.

    Identifiability caveats: with a fixed codec and hidden size, bytes
    and embeddings are collinear — vary the hidden size in the sweep,
    as ``benchmarks/bench_wire.py`` does.  Fit one model per codec:
    codec encode/decode cost is real per-embedding serialisation work
    (§5.4 folds it into ``per_embedding_overhead``), and it differs per
    codec, so a shared fit across codecs is mis-specified.

    Returns a :class:`NetworkModel` carrying the fitted parameters
    (``bytes_per_scalar`` copied from ``base``/default: the codec, not
    the link, decides it).
    """
    import numpy as np

    rows = [(float(b), float(r), float(e), float(t))
            for b, r, e, t in samples]
    if len(rows) < 3:
        raise ValueError(f"need >= 3 samples to fit 3 parameters, "
                         f"got {len(rows)}")
    A = np.array([[b, r, e] for b, r, e, _ in rows])
    y = np.array([t for *_, t in rows])
    if relative:
        w = 1.0 / np.maximum(y, 1e-12)
        A = A * w[:, None]
        y = y * w
    active = [0, 1, 2]
    coef = np.zeros(3)
    while active:
        sol, *_ = np.linalg.lstsq(A[:, active], y, rcond=None)
        if (sol >= 0).all():
            coef[:] = 0.0
            coef[active] = sol
            break
        active = [c for c, v in zip(active, sol) if v >= 0]
    base = base or NetworkModel()
    inv_bw, rpc_oh, emb_oh = coef
    return NetworkModel(
        bandwidth_bytes_per_s=(1.0 / inv_bw) if inv_bw > 0 else float("inf"),
        rpc_overhead_s=float(rpc_oh),
        per_embedding_overhead_s=float(emb_oh),
        bytes_per_scalar=base.bytes_per_scalar,
    )
