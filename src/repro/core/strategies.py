"""Declarative strategy configurations (paper §5.2 notation).

D    — default federated GNN, no embedding exchange (P_0).
E    — EmbC baseline: full expansion, blocking pull/push each round.
O    — E + push overlap (§4.2).
P    — E + uniform random pruning with retention limit (§4.1.1).
OP   — O + P.
OPP  — OP + scored pull pre-fetch (§4.3).
OPG  — OP + score-based graph pruning to top-f% (§4.1.2).

All knobs are explicit so ablations (P_i sweeps, Tf sweeps, R25/B25/D25)
are just constructor calls.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    use_embeddings: bool = True            # False ⇒ default federated GNN
    overlap_push: bool = False             # §4.2
    retention_limit: Optional[int] = None  # §4.1.1 P_i; None = P_inf
    scored_prune_frac: Optional[float] = None  # §4.1.2 top-f%; None = off
    prefetch_frac: Optional[float] = None  # §4.3 x%; None = pull-all upfront
    score_kind: str = "frequency"          # frequency | degree | bridge
    random_subset: bool = False            # R25-style ablation selector
    # Measured contention: concurrent push slows the final epoch (paper
    # reports +14–32%, Papers +80s).  Applied when overlap_push is on.
    overlap_interference: float = 1.18
    # -- exchange subsystem (repro.exchange) --------------------------------
    codec: str = "fp32"                    # wire codec: fp32 | fp16 | int8
    delta_threshold: Optional[float] = None  # τ delta pushes; None = full
    num_server_shards: int = 1             # hashed embedding-server shards
    # transport kind: auto | inprocess | sharded | tcp.  "auto" infers
    # from num_server_shards / the trainer's transport_addrs; "tcp"
    # needs live embed_server listeners (repro.launch.embed_server) and
    # the trainer's transport_addrs pointing at them.
    transport: str = "auto"
    # -- embedding-shard placement (ShardedTransport) ------------------------
    # hash — static gid % S (historical).  pull_frequency — after round
    # `rebalance_round` the transport re-places rows by observed per-gid
    # pull counts (greedy LPT onto the least-loaded shard), falling back
    # to hash placement for unseen ids or when no pulls were logged.
    shard_placement: str = "hash"
    rebalance_round: int = 1
    # EF-SGD style error feedback: accumulate the codec quantization
    # residual client-side and fold it into the next push, so lossy
    # codecs (fp16/int8) stop biasing converged embeddings.
    error_feedback: bool = False
    # -- adaptive τ (delta_threshold schedule) ------------------------------
    # constant — τ fixed at delta_threshold every round (historical)
    # linear   — τ ramps 0 → delta_threshold over delta_rounds rounds
    #            (push everything early, when embeddings move fast)
    # plateau  — τ = 0 until the best accuracy stops improving by more
    #            than plateau_eps over plateau_window rounds, then
    #            delta_threshold
    delta_schedule: str = "constant"
    delta_rounds: int = 10
    plateau_window: int = 3
    plateau_eps: float = 2e-3
    # -- control plane (repro.fedsvc) ---------------------------------------
    # aggregation: sync — barriered FedAvg, bit-compatible with the
    # in-process run_round; async — FedBuff-style buffered aggregation:
    # the coordinator folds every `buffer_size` client deltas into the
    # global model, each scaled by staleness_decay ** staleness.
    aggregation: str = "sync"
    buffer_size: int = 2
    staleness_decay: float = 0.5
    # -- weight-wire compression (coordinator ↔ worker model exchange) ------
    # None — raw fp32 full leaves both directions (bit-compatible with
    # the in-process trainer).  "fp32" | "fp16" | "int8" — the exchange
    # codec stack applied to the *weight* plane: worker→coordinator
    # updates ship codec-encoded deltas (local − base) with per-client
    # error-feedback residual carry, and coordinator→worker get_model
    # serves version-diff deltas against the worker's last-served view
    # (full model only on first fetch / re-join).
    weight_codec: Optional[str] = None
    weight_error_feedback: bool = True     # EF on the weight deltas
    # -- coordinator-driven client sampling (sync rounds) -------------------
    # FedBuff-style per-round participation: each sync round the
    # coordinator samples ceil(sample_frac·K) clients (min 1) and the
    # pull barrier + FedAvg trigger consider only the sampled subset;
    # unsampled workers skip straight to the next round's get_model.
    # None — every client participates every round (historical).
    sample_frac: Optional[float] = None
    # -- dynamic graphs (repro.dyngraph) ------------------------------------
    # restream: scoring used when growth events admit new vertices into
    # the existing partition — "ldg" (capacity-penalised affinity) or
    # "fennel" (α·γ·|P|^{γ−1} marginal-cost).  restream_passes: warm
    # re-assignment passes over *all* vertices after each event (0 =
    # admit-only, the single-pass incremental baseline).
    restream: str = "ldg"
    restream_passes: int = 0

    def delta_for_round(self, round_idx: int,
                        accuracies: Sequence[float] = ()) -> Optional[float]:
        """τ in effect for ``round_idx`` given accuracies of *finished*
        rounds — the adaptive-τ schedule (ROADMAP follow-up)."""
        if self.delta_threshold is None:
            return None
        if self.delta_schedule == "constant":
            return self.delta_threshold
        if self.delta_schedule == "linear":
            frac = min(1.0, round_idx / max(1, self.delta_rounds))
            return self.delta_threshold * frac
        if self.delta_schedule == "plateau":
            w = self.plateau_window
            if len(accuracies) < w + 1:
                return 0.0
            recent = max(accuracies[-w:])
            before = max(accuracies[:-w])
            return self.delta_threshold \
                if recent - before < self.plateau_eps else 0.0
        raise ValueError(
            f"unknown delta_schedule {self.delta_schedule!r}; "
            "expected constant | linear | plateau")

    def describe(self) -> str:
        bits = [self.name]
        if not self.use_embeddings:
            bits.append("no-embeddings")
        if self.codec != "fp32":
            bits.append(self.codec)
        if self.delta_threshold is not None:
            bits.append(f"delta_tau={self.delta_threshold:g}")
            if self.delta_schedule != "constant":
                bits.append(f"tau_sched={self.delta_schedule}")
        if self.error_feedback:
            bits.append("ef")
        if self.aggregation != "sync":
            bits.append(f"agg={self.aggregation}"
                        f"(m={self.buffer_size},"
                        f"decay={self.staleness_decay:g})")
        if self.weight_codec is not None:
            ef = "+ef" if self.weight_error_feedback else ""
            bits.append(f"wcodec={self.weight_codec}{ef}")
        if self.sample_frac is not None:
            bits.append(f"sample={self.sample_frac:g}")
        if self.num_server_shards > 1:
            bits.append(f"shards={self.num_server_shards}")
        if self.shard_placement != "hash":
            bits.append(f"place={self.shard_placement}")
        if self.transport != "auto":
            bits.append(f"wire={self.transport}")
        if self.retention_limit is not None:
            bits.append(f"P_{self.retention_limit}")
        if self.scored_prune_frac is not None:
            sel = "R" if self.random_subset else "T"
            bits.append(f"{sel}{int(self.scored_prune_frac * 100)}:{self.score_kind}")
        if self.prefetch_frac is not None:
            bits.append(f"prefetch_x={int(self.prefetch_frac * 100)}%")
        if self.overlap_push:
            bits.append("overlap")
        if self.restream != "ldg":
            bits.append(f"restream={self.restream}")
        if self.restream_passes:
            bits.append(f"repass={self.restream_passes}")
        return " ".join(bits)


def default_strategies(*, retention: int = 4, f: float = 0.25,
                       x: float = 0.25) -> dict[str, Strategy]:
    """The seven strategies of Figs. 6–9 with paper-default knobs
    (P_4 for uniform pruning, f=x=25%)."""
    return {
        "D": Strategy("D", use_embeddings=False),
        "E": Strategy("E"),
        "O": Strategy("O", overlap_push=True),
        "P": Strategy("P", retention_limit=retention),
        "OP": Strategy("OP", overlap_push=True, retention_limit=retention),
        "OPP": Strategy("OPP", overlap_push=True, retention_limit=retention,
                        prefetch_frac=x),
        "OPG": Strategy("OPG", overlap_push=True, retention_limit=retention,
                        scored_prune_frac=f),
    }
