"""Federated GNN training runtime (paper §3) with OptimES strategies (§4).

One process simulates the cross-silo deployment: K client shards train in
(logical) parallel; the aggregation server FedAvg-aggregates; the
remote-embedding exchange subsystem (repro.exchange: wire codec × delta
pushes × transport shards, per Strategy knobs) mediates every pull /
push / prefetch / dynamic-pull against the embedding store.  Compute is
*measured* (wall clock of jitted steps); network is *modelled* by
:class:`NetworkModel` — recorded separately per phase, so every paper
figure can be regenerated.

Numerical faithfulness notes:
  * The embedding server's content is static within a round (clients pull
    previous-round values).  Prefetch (§4.3) therefore changes only the
    *timing*, never the numerics — we fill the client cache at round start
    and account pull time per-strategy.  Pruning and overlap DO change
    numerics and are implemented numerically (smaller expanded subgraph;
    stale epoch-(ε−1) push embeddings).  Lossy wire codecs (fp16/int8)
    and τ>0 delta pushes also change numerics — by design, both
    directions of the wire are honest.  Transport sharding never does
    (row-independent codecs).
  * Round wall time = max over clients (they run in parallel silos)
    + aggregation/validation (~100 ms in the paper; we measure ours).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # break the repro.exchange → repro.core import cycle
    from repro.exchange import ExchangeClient, PushPlan

from repro.fedsvc.aggregation import fedavg_leaves
from repro.graphs.graph import Graph
from repro.graphs.partition import (ClientShard, bfs_partition,
                                    make_client_shards)
from repro.graphs.sampler import NeighborSampler
from repro.models import gnn
from repro.obsv.trace import TRACE
from repro.optim import Optimizer, adam

from .cost_model import NetworkModel
from .pruning import score_remote_nodes, top_fraction
from .strategies import Strategy


@dataclasses.dataclass
class PhaseTimes:
    pull: float = 0.0
    train: float = 0.0
    dynamic_pull: float = 0.0   # §4.3 on-demand pulls (hatched blue stack)
    push_compute: float = 0.0
    push_transfer: float = 0.0
    agg: float = 0.0

    def client_total(self, *, overlap: bool, interference: float,
                     epochs: int) -> float:
        """Wall time for one client's round under the §4.2 timeline."""
        push = self.push_compute + self.push_transfer
        train = self.train + self.dynamic_pull
        if overlap and epochs >= 2:
            last_epoch = train / epochs
            head = train - last_epoch
            return self.pull + head + max(last_epoch * interference, push)
        return self.pull + train + push


@dataclasses.dataclass
class ClientRoundResult:
    """One client's share of a federated round — the unit of work the
    in-process simulator and the fedsvc worker process both execute
    (via :meth:`FederatedGNNTrainer.client_round`)."""
    client_id: int
    params: object                           # locally trained pytree
    phases: PhaseTimes
    rpc_sizes: list[int]                     # dynamic-pull RPC sizes
    push_plan: Optional["PushPlan"]          # priced, not yet applied
    weight: float                            # FedAvg weight (train verts)
    loss: float
    client_time: float                       # modelled §4.2 wall time


@dataclasses.dataclass
class RoundStats:
    round_idx: int
    accuracy: float
    round_time: float
    cum_time: float
    phases: PhaseTimes                       # max over clients per phase
    pull_rpc_sizes: list[int]                # nodes per dynamic-pull RPC
    embeddings_stored: int
    train_loss: float


def time_to_accuracy(stats: list[RoundStats], target: float,
                     *, smooth: int = 5) -> Optional[float]:
    """Cumulative time when the ``smooth``-round moving average accuracy
    first reaches ``target`` (paper §5.2 metric)."""
    accs = [s.accuracy for s in stats]
    for i in range(len(accs)):
        lo = max(0, i - smooth + 1)
        if np.mean(accs[lo: i + 1]) >= target:
            return stats[i].cum_time
    return None


def peak_accuracy(stats: list[RoundStats]) -> float:
    return max(s.accuracy for s in stats) if stats else 0.0


def sampled_eval_vertices(g, max_edges: int, seed: int) -> np.ndarray:
    """Seeded uniform vertex sample whose in-edge mass fits ``max_edges``.

    The unbiased replacement for the old vertex-*prefix* fallback: a
    prefix inherits whatever ordering the store was built with (RMAT
    hubs first, SBM blocks contiguous), so prefix accuracy estimates a
    different population than the full graph.  A uniform permutation
    prefix estimates the same one.  Always returns ≥ 1 vertex, sorted
    ascending."""
    deg = np.diff(np.asarray(g.indptr))
    rng = np.random.default_rng((seed, 104729))
    perm = rng.permutation(g.num_vertices)
    k = int(np.searchsorted(np.cumsum(deg[perm]), max_edges, side="right"))
    return np.sort(perm[: max(1, k)]).astype(np.int64)


def eval_arrays_for(g, sel: np.ndarray) -> dict:
    """``full_propagate`` inputs over the subgraph induced by the sorted
    vertex selection ``sel`` (edges with both endpoints selected, ids
    remapped to positions in ``sel``).  With ``sel == arange(V)`` this
    reproduces the exact full-graph arrays bit-for-bit."""
    indptr = np.asarray(g.indptr)
    starts = indptr[sel]
    counts = (indptr[sel + 1] - starts).astype(np.int64)
    total = int(counts.sum())
    # CSR range-gather: positions of every selected vertex's in-edges
    offsets = np.zeros(len(sel) + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    pos = (np.arange(total, dtype=np.int64)
           - np.repeat(offsets[:-1], counts) + np.repeat(starts, counts))
    e_src = np.asarray(g.indices[pos], dtype=np.int64)
    e_dst = np.repeat(np.arange(len(sel), dtype=np.int64), counts)
    # drop edges whose source is outside the selection, remap the rest
    loc = np.minimum(np.searchsorted(sel, e_src), len(sel) - 1)
    keep = sel[loc] == e_src
    return {
        "edge_src": jnp.asarray(loc[keep], jnp.int32),
        "edge_dst": jnp.asarray(e_dst[keep], jnp.int32),
        "src_is_remote": jnp.zeros(int(keep.sum()), bool),
        "num_local": len(sel),
        "features": jnp.asarray(np.asarray(g.features[sel]), jnp.float32),
    }


class FederatedGNNTrainer:
    def __init__(
        self,
        graph: Graph,
        num_clients: int,
        strategy: Strategy,
        *,
        conv: str = "graphconv",
        num_layers: int = 3,
        hidden: int = 32,
        fanout: int = 5,
        batch_size: int = 64,
        epochs_per_round: int = 3,
        lr: float = 1e-2,
        optimizer: Optimizer | None = None,
        net: NetworkModel | None = None,
        shard_nets: list[NetworkModel] | None = None,
        transport_addrs: list | None = None,
        seed: int = 0,
        part: np.ndarray | None = None,
        shards: list[ClientShard | None] | None = None,
        only_clients: list[int] | None = None,
        eval_max_edges: int = 4_000_000,
        growth=None,
    ):
        self.g = graph
        self.k = num_clients
        self.strategy = strategy
        self.conv = conv
        self.L = num_layers
        self.hidden = hidden
        self.fanout = fanout
        self.batch_size = batch_size
        self.epochs = epochs_per_round
        self.lr = lr
        self.opt = optimizer or adam(lr)
        self.net = net or NetworkModel()
        # heterogeneous per-shard links (ShardedTransport); default: the
        # trainer-wide NetworkModel replicated per shard
        self.shard_nets = shard_nets
        # live embed_server listeners, one per shard (Strategy.transport
        # = "tcp", or inferred when addresses are given)
        self.transport_addrs = transport_addrs
        self.seed = seed
        # shard-local mode (fedsvc workers): build samplers / caches /
        # exchange registrations only for the owned clients; with
        # prebuilt ``shards`` (an mmap store's shard dir) the graph is
        # never re-scanned either.
        self.only_clients = None if only_clients is None \
            else sorted(int(c) for c in only_clients)
        self._prebuilt_shards = shards
        self.eval_max_edges = eval_max_edges
        # dynamic-graph runtime (repro.dyngraph.GrowthRuntime-shaped):
        # apply_growth() advances it between rounds and rebuilds every
        # shard-derived structure when the graph jumps.
        self.growth = growth
        self._growth_round = 0        # round of the last graph jump
        self._growth_accs_base = 0    # pre-jump accuracies to ignore (τ)
        if part is None:
            if getattr(graph, "is_store", False):
                # out-of-core plane: single-pass streaming LDG instead
                # of the O(V)-frontier BFS grow
                from repro.graphstore import ldg_partition
                part = ldg_partition(graph, num_clients, seed=seed)
            else:
                part = bfs_partition(graph, num_clients, seed=seed)
        self.part = part
        self._setup()

    # -- setup ----------------------------------------------------------------

    def _client_rng(self, ci: int, salt: int) -> np.random.Generator:
        """Per-(client, purpose) generator for the R25-style random
        subset draws: seeded independently of build order, so a
        shard-local worker (only_clients=...) draws the same subsets as
        the full in-process trainer."""
        return np.random.default_rng((self.seed, salt, ci))

    def _build_shards(self, limit, retained_remote=None
                      ) -> list[ClientShard]:
        """Shard extraction, dispatched per graph plane: streaming over
        an mmap store, materialized for an in-memory Graph — outputs are
        bit-identical (gated in tests/test_graphstore.py)."""
        from repro.graphstore import build_client_shards
        return build_client_shards(
            self.g, self.part, retention_limit=limit,
            retained_remote=retained_remote, seed=self.seed)

    def _setup(self) -> None:
        st = self.strategy
        self.owned = list(range(self.k)) if self.only_clients is None \
            else self.only_clients
        self._registered = np.zeros(0, np.int64)  # gids exchange knows
        self._build_shard_state()
        shards = self.shards

        # remote-embedding exchange: transport (embedding server shard(s)
        # behind modelled links) + one codec/delta-aware client per silo
        from repro.exchange import ExchangeClient, make_transport
        if st.shard_placement not in ("hash", "pull_frequency"):
            raise ValueError(
                f"unknown shard_placement {st.shard_placement!r}; "
                "expected hash | pull_frequency")
        if st.use_embeddings:
            self.exchange = make_transport(
                self.L, self.hidden, kind=st.transport,
                num_shards=st.num_server_shards,
                nets=self.shard_nets if self.shard_nets is not None
                else self.net,
                addrs=self.transport_addrs, codec=st.codec)
            if st.shard_placement == "pull_frequency":
                if not hasattr(self.exchange, "rebalance_by_pulls"):
                    raise ValueError(
                        "shard_placement='pull_frequency' needs the "
                        "sharded in-process transport (num_server_shards "
                        "> 1, transport != 'tcp'): "
                        f"{type(self.exchange).__name__} cannot migrate "
                        "rows")
                self.exchange.track_pulls = True
            self.ex_clients: list[ExchangeClient | None] = [
                None if shards[ci] is None else
                ExchangeClient(self.exchange, st.codec,
                               delta_threshold=st.delta_threshold,
                               error_feedback=st.error_feedback)
                for ci in range(self.k)
            ]
        else:
            self.exchange = None
            self.ex_clients = [None] * self.k
        self._register_shard_nodes()
        self._build_client_state()
        self._build_eval_state()

        # model + jitted train step
        self.params = gnn.init_gnn(jax.random.PRNGKey(self.seed), self.conv,
                                   self.g.feat_dim, self.hidden,
                                   self.g.num_classes, self.L)
        opt = self.opt

        def _step(params, opt_state, batch, features, caches, labels):
            loss, grads = jax.value_and_grad(
                functools.partial(gnn.loss_fn, conv=self.conv))(
                    params, batch, features, caches, labels)
            params, opt_state = opt.step(params, grads, opt_state)
            return params, opt_state, loss

        self._train_step = jax.jit(_step)
        self._treedef = jax.tree_util.tree_structure(self.params)
        self.acc_history: list[float] = []   # finished-round accuracies

    def _build_shard_state(self) -> None:
        """Everything derived from (graph, part): shards, reciprocal
        push sets, push-row indices, prefetch sets.  Re-run after each
        graph growth jump."""
        st = self.strategy
        limit = 0 if not st.use_embeddings else st.retention_limit
        if self._prebuilt_shards is not None:
            # prebuilt (mmap'd) shards: a worker never re-scans the
            # graph.  Score-based pruning still applies, shard-locally.
            shards = list(self._prebuilt_shards)
            if st.use_embeddings and st.scored_prune_frac is not None:
                from repro.graphs.partition import filter_shard_remote
                for ci in self.owned:
                    sh = shards[ci]
                    scores = score_remote_nodes(sh, st.score_kind, self.L)
                    keep = top_fraction(scores, st.scored_prune_frac,
                                        rng=self._client_rng(ci, 1),
                                        random_subset=st.random_subset)
                    shards[ci] = filter_shard_remote(
                        sh, sh.pull_nodes[keep])
        else:
            # NOTE: without prebuilt shards every client's shard is
            # extracted (the reciprocal push recompute below needs all
            # pull sets), so this fallback holds O(E) shard edges even
            # under only_clients — bake shards with launch/build_store
            # for stores where that matters.
            shards = self._build_shards(limit)

            # score-based pruning (§4.1.2): keep top-f% pull nodes per
            # client, scored on the (retention-pruned) expanded subgraph.
            # Same seed ⇒ the same retention edges survive before the set
            # filter applies.
            if st.use_embeddings and st.scored_prune_frac is not None:
                retained2 = {}
                for sh in shards:
                    scores = score_remote_nodes(sh, st.score_kind, self.L)
                    keep = top_fraction(scores, st.scored_prune_frac,
                                        rng=self._client_rng(sh.client_id, 1),
                                        random_subset=st.random_subset)
                    retained2[sh.client_id] = sh.pull_nodes[keep]
                shards = self._build_shards(limit, retained_remote=retained2)
        self.shards = shards

        # push sets follow the *retained* pull sets: client k pushes exactly
        # the nodes other clients retained (pruning shrinks pushes, §4.1.1).
        # Possible only when every shard is visible; a shard-local worker
        # keeps the reciprocal sets stored at shard-build time (a superset
        # under scored pruning — extra pushed rows are simply never read).
        part = self.part
        if all(sh is not None for sh in shards):
            for sh in shards:
                wanted = [
                    other.pull_nodes[part[other.pull_nodes] == sh.client_id]
                    for other in shards if other.client_id != sh.client_id]
                sh.push_nodes = np.unique(np.concatenate(wanted)) \
                    if wanted else np.zeros(0, np.int64)

        # push-node local-row indices, hoisted: both push paths
        # (pretrain_round, _compute_push) used to rebuild the
        # global→local dict per client per round, O(num_local) each time.
        self.push_rows: list[np.ndarray | None] = [None] * self.k
        for ci in self.owned:
            sh = shards[ci]
            g2l = {int(g): i
                   for i, g in enumerate(sh.global_ids[:sh.num_local])}
            self.push_rows[ci] = \
                np.fromiter((g2l[int(g)] for g in sh.push_nodes),
                            np.int64, len(sh.push_nodes))

        # prefetch scores (§4.3) on the final expanded shard
        self.prefetch_sets: list[np.ndarray | None] = [None] * self.k
        for ci in self.owned:
            sh = shards[ci]
            if st.use_embeddings and st.prefetch_frac is not None:
                scores = score_remote_nodes(sh, st.score_kind, self.L)
                idx = top_fraction(scores, st.prefetch_frac,
                                   rng=self._client_rng(ci, 2),
                                   random_subset=st.random_subset)
            else:
                idx = np.arange(len(sh.pull_nodes))
            self.prefetch_sets[ci] = idx

    def _register_shard_nodes(self) -> None:
        """Register the owned shards' pull/push sets with the exchange.

        Registration is idempotent server-side (the capacity-doubling
        table keeps existing rows), so after a growth jump only the
        genuinely new boundary vertices matter — those are counted into
        the growth runtime's boundary-registration metric."""
        if self.exchange is None:
            return
        fresh = 0
        for ci in self.owned:
            sh = self.shards[ci]
            for gids in (sh.pull_nodes, sh.push_nodes):
                if self.growth is not None and len(gids):
                    fresh += len(np.setdiff1d(gids, self._registered))
                    self._registered = np.union1d(self._registered, gids)
                self.exchange.register(gids)
        if self.growth is not None and fresh:
            self.growth.record_boundary(fresh)

    def _build_client_state(self) -> None:
        """Per-client training state over the current shards: samplers,
        device arrays, embedding caches."""
        shards = self.shards
        self.samplers: list[NeighborSampler | None] = [None] * self.k
        self.shard_arrays: list[dict | None] = [None] * self.k
        self.feats = [None] * self.k
        self.labels = [None] * self.k
        for ci in self.owned:
            sh = shards[ci]
            self.samplers[ci] = NeighborSampler(
                sh, self.fanout, self.L, self.batch_size, seed=self.seed)
            self.shard_arrays[ci] = gnn.shard_to_arrays(sh)
            self.feats[ci] = jnp.asarray(sh.features, jnp.float32)
            self.labels[ci] = jnp.asarray(sh.labels, jnp.int32)
        self._caches: list[list[jnp.ndarray] | None] = [
            None if sh is None else
            [jnp.zeros((max(1, sh.num_remote), self.hidden), jnp.float32)
             for _ in range(self.L - 1)]
            for sh in shards
        ]

    def _build_eval_state(self) -> None:
        # global eval graph (aggregation server's held-out test set):
        # full-neighbourhood forward over the whole graph — or, past
        # ``eval_max_edges``, over a seeded uniform vertex sample whose
        # induced edges fit the budget (the unbiased estimator for
        # million-vertex stores; the old vertex-prefix fallback skewed
        # toward whatever the store's build order put first).
        # Shard-local workers never evaluate and skip the arrays.
        if self.only_clients is None:
            if self.g.num_edges > self.eval_max_edges:
                sel = sampled_eval_vertices(self.g, self.eval_max_edges,
                                            self.seed)
            else:
                sel = np.arange(self.g.num_vertices, dtype=np.int64)
            self.eval_gids = sel
            self.eval_arrays = eval_arrays_for(self.g, sel)
            self.test_idx = np.nonzero(
                ~np.asarray(self.g.train_mask[sel]))[0]
        else:
            self.eval_gids = None
            self.eval_arrays = None
            self.test_idx = None

    # -- dynamic graphs (repro.dyngraph) ---------------------------------------

    def apply_growth(self, epoch: int,
                     round_idx: int | None = None) -> bool:
        """Advance the growth runtime to ``epoch`` and, if the graph
        jumped, swap in the merged view and rebuild every shard-derived
        structure (shards, push sets, samplers, caches, eval sample).
        Model params and the exchange survive — only the *new* boundary
        vertices are registered (the server's capacity-doubling path).
        ``round_idx`` stamps the jump so the plateau-τ schedule restarts
        from it.  → True when anything changed."""
        if self.growth is None:
            return False
        if not self.growth.advance_to(epoch, part=self.part):
            return False
        self.g = self.growth.graph
        self.part = self.growth.part
        if round_idx is not None:
            self._growth_round = int(round_idx)
            self._growth_accs_base = int(round_idx)
        self._refresh_after_growth()
        return True

    def _refresh_after_growth(self) -> None:
        self._prebuilt_shards = None    # extracted pre-growth: stale
        self._build_shard_state()
        self._register_shard_nodes()
        self._build_client_state()
        self._build_eval_state()

    # -- params <-> leaves (fedsvc control plane) ------------------------------

    def params_leaves(self, params=None) -> list[np.ndarray]:
        """Flat numpy leaves of ``params`` (default: the global model),
        in canonical tree_flatten order — the coordinator wire format."""
        return [np.asarray(l) for l in
                jax.tree_util.tree_leaves(
                    self.params if params is None else params)]

    def leaves_to_params(self, leaves):
        """Inverse of :meth:`params_leaves`."""
        return jax.tree_util.tree_unflatten(
            self._treedef, [jnp.asarray(l) for l in leaves])

    def set_round_tau(self, round_idx: int, accuracies=None) -> None:
        """Apply the adaptive-τ schedule (Strategy.delta_schedule) for
        this round to every client's delta tracker.  After a graph
        growth jump the schedule restarts from the jump round: linear
        warm-up re-ramps, and the plateau detector only sees post-jump
        accuracies (pre-jump plateaus don't count against a graph the
        model has never trained on)."""
        tau = self.strategy.delta_for_round(
            round_idx - self._growth_round,
            list(self.acc_history if accuracies is None
                 else accuracies)[self._growth_accs_base:])
        if tau is None:
            return
        for ex in self.ex_clients:
            if ex is not None and ex.delta is not None:
                ex.delta.tau = tau

    # -- embedding exchange helpers ---------------------------------------------

    @property
    def server(self):
        """Back-compat alias: the embedding-server side of the exchange
        (a Transport; exposes num_embeddings_stored / log / memory_bytes)."""
        return self.exchange

    def _fill_cache(self, ci: int) -> None:
        """Materialise this round's pull-node embeddings into the client
        cache (numerics; timing handled separately).  Values go through
        the wire codec, so lossy codecs shape training numerics here."""
        sh = self.shards[ci]
        if self.exchange is None or len(sh.pull_nodes) == 0:
            return
        with TRACE.span("client.pull", args={"client": ci,
                                             "rows": len(sh.pull_nodes)}):
            vals = self.ex_clients[ci].peek(sh.pull_nodes)
            pad = max(1, sh.num_remote) - sh.num_remote
            self._caches[ci] = [
                jnp.asarray(np.concatenate([
                    vals[l], np.zeros((pad, self.hidden), np.float32)]))
                if sh.num_remote else self._caches[ci][l]
                for l in range(self.L - 1)
            ]

    def _pull_time(self, ci: int, minibatches) -> tuple[float, float, list[int]]:
        """(upfront pull s, dynamic pull s, nodes-per-dynamic-RPC sizes)."""
        sh = self.shards[ci]
        st = self.strategy
        ex = self.ex_clients[ci]
        if self.exchange is None or len(sh.pull_nodes) == 0:
            return 0.0, 0.0, []
        if st.prefetch_frac is None:
            return ex.pull_cost(sh.pull_nodes), 0.0, []
        # §4.3: batched prefetch of top-x% + per-minibatch on-demand RPCs.
        pre = self.prefetch_sets[ci]
        t_pre = ex.pull_cost(sh.pull_nodes[pre])
        present = [np.zeros(sh.num_remote, bool) for _ in range(self.L - 1)]
        for p in present:
            p[pre] = True
        t_dyn, sizes = 0.0, []
        for mb in minibatches:
            miss_gids = []
            for l, used in enumerate(mb.remote_slots_used):
                miss = used[~present[l][used]]
                if len(miss):
                    # remote slot i ↔ sh.pull_nodes[i] (shard layout:
                    # global_ids = [local, pull_nodes])
                    miss_gids.append(sh.pull_nodes[miss])
                present[l][miss] = True
            if miss_gids:
                gids = np.concatenate(miss_gids)
                t_dyn += ex.dynamic_pull(gids)
                sizes.append(len(gids))
        return t_pre, t_dyn, sizes

    def _compute_push(self, ci: int, params) -> tuple[Optional[PushPlan],
                                                      float, float]:
        """Forward pass for push-node embeddings (§3.2.2 push phase).
        Returns (delta-filtered+encoded push plan, compute s, transfer s)."""
        sh = self.shards[ci]
        if self.exchange is None or len(sh.push_nodes) == 0:
            return None, 0.0, 0.0
        with TRACE.span("client.push_compute", args={"client": ci}):
            t0 = time.perf_counter()
            outs = gnn.full_propagate(params, self.shard_arrays[ci],
                                      self._caches[ci], conv=self.conv)
            jax.block_until_ready(outs)
            t_compute = time.perf_counter() - t0
            rows = self.push_rows[ci]
            vals = [np.asarray(outs[l])[rows] for l in range(self.L - 1)]
            plan = self.ex_clients[ci].plan_push(sh.push_nodes, vals)
        return plan, t_compute, plan.transfer_time

    # -- lifecycle ---------------------------------------------------------------

    def pretrain_round(self, client_ids: list[int] | None = None) -> None:
        """§3.2.1: initialise push-node embeddings on the unexpanded local
        subgraphs (remote neighbours masked) and seed the server.  A
        fedsvc worker passes its own ``client_ids`` so each process
        seeds exactly the rows it owns (push sets are disjoint across
        clients, so order never matters)."""
        if self.exchange is None:
            return
        for ci in (self.owned if client_ids is None else client_ids):
            sh = self.shards[ci]
            if len(sh.push_nodes) == 0:
                continue
            outs = gnn.full_propagate(self.params, self.shard_arrays[ci],
                                      None, conv=self.conv)
            rows = self.push_rows[ci]
            vals = [np.asarray(outs[l])[rows] for l in range(self.L - 1)]
            self.ex_clients[ci].push(sh.push_nodes, vals)

    def export_for_serving(self) -> dict:
        """Publish the trained state for the serving plane (gnnserve).

        Training only ever stores the reciprocal push-node rows; a
        query can land on *any* vertex, so this registers every owned
        shard's local vertices with the exchange and pushes their full
        h^1..h^{L-1} (full-neighbourhood propagate against the current
        caches).  Rows cross the wire through a plain
        :class:`ExchangeClient` — the codec applies and row versions
        bump, but delta shadows / error-feedback residuals are left
        untouched (serving must not perturb a resumable trainer).

        Returns the bundle ``gnnserve.engine.build_serving`` consumes.
        """
        if self.exchange is None:
            raise RuntimeError("export_for_serving needs an embedding-"
                               "sharing strategy (use_embeddings=True)")
        from repro.exchange import ExchangeClient
        pub = ExchangeClient(self.exchange, self.strategy.codec)
        for ci in self.owned:
            sh = self.shards[ci]
            self._fill_cache(ci)
            outs = gnn.full_propagate(self.params, self.shard_arrays[ci],
                                      self._caches[ci], conv=self.conv)
            gids = np.asarray(sh.global_ids[:sh.num_local], np.int64)
            pub.register(gids)
            pub.push(gids, [np.asarray(outs[l])
                            for l in range(self.L - 1)])
        return {
            "params": self.params,
            "conv": self.conv,
            "num_layers": self.L,
            "hidden": self.hidden,
            "part": np.asarray(self.part),
            "shards": {ci: self.shards[ci] for ci in self.owned},
            "transport": self.exchange,
            "codec": self.strategy.codec,
        }

    def evaluate(self, params=None) -> float:
        if self.eval_arrays is None:
            raise RuntimeError(
                "shard-local trainer (only_clients=...) has no eval "
                "graph; evaluation belongs to the coordinator")
        outs = gnn.full_propagate(
            self.params if params is None else params,
            self.eval_arrays, None, conv=self.conv)
        pred = np.asarray(jnp.argmax(outs[-1], axis=-1))
        truth = np.asarray(self.g.labels[self.eval_gids[self.test_idx]])
        return float((pred[self.test_idx] == truth).mean())

    def client_round(self, ci: int, params=None, *,
                     fill_cache: bool = True) -> ClientRoundResult:
        """One client's share of a round: cache fill (pull), sampling,
        local epochs, push planning.  The in-process :meth:`run_round`
        loops this over all clients; a fedsvc worker process runs it for
        the client(s) it owns.  The returned push plan is *not* applied
        — the caller commits it once every client has pulled (server
        static within the round, §4.2)."""
        st = self.strategy
        sh = self.shards[ci]
        p = PhaseTimes()
        if fill_cache:
            self._fill_cache(ci)
        # pre-sample the round's minibatches (sampling is part of the
        # measured train phase, like DGL's dataloader)
        t0 = time.perf_counter()
        epochs_batches = [list(self.samplers[ci].epoch())
                          for _ in range(self.epochs)]
        sample_t = time.perf_counter() - t0
        p.pull, p.dynamic_pull, sizes = self._pull_time(
            ci, [mb for ep in epochs_batches for mb in ep])

        params = self.params if params is None else params
        opt_state = self.opt.init(params)
        t_train = sample_t
        push_plan: Optional[PushPlan] = None
        loss = jnp.zeros(())
        for e, batches in enumerate(epochs_batches, start=1):
            t0 = time.perf_counter()
            with TRACE.span("client.train_epoch",
                            args={"client": ci, "epoch": e}):
                for mb in batches:
                    batch = gnn.blocks_to_arrays(mb)
                    params, opt_state, loss = self._train_step(
                        params, opt_state, batch, self.feats[ci],
                        self._caches[ci], self.labels[ci])
                jax.block_until_ready(loss)
            t_train += time.perf_counter() - t0
            if st.overlap_push and e == self.epochs - 1:
                # §4.2: stale push computed from the epoch-(ε−1) model
                push_plan, p.push_compute, p.push_transfer = \
                    self._compute_push(ci, params)
        if not st.overlap_push or self.epochs < 2:
            push_plan, p.push_compute, p.push_transfer = \
                self._compute_push(ci, params)
        p.train = t_train
        return ClientRoundResult(
            client_id=ci, params=params, phases=p, rpc_sizes=sizes,
            push_plan=push_plan,
            weight=float(len(sh.train_vertices())),
            loss=float(loss),
            client_time=p.client_total(
                overlap=st.overlap_push,
                interference=st.overlap_interference, epochs=self.epochs))

    def run_round(self, round_idx: int, cum_time: float) -> RoundStats:
        assert self.only_clients is None, \
            "run_round needs every client; shard-local trainers drive " \
            "client_round through the fedsvc control plane"
        TRACE.set_context(round=round_idx)
        self.set_round_tau(round_idx)
        # pull-frequency shard rebalancing (ROADMAP): after the first
        # round's pulls are logged, re-place hot rows across the
        # embedding-server shards by observed pull counts (LPT) —
        # numerics are untouched (row-independent codecs), only the
        # per-shard time/byte ledgers move.
        st = self.strategy
        if st.use_embeddings and st.shard_placement == "pull_frequency" \
                and round_idx == st.rebalance_round:
            self.exchange.rebalance_by_pulls()
        phases = PhaseTimes()
        all_rpc_sizes: list[int] = []

        results = [self.client_round(ci) for ci in range(self.k)]
        for res in results:
            all_rpc_sizes += res.rpc_sizes
            for name in ("pull", "train", "dynamic_pull", "push_compute",
                         "push_transfer"):
                setattr(phases, name, max(getattr(phases, name),
                                          getattr(res.phases, name)))

        # all clients pulled before anyone pushes (server is static
        # within the round) — apply the planned pushes now.
        for res in results:
            if res.push_plan is not None:
                self.ex_clients[res.client_id].apply_push(res.push_plan)

        # FedAvg + validation on the aggregation server.  The leaf-wise
        # fedavg_leaves is shared with the fedsvc coordinator, so the
        # multi-process sync path aggregates with the same float32
        # arithmetic in the same client order.
        t0 = time.perf_counter()
        with TRACE.span("round.aggregate", args={"round": round_idx}):
            weights = [res.weight for res in results]
            agg = fedavg_leaves([self.params_leaves(res.params)
                                 for res in results], weights)
            self.params = self.leaves_to_params(agg)
            acc = self.evaluate()
        t_agg = time.perf_counter() - t0 \
            + 2 * self.net.model_transfer_time(self._num_params())
        phases.agg = t_agg
        self.acc_history.append(acc)
        losses = [res.loss for res in results]

        round_time = max(res.client_time for res in results) + t_agg
        return RoundStats(
            round_idx=round_idx,
            accuracy=acc,
            round_time=round_time,
            cum_time=cum_time + round_time,
            phases=phases,
            pull_rpc_sizes=all_rpc_sizes,
            embeddings_stored=0 if self.exchange is None
            else self.exchange.num_embeddings_stored,
            train_loss=float(np.mean(losses)),
        )

    def train(self, num_rounds: int, *, verbose: bool = False
              ) -> list[RoundStats]:
        self.pretrain_round()
        stats: list[RoundStats] = []
        cum = 0.0
        for r in range(num_rounds):
            if self.growth is not None:
                self.apply_growth(self.growth.epoch_for_round(r), r)
            s = self.run_round(r, cum)
            cum = s.cum_time
            stats.append(s)
            if verbose:
                print(f"  round {r:3d} acc={s.accuracy:.4f} "
                      f"loss={s.train_loss:.3f} t={s.round_time:.3f}s "
                      f"(pull {s.phases.pull:.3f} train {s.phases.train:.3f} "
                      f"dyn {s.phases.dynamic_pull:.3f} "
                      f"push {s.phases.push_compute + s.phases.push_transfer:.3f})")
        return stats

    def _num_params(self) -> int:
        return sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(self.params))
