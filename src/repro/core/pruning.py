"""Remote-neighbourhood pruning (§4.1) and node scoring.

Two families:

* **Uniform random pruning with retention limit** ``P_i`` (§4.1.1): each
  local boundary vertex keeps at most ``i`` of its remote in-neighbours,
  chosen uniformly at random, during subgraph expansion.  ``P_0`` degrades
  to the default federated GNN (strategy D); ``P_inf`` is EmbC.

* **Score-based pruning** (§4.1.2): remote (pull) nodes are ranked and the
  top-f% retained.  Scores:
  - ``frequency``: S(v) = |{x ∈ T : v ∈ N_L(x)}| / |T| — the fraction of
    training vertices with v inside their L-hop in-neighbourhood, computed
    offline on the expanded subgraph (paths terminate at remote vertices,
    which holds structurally here because remote rows have no in-edges).
  - ``degree``: in-degree of the remote vertex as seen by this client.
  - ``bridge``: degree-based bridging coefficient × ego betweenness proxy
    (full betweenness is O(VE); the paper computes these offline too, and
    only their *ranking* matters for pruning).
"""

from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph
from repro.graphs.partition import ClientShard


# -- retention-limit pruning ------------------------------------------------

def retention_pruned_sets(
    g: Graph,
    part: np.ndarray,
    limit: int | None,
    *,
    seed: int = 0,
) -> dict[int, np.ndarray] | None:
    """Per-client retained remote vertex sets under retention limit P_i.

    Returns None for P_inf (no pruning).  Retention is per *boundary
    vertex*: each local vertex keeps ≤ limit remote in-neighbours; the
    retained set is the union.  Done offline before loading the subgraph,
    as in the paper's implementation.
    """
    if limit is None:
        return None
    rng = np.random.default_rng(seed)
    k = int(part.max()) + 1
    if limit == 0:
        return {c: np.zeros(0, np.int64) for c in range(k)}
    # Vectorized over the whole CSR: one uniform priority per edge, and
    # each boundary vertex keeps the ``limit`` remote in-neighbours with
    # the smallest priorities — uniform without replacement, selected
    # for every vertex at once instead of a per-vertex rng.choice loop
    # (the selection rule tests/test_federated.py pins against a
    # per-vertex reference with the same priorities).
    e_dst = np.repeat(np.arange(g.num_vertices), np.diff(g.indptr))
    e_src = g.indices.astype(np.int64)
    prio = rng.random(g.num_edges)
    # only boundary (remote) edges compete for retention slots
    bnd = np.nonzero(part[e_src] != part[e_dst])[0]
    e_src, e_dst, prio = e_src[bnd], e_dst[bnd], prio[bnd]
    if len(e_dst) == 0:
        return {c: np.zeros(0, np.int64) for c in range(k)}
    # CSR order survives the filter, so each destination's remote edges
    # form one contiguous run — `limit` minimum.reduceat sweeps select
    # its `limit` smallest priorities without any sort (priorities are
    # continuous, so within-run duplicates have probability zero)
    starts = np.r_[0, 1 + np.nonzero(np.diff(e_dst))[0]]
    run_of = np.zeros(len(e_dst), np.int64)
    run_of[starts] = 1
    run_of = np.cumsum(run_of) - 1
    work = prio.copy()
    keep_mask = np.zeros(len(e_dst), bool)
    for _ in range(min(limit, int(np.diff(np.r_[starts,
                                                len(e_dst)]).max()))):
        m = np.minimum.reduceat(work, starts)
        sel = (work == m[run_of]) & np.isfinite(work)
        keep_mask |= sel
        work[sel] = np.inf
    kept = np.nonzero(keep_mask)[0]
    # group survivors by client: unique (client, src) pairs in one pass
    key = part[e_dst[kept]].astype(np.int64) * g.num_vertices + e_src[kept]
    key = np.unique(key)
    cli = key // g.num_vertices
    srcs = key % g.num_vertices
    bounds = np.searchsorted(cli, np.arange(k + 1))
    return {c: srcs[bounds[c]: bounds[c + 1]] for c in range(k)}


# -- scoring ------------------------------------------------------------------

def _reach_counts(shard: ClientShard, num_hops: int) -> np.ndarray:
    """counts[v] = #train vertices with node v in their ≤num_hops
    in-neighbourhood of the expanded subgraph."""
    train = shard.train_vertices()
    n_total = len(shard.global_ids)
    t = len(train)
    if t == 0:
        return np.zeros(n_total, np.int64)
    # reach[i, v] — train vertex i reaches v in ≤ h hops (dense bool;
    # shards are ≤ tens of thousands of vertices at our scale).
    reach = np.zeros((t, n_total), dtype=bool)
    reach[np.arange(t), train] = True
    e_dst = np.repeat(np.arange(shard.num_local), np.diff(shard.indptr))
    e_src = shard.indices.astype(np.int64)
    for _ in range(num_hops):
        new = np.zeros_like(reach)
        # v reachable next hop if some u with (v -> u) edge is reachable.
        # Group edges by dst to vectorise the OR-scatter.
        np.logical_or.at(new.T, e_src, reach[:, e_dst].T)
        reach |= new
    return reach.sum(axis=0).astype(np.int64)


def frequency_scores(shard: ClientShard, num_hops: int) -> np.ndarray:
    """S(v) for each remote (pull) slot of the shard (§4.1.2)."""
    counts = _reach_counts(shard, num_hops)
    t = max(1, len(shard.train_vertices()))
    return counts[shard.num_local:] / t


def degree_scores(shard: ClientShard) -> np.ndarray:
    """In-degree centrality of remote vertices as seen locally: number of
    local vertices each remote vertex feeds into."""
    n_total = len(shard.global_ids)
    deg = np.zeros(n_total, np.int64)
    np.add.at(deg, shard.indices.astype(np.int64), 1)
    return deg[shard.num_local:].astype(np.float64)


def bridge_scores(shard: ClientShard) -> np.ndarray:
    """Bridging-coefficient proxy for bridge centrality [12].

    BrC(v) ≈ betweenness_proxy(v) × bridging_coefficient(v) with
    bridging_coefficient(v) = (1/deg v) / Σ_{n∈N(v)} 1/deg(n).  For remote
    vertices only their local star is visible, so deg(v) is the local
    in-degree and N(v) the local vertices they feed; the betweenness proxy
    is that local degree (a remote vertex bridging many local vertices to
    an unseen community scores high).  Ranking-compatible with the paper's
    offline centrality exchange.
    """
    n_total = len(shard.global_ids)
    deg = np.zeros(n_total, np.float64)
    np.add.at(deg, shard.indices.astype(np.int64), 1.0)
    local_deg = np.maximum(np.diff(shard.indptr).astype(np.float64), 1.0)
    inv_nbr_sum = np.zeros(n_total, np.float64)
    e_dst = np.repeat(np.arange(shard.num_local), np.diff(shard.indptr))
    np.add.at(inv_nbr_sum, shard.indices.astype(np.int64), 1.0 / local_deg[e_dst])
    d = np.maximum(deg, 1.0)
    bridging = (1.0 / d) / np.maximum(inv_nbr_sum, 1e-9)
    return (deg * bridging)[shard.num_local:]


def score_remote_nodes(shard: ClientShard, kind: str, num_hops: int) -> np.ndarray:
    if kind == "frequency":
        return frequency_scores(shard, num_hops)
    if kind == "degree":
        return degree_scores(shard)
    if kind == "bridge":
        return bridge_scores(shard)
    raise KeyError(f"unknown score kind {kind!r}")


def top_fraction(scores: np.ndarray, frac: float,
                 *, rng: np.random.Generator | None = None,
                 random_subset: bool = False) -> np.ndarray:
    """Indices of the top ``frac`` of scores (or a random subset of the
    same size, for the R25-style ablations)."""
    n = len(scores)
    k = int(np.ceil(frac * n))
    if k >= n:
        return np.arange(n)
    if random_subset:
        rng = rng or np.random.default_rng(0)
        return np.sort(rng.choice(n, size=k, replace=False))
    # stable top-k: break ties by index for determinism
    order = np.lexsort((np.arange(n), -scores))
    return np.sort(order[:k])
