"""In-memory embedding server (the paper's Redis KV store).

Stores the h^1..h^{L-1} embeddings of every registered boundary vertex in
one table ("database" in the paper's Redis terms) per layer, keyed by
global vertex id.  Clients interact through batched ``push``/``pull``
calls whose network cost is accounted by a :class:`NetworkModel` — get/set
RPCs are batched + pipelined exactly as §5.1 describes.

The server is honest-but-curious: it only ever sees (vertex id →
embedding vector); raw features (h^0) are never registered.

The exchange subsystem (repro.exchange) uses the *storage* surface only
(``register``/``write``/``gather``) and does its own codec-aware wire
accounting per transport shard; the classic ``push``/``pull`` RPC surface
remains for direct single-server use.

Row versions: every row carries a monotonically increasing version
counter, bumped by ``write`` (so a τ-delta push bumps exactly the rows
it selected).  ``versions``/``gather_if_stale`` let a serving-side cache
validate held rows for the cost of 8 B/row instead of re-pulling whole
embeddings — a cached row is valid precisely while the server hasn't
accepted a delta for it.

Device-table mode (``device_tables=True``): the layer tables live as
jax Arrays, stored lane-aligned — (capacity, pad_hidden(hidden)) with
power-of-two capacity ≥ 256 — so the fused exchange kernels
(:mod:`repro.kernels.exchange_fused`) see pre-padded tables and never
copy them.  :meth:`gather_quantized` / :meth:`write_quantized` are the
fused pull-response / push-apply surface: gather+int8-encode and
int8-decode+scatter run as one device program each, bit-identical to
gather→encode / decode→write on the numpy tables (the codec is
row-independent and the pad columns stay zero).
"""

from __future__ import annotations

import numpy as np

from .cost_model import NetworkModel, TransferLog


class EmbeddingServer:
    def __init__(self, num_layers: int, hidden: int,
                 net: NetworkModel | None = None, *,
                 device_tables: bool = False):
        assert num_layers >= 2, "embedding sharing needs L >= 2"
        self.L = num_layers
        self.hidden = hidden
        self.net = net or NetworkModel()
        self.device_tables = bool(device_tables)
        if self.device_tables:
            from repro.kernels.quantize import pad_hidden
            self._hp = pad_hidden(hidden)      # lane-aligned column count
        else:
            self._hp = hidden
        self._row: dict[int, int] = {}         # global id -> row
        #: dense gid → row map (-1 = unregistered): the vectorized
        #: translation behind ``_rows``, kept in sync by
        #: register/forget — no per-id python dict scan on the hot path
        self._gid2row = np.full(0, -1, np.int64)
        self._next_row = 0                     # rows handed out so far
        self._cap = 0                          # allocated rows per table
        if self.device_tables:
            import jax.numpy as jnp
            self._bufs = [jnp.zeros((0, self._hp), jnp.float32)
                          for _ in range(num_layers - 1)]
        else:
            self._bufs = [np.zeros((0, hidden), np.float32)
                          for _ in range(num_layers - 1)]
        self._ver = np.zeros(0, np.int64)      # per-row write counter
        self._reallocs = 0                     # growth events (O(log n))
        self.log = TransferLog()

    # -- registration ------------------------------------------------------

    def _ensure_capacity(self, rows: int) -> None:
        """Capacity-doubling growth: amortized O(1) per registered row
        instead of the quadratic rebuild-every-call np.concatenate.
        Device tables start at 256 rows so capacity always lands on a
        row bucket (power of two) — the fused kernels' ``pad_rows`` is
        then a no-op on the whole table."""
        if rows <= self._cap:
            return
        new_cap = max(256 if self.device_tables else 16, self._cap)
        while new_cap < rows:
            new_cap *= 2
        if self.device_tables:
            import jax.numpy as jnp
            self._bufs = [
                jnp.zeros((new_cap, self._hp), jnp.float32)
                .at[: self._next_row].set(buf[: self._next_row])
                for buf in self._bufs]
        else:
            grown = []
            for buf in self._bufs:
                g = np.zeros((new_cap, self.hidden), np.float32)
                g[: self._next_row] = buf[: self._next_row]
                grown.append(g)
            self._bufs = grown
        ver = np.zeros(new_cap, np.int64)
        ver[: self._next_row] = self._ver[: self._next_row]
        self._ver = ver
        self._cap = new_cap
        self._reallocs += 1

    def _ensure_gid_map(self, max_gid: int) -> None:
        if max_gid < len(self._gid2row):
            return
        grown = np.full(max(max_gid + 1, 2 * len(self._gid2row), 16),
                        -1, np.int64)
        grown[: len(self._gid2row)] = self._gid2row
        self._gid2row = grown

    def register(self, global_ids: np.ndarray) -> None:
        """Make rows for vertices whose embeddings will be shared."""
        new = [int(g) for g in np.unique(global_ids) if int(g) not in self._row]
        if not new:
            return
        base = self._next_row
        self._ensure_capacity(base + len(new))
        self._ensure_gid_map(max(new))
        for i, gid in enumerate(new):
            self._row[gid] = base + i
        self._gid2row[np.asarray(new, np.int64)] = \
            base + np.arange(len(new), dtype=np.int64)
        self._next_row = base + len(new)

    def forget(self, global_ids: np.ndarray) -> None:
        """Drop registrations (shard rebalancing moved the rows away).
        Row slots are not recycled — registration is append-only, so a
        forget leaves a hole that only costs capacity, never
        correctness (``register`` hands out fresh rows past it)."""
        for g in np.unique(global_ids):
            self._row.pop(int(g), None)
            if 0 <= g < len(self._gid2row):
                self._gid2row[int(g)] = -1

    @property
    def _tables(self) -> list[np.ndarray]:
        """Logical (allocated-rows) views of the capacity buffers.
        Writes through a view hit the backing buffer (numpy mode; device
        tables are immutable jax Arrays)."""
        n = self._next_row
        return [buf[:n, : self.hidden] for buf in self._bufs]

    @property
    def num_embeddings_stored(self) -> int:
        """Vertices registered × (L-1) layer tables (Fig. 2a marker)."""
        return len(self._row) * (self.L - 1)

    def memory_bytes(self) -> int:
        """Actual allocation, including capacity-doubling headroom (up to
        ~2× the registered rows right after a growth event)."""
        return sum(buf.nbytes for buf in self._bufs)

    def _rows(self, global_ids: np.ndarray) -> np.ndarray:
        gids = np.asarray(global_ids, np.int64)
        if len(gids) == 0:
            return np.zeros(0, np.int64)
        m = self._gid2row
        if len(m):
            safe = np.clip(gids, 0, len(m) - 1)
            rows = np.where((gids >= 0) & (gids < len(m)), m[safe], -1)
        else:
            rows = np.full(len(gids), -1, np.int64)
        if np.all(rows >= 0):
            return rows
        missing = [int(g) for g in gids[rows < 0]]
        shown = ", ".join(str(g) for g in missing[:8])
        if len(missing) > 8:
            shown += f", ... ({len(missing) - 8} more)"
        raise KeyError(
            f"{len(missing)} unregistered vertex id(s) in a request "
            f"of {len(global_ids)} (gids: {shown}); this server has "
            f"{len(self._row)} registered rows — register() boundary "
            "vertices before write/gather")

    # -- storage surface (used by repro.exchange transports) ----------------

    def write(self, global_ids: np.ndarray,
              layer_values: list[np.ndarray]) -> None:
        """Raw store of h^1..h^{L-1} rows — no wire accounting."""
        assert len(layer_values) == self.L - 1
        if len(global_ids) == 0:
            return
        rows = self._rows(global_ids)
        if self.device_tables:
            import jax.numpy as jnp
            rj = jnp.asarray(rows)
            self._bufs = [
                buf.at[rj, : self.hidden].set(
                    jnp.asarray(vals, jnp.float32))
                for buf, vals in zip(self._bufs, layer_values)]
        else:
            for buf, vals in zip(self._bufs, layer_values):
                buf[rows] = np.asarray(vals, np.float32)
        self._ver[rows] += 1

    def gather(self, global_ids: np.ndarray,
               layers: list[int] | None = None) -> list[np.ndarray]:
        """Raw read of the selected layer tables — no wire accounting.
        ``layers`` is 1-indexed; ``None`` means all L-1; ``[]`` means
        none (and returns an empty list).  Device tables return jax
        Arrays (same values — callers convert at most once)."""
        sel = list(range(1, self.L)) if layers is None else list(layers)
        if len(global_ids) == 0:
            return [np.zeros((0, self.hidden), np.float32) for _ in sel]
        rows = self._rows(global_ids)
        if self.device_tables:
            import jax.numpy as jnp
            rj = jnp.asarray(rows)
            return [jnp.take(self._bufs[l - 1], rj, axis=0)[:, : self.hidden]
                    for l in sel]
        # fancy indexing already allocates fresh arrays — no copy needed
        return [self._bufs[l - 1][rows] for l in sel]

    # -- fused device surface (repro.kernels.exchange_fused) ----------------

    def gather_quantized(self, global_ids: np.ndarray,
                         layers: list[int] | None = None
                         ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Pull response in wire form: one (values int8 (n, hidden),
        scales fp32 (n, 1)) pair per selected layer, bit-identical to
        ``quantize_int8(gather(...))``.  On device tables the gather and
        the encode run as one fused program over the resident table."""
        from repro.kernels import ops
        sel = list(range(1, self.L)) if layers is None else list(layers)
        if len(global_ids) == 0:
            return [(np.zeros((0, self.hidden), np.int8),
                     np.zeros((0, 1), np.float32)) for _ in sel]
        rows = self._rows(global_ids)
        out = []
        for l in sel:
            v, s = ops.gather_quantize(self._bufs[l - 1], rows)
            out.append((v[:, : self.hidden], s))
        return out

    def write_quantized(self, global_ids: np.ndarray,
                        layer_payloads: list[tuple]) -> None:
        """Push apply straight from wire form: decode int8 rows and
        store them, one fused dequant+scatter program per layer table on
        device tables — bit-identical to ``write(decode(payload))``."""
        assert len(layer_payloads) == self.L - 1
        if len(global_ids) == 0:
            return
        rows = self._rows(global_ids)
        if self.device_tables:
            from repro.kernels import ops
            self._bufs = [
                ops.dequant_scatter(buf, rows, v, s)
                for buf, (v, s) in zip(self._bufs, layer_payloads)]
        else:
            for buf, (v, s) in zip(self._bufs, layer_payloads):
                buf[rows] = np.asarray(v).astype(np.float32) \
                    * np.asarray(s, np.float32)
        self._ver[rows] += 1

    def versions(self, global_ids: np.ndarray) -> np.ndarray:
        """Current write counters for ``global_ids`` (int64, one per row
        — ``write`` always touches all L-1 layers of a row together, so
        one counter covers them all)."""
        if len(global_ids) == 0:
            return np.zeros(0, np.int64)
        return self._ver[self._rows(global_ids)].copy()

    def gather_if_stale(
        self, global_ids: np.ndarray, have_versions: np.ndarray,
        layers: list[int] | None = None,
    ) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
        """Conditional gather (If-None-Match): return current versions
        for all requested rows but row *values* only where the caller's
        ``have_versions`` entry is out of date (use -1 for "never seen").

        Returns ``(versions, stale_pos, layer_values)`` where
        ``stale_pos`` indexes into ``global_ids`` and ``layer_values[j]``
        holds the selected layer's rows for exactly those positions, in
        ``stale_pos`` order."""
        sel = list(range(1, self.L)) if layers is None else list(layers)
        if len(global_ids) == 0:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                    [np.zeros((0, self.hidden), np.float32) for _ in sel])
        rows = self._rows(global_ids)
        ver = self._ver[rows].copy()
        stale = np.nonzero(ver != np.asarray(have_versions, np.int64))[0]
        if self.device_tables:
            import jax.numpy as jnp
            rj = jnp.asarray(rows[stale])
            vals = [jnp.take(self._bufs[l - 1], rj, axis=0)[:, : self.hidden]
                    for l in sel]
        else:
            vals = [self._bufs[l - 1][rows[stale]] for l in sel]
        return ver, stale.astype(np.int64), vals

    # -- RPC surface ---------------------------------------------------------

    def push(self, global_ids: np.ndarray,
             layer_values: list[np.ndarray]) -> float:
        """Batched pipelined SET of h^1..h^{L-1} for ``global_ids``.

        ``layer_values[l]`` is an (n, hidden) array for layer l+1.
        Returns modelled wall time."""
        assert len(layer_values) == self.L - 1
        if len(global_ids) == 0:
            return 0.0
        self.write(global_ids, layer_values)
        t = self.net.transfer_time(len(global_ids), self.hidden, self.L - 1)
        self.log.add(bytes=self.net.embedding_bytes(len(global_ids),
                                                    self.hidden, self.L - 1),
                     rpcs=1, embeddings=len(global_ids) * (self.L - 1),
                     seconds=t)
        return t

    def pull(self, global_ids: np.ndarray,
             *, layers: list[int] | None = None) -> tuple[list[np.ndarray], float]:
        """Batched pipelined GET.  Returns ([per-layer (n, hidden)], time).

        ``layers`` selects which h^l tables to fetch (1-indexed);
        ``None`` fetches all L-1, an explicit ``[]`` fetches none."""
        sel = list(range(1, self.L)) if layers is None else list(layers)
        out = self.gather(global_ids, sel)
        if len(global_ids) == 0 or len(sel) == 0:
            return out, 0.0
        t = self.net.transfer_time(len(global_ids), self.hidden, len(sel))
        self.log.add(bytes=self.net.embedding_bytes(len(global_ids),
                                                    self.hidden, len(sel)),
                     rpcs=1, embeddings=len(global_ids) * len(sel), seconds=t)
        return out, t
