"""In-memory embedding server (the paper's Redis KV store).

Stores the h^1..h^{L-1} embeddings of every registered boundary vertex in
one table ("database" in the paper's Redis terms) per layer, keyed by
global vertex id.  Clients interact through batched ``push``/``pull``
calls whose network cost is accounted by a :class:`NetworkModel` — get/set
RPCs are batched + pipelined exactly as §5.1 describes.

The server is honest-but-curious: it only ever sees (vertex id →
embedding vector); raw features (h^0) are never registered.
"""

from __future__ import annotations

import numpy as np

from .cost_model import NetworkModel, TransferLog


class EmbeddingServer:
    def __init__(self, num_layers: int, hidden: int,
                 net: NetworkModel | None = None):
        assert num_layers >= 2, "embedding sharing needs L >= 2"
        self.L = num_layers
        self.hidden = hidden
        self.net = net or NetworkModel()
        self._row: dict[int, int] = {}         # global id -> row
        self._tables: list[np.ndarray] = [
            np.zeros((0, hidden), np.float32) for _ in range(num_layers - 1)
        ]
        self.log = TransferLog()

    # -- registration ------------------------------------------------------

    def register(self, global_ids: np.ndarray) -> None:
        """Make rows for vertices whose embeddings will be shared."""
        new = [int(g) for g in np.unique(global_ids) if int(g) not in self._row]
        if not new:
            return
        base = len(self._row)
        for i, gid in enumerate(new):
            self._row[gid] = base + i
        grow = np.zeros((len(new), self.hidden), np.float32)
        self._tables = [np.concatenate([t, grow], axis=0) for t in self._tables]

    @property
    def num_embeddings_stored(self) -> int:
        """Vertices registered × (L-1) layer tables (Fig. 2a marker)."""
        return len(self._row) * (self.L - 1)

    def memory_bytes(self) -> int:
        return sum(t.nbytes for t in self._tables)

    def _rows(self, global_ids: np.ndarray) -> np.ndarray:
        return np.fromiter((self._row[int(g)] for g in global_ids),
                           dtype=np.int64, count=len(global_ids))

    # -- RPC surface ---------------------------------------------------------

    def push(self, global_ids: np.ndarray,
             layer_values: list[np.ndarray]) -> float:
        """Batched pipelined SET of h^1..h^{L-1} for ``global_ids``.

        ``layer_values[l]`` is an (n, hidden) array for layer l+1.
        Returns modelled wall time."""
        assert len(layer_values) == self.L - 1
        if len(global_ids) == 0:
            return 0.0
        rows = self._rows(global_ids)
        for tbl, vals in zip(self._tables, layer_values):
            tbl[rows] = np.asarray(vals, np.float32)
        t = self.net.transfer_time(len(global_ids), self.hidden, self.L - 1)
        self.log.add(bytes=self.net.embedding_bytes(len(global_ids),
                                                    self.hidden, self.L - 1),
                     rpcs=1, embeddings=len(global_ids) * (self.L - 1),
                     seconds=t)
        return t

    def pull(self, global_ids: np.ndarray,
             *, layers: list[int] | None = None) -> tuple[list[np.ndarray], float]:
        """Batched pipelined GET.  Returns ([per-layer (n, hidden)], time).

        ``layers`` selects which h^l tables to fetch (1-indexed);
        default all L-1."""
        sel = layers or list(range(1, self.L))
        if len(global_ids) == 0:
            return [np.zeros((0, self.hidden), np.float32) for _ in sel], 0.0
        rows = self._rows(global_ids)
        out = [self._tables[l - 1][rows].copy() for l in sel]
        t = self.net.transfer_time(len(global_ids), self.hidden, len(sel))
        self.log.add(bytes=self.net.embedding_bytes(len(global_ids),
                                                    self.hidden, len(sel)),
                     rpcs=1, embeddings=len(global_ids) * len(sel), seconds=t)
        return out, t
