"""Federated optimization of the architecture zoo — the paper's systems
ideas mapped onto the multi-pod mesh (DESIGN.md §3/§4).

Cross-silo federated learning of a transformer: each *silo* (pod) runs
``local_steps`` of training on its own data shard, then silos aggregate.
The three OptimES levers transfer directly:

  * prune what you communicate  → top-k magnitude sparsification of the
    model delta before cross-silo aggregation (§4.1 analogue; the
    frequency-score pruning of boundary embeddings becomes magnitude
    scoring of parameter deltas);
  * overlap communication with the compute tail → ``stale_aggregation``:
    round r applies the aggregate of round r-1's deltas, so the
    cross-pod all-reduce overlaps the next round's local steps (§4.2's
    stale-push, with the same one-round staleness trade);
  * batched exchange through a server → the aggregation is a mean over
    the silo axis (a ``pod``-axis psum at TPU scale; a stacked-leading-
    dim mean here, which GSPMD lowers to exactly that when the leading
    dim is sharded over 'pod').

Everything is pure JAX: silo-stacked params (leading dim = num_silos),
``vmap`` for local steps, so the same code runs on 1 CPU device (tests,
examples) and on the (pod, data, model) production mesh (dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import Optimizer


@dataclasses.dataclass(frozen=True)
class FedOptConfig:
    num_silos: int
    local_steps: int = 4
    delta_topk_frac: Optional[float] = None   # None = dense deltas (EmbC-ish)
    stale_aggregation: bool = False           # §4.2 overlap analogue


def replicate(tree: Any, n: int) -> Any:
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)


def _topk_sparsify(delta: Any, frac: float) -> tuple[Any, float]:
    """Keep the top-``frac`` magnitude entries per leaf (threshold via
    per-leaf quantile — the sort-free analogue of kernels/topk_mask).
    Returns (sparse delta, kept fraction actually communicated)."""
    kept_n, total_n = 0.0, 0.0

    def one(d):
        nonlocal kept_n, total_n
        if d.ndim == 0:
            return d
        mag = jnp.abs(d.astype(jnp.float32))
        thr = jnp.quantile(mag.reshape(-1), 1.0 - frac)
        mask = mag >= thr
        kept_n += float(frac) * d.size
        total_n += d.size
        return jnp.where(mask, d, 0).astype(d.dtype)

    out = jax.tree_util.tree_map(one, delta)
    return out, (kept_n / max(total_n, 1.0))


class FederatedLMTrainer:
    """Driver for federated training of any zoo architecture.

    Holds silo-stacked params/optimizer state and an ``anchor`` (the last
    agreed global model).  ``round(batches)`` = local_steps per silo +
    aggregation (possibly stale, possibly sparsified)."""

    def __init__(self, model_cfg, optimizer: Optimizer, fed: FedOptConfig,
                 rng=None):
        self.cfg = model_cfg
        self.opt = optimizer
        self.fed = fed
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        anchor = lm.init_params(rng, model_cfg)
        self.anchor = anchor
        self.params = replicate(anchor, fed.num_silos)
        self.opt_state = jax.vmap(optimizer.init)(self.params)
        self.pending_delta = None                   # stale-aggregation buffer
        self.comm_fraction = 1.0
        inner = lm.make_train_step(model_cfg, optimizer)

        def silo_round(params, opt_state, batches):
            """local_steps of training on one silo.  batches: pytree with
            leading (local_steps, ...) dims."""
            def body(carry, b):
                p, s = carry
                p, s, m = inner(p, s, b)
                return (p, s), m["loss"]

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), batches)
            return params, opt_state, losses.mean()

        self._silo_round = jax.jit(jax.vmap(silo_round))

    def round(self, batches: Any) -> dict:
        """batches: pytree with leading (num_silos, local_steps, ...)."""
        fed = self.fed
        self.params, self.opt_state, losses = self._silo_round(
            self.params, self.opt_state, batches)
        delta = jax.tree_util.tree_map(
            lambda p, a: (p - a[None]).mean(axis=0), self.params,
            self.anchor)
        if fed.delta_topk_frac is not None:
            delta, self.comm_fraction = _topk_sparsify(
                delta, fed.delta_topk_frac)

        if fed.stale_aggregation:
            # apply LAST round's aggregate now; ship this round's delta
            # while the next round trains (one-round staleness, §4.2)
            apply_delta = self.pending_delta
            self.pending_delta = delta
        else:
            apply_delta = delta

        if apply_delta is not None:
            self.anchor = jax.tree_util.tree_map(
                lambda a, d: (a + d.astype(a.dtype)), self.anchor,
                apply_delta)
            self.params = replicate(self.anchor, fed.num_silos)
            self.opt_state = jax.vmap(self.opt.init)(self.params)
        return {"loss": float(jnp.mean(losses)),
                "comm_fraction": self.comm_fraction}

    def comm_bytes_per_round(self) -> int:
        n = sum(int(jnp.size(p)) * p.dtype.itemsize
                for p in jax.tree_util.tree_leaves(self.anchor))
        frac = self.fed.delta_topk_frac or 1.0
        return int(n * frac)
