"""Batched serving runtime: continuous batching over the zoo's decode step.

A fixed number of *lanes* (the decode batch) each carry one in-flight
request; every ``step()`` runs one decode for the whole batch, finished
lanes retire immediately and the next queued request takes the lane —
the cache lane is reset in place (valid mask / write index / length), so
there is no re-compile and no idle bubble waiting for the longest request
(vLLM-style continuous batching, CPU-scale).

Works with every architecture family: attention caches reset via their
ring-buffer bookkeeping; SSM caches reset by zeroing conv/state lanes.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0                # tokens consumed from the prompt

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


def _reset_lane(cache, lane: int):
    """Zero one lane's bookkeeping (and state, for SSM) in a cache tree."""
    def fix(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("index", "length"):
            # leading layer-stack dims broadcast; lane is the last axis
            return leaf.at[..., lane].set(0)
        if name == "valid":
            return leaf.at[..., lane, :].set(False)
        if name in ("state", "conv_x", "conv_BC"):
            # (..., B, ...) — batch axis position differs per leaf kind;
            # both SSM caches carry batch right after the layer stack
            nd_batch = {"state": 4, "conv_x": 3, "conv_BC": 3}[name]
            idx = (Ellipsis, lane) + (slice(None),) * (nd_batch - 1)
            return leaf.at[idx].set(0)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, lanes: int,
                 capacity: int, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes
        self.capacity = capacity
        self.cache = lm.init_cache(cfg, lanes, capacity)
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c))
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * lanes
        self._next_rid = 0
        self.completed: list[Request] = []
        self.steps = 0

    # -- API -------------------------------------------------------------

    def submit(self, prompt: np.ndarray, *, max_new: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int64),
                                  max_new))
        return rid

    def _fill_lanes(self):
        for lane in range(self.lanes):
            if self.active[lane] is None and self.queue:
                self.active[lane] = self.queue.popleft()
                self.cache = _reset_lane(self.cache, lane)

    def step(self) -> list[tuple[int, int]]:
        """One decode tick.  Returns [(rid, emitted_token)] for lanes that
        produced a generation token this tick."""
        self._fill_lanes()
        if not any(self.active):
            return []
        toks = np.zeros((self.lanes, 1), np.int32)
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            if req.pos < len(req.prompt):
                toks[lane, 0] = req.prompt[req.pos]           # teacher-force
            else:
                toks[lane, 0] = req.generated[-1] if req.generated else 0
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(toks), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        out = []
        self.steps += 1
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            if req.pos < len(req.prompt):
                req.pos += 1
                if req.pos == len(req.prompt):
                    req.generated.append(int(nxt[lane]))
                    out.append((req.rid, int(nxt[lane])))
            else:
                req.generated.append(int(nxt[lane]))
                out.append((req.rid, int(nxt[lane])))
            if req.done:
                self.completed.append(req)
                self.active[lane] = None
        return out

    def run_to_completion(self, *, max_steps: int = 100_000
                          ) -> list[Request]:
        while (any(self.active) or self.queue) and self.steps < max_steps:
            self.step()
        return self.completed
