"""Deterministic synthetic data pipelines for the architecture zoo.

Offline container ⇒ no real corpora; batches are seeded synthetic token
streams with a learnable structure (a noisy Markov chain over the vocab)
so "loss decreases" is meaningful, plus the modality-stub inputs for
vlm/audio (the allowed carve-out: precomputed patch/frame embeddings).
"""

from __future__ import annotations

from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _markov_tokens(rng: np.random.Generator, vocab: int, batch: int,
                   seq: int, order_stride: int = 7) -> np.ndarray:
    """Tokens with predictable structure: t_{i+1} ≈ (a·t_i + b) mod V with
    noise — a next-token pattern a small model can actually learn."""
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = rng.integers(0, vocab, batch)
    noise = rng.random((batch, seq)) < 0.15
    rand = rng.integers(0, vocab, (batch, seq))
    for i in range(1, seq):
        nxt = (toks[:, i - 1] * order_stride + 13) % vocab
        toks[:, i] = np.where(noise[:, i], rand[:, i], nxt)
    return toks


def synthetic_batches(cfg: ModelConfig, *, batch: int, seq: int,
                      seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        toks = _markov_tokens(rng, cfg.vocab_size, batch, seq + 1)
        out = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if cfg.family == "vlm":
            out["vision"] = jnp.asarray(
                rng.standard_normal((batch, cfg.vision_tokens,
                                     cfg.vision_dim)) * 0.1, jnp.float32
            ).astype(cfg.dtype)
        if cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model))
                * 0.1, jnp.float32).astype(cfg.dtype)
        yield out


def synthetic_request_stream(cfg: ModelConfig, *, batch: int,
                             prompt_len: int, seed: int = 0
                             ) -> Iterator[np.ndarray]:
    """Batched serve requests: (batch, prompt_len) token prompts."""
    rng = np.random.default_rng(seed)
    while True:
        yield _markov_tokens(rng, cfg.vocab_size, batch, prompt_len)
