from .pipeline import synthetic_batches, synthetic_request_stream

__all__ = ["synthetic_batches", "synthetic_request_stream"]
