"""Mamba2 SSD (state-space duality) blocks in pure JAX. [arXiv:2405.21060]

Training/prefill uses the chunked dual form: quadratic attention-like
computation inside fixed-size chunks, linear recurrence across chunks
(``lax.scan`` carrying the (B, H, P, N) state).  Decode is the O(1)
recurrent update, which is what makes the long_500k shape native for the
ssm/hybrid architectures.

TPU adaptation: chunk size defaults to 256 (multiple of the 128 MXU tile)
and all intra-chunk contractions are einsums that map onto the MXU; the
cross-chunk scan carries only the compressed state.

Sharding note (§Perf): the reference implementation fuses z/x/B/C/dt into
ONE in_proj whose output dim (2·d_in + 2N + H) is not divisible by the
model axis — which forced full replication of the SSM weights (and their
Adam states: 12.5 GiB/chip for mamba2-1.3b).  We therefore keep separate,
shard-aligned projections: ``in_zx`` (D, 2·d_in) tensor-parallel over the
head/channel dim, ``in_BC``/``in_dt`` small and replicated.  Identical
math (it is one matmul split by output columns), clean SPMD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .layers import dense_init, gated_rms_norm


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state
    return d_in, nheads, conv_ch


def init_ssm(rng, cfg: ModelConfig):
    D = cfg.d_model
    d_in, H, _ = ssm_dims(cfg)
    N, W = cfg.ssm_state, cfg.ssm_conv_width
    k = jax.random.split(rng, 5)
    return {
        "in_zx": dense_init(k[0], (D, 2 * d_in), cfg.dtype),
        "in_BC": dense_init(k[1], (D, 2 * N), cfg.dtype),
        "in_dt": dense_init(k[2], (D, H), cfg.dtype),
        "conv_x": dense_init(k[3], (W, d_in), cfg.dtype, scale=0.5),
        "conv_x_b": jnp.zeros((d_in,), cfg.dtype),
        "conv_BC": dense_init(k[3], (W, 2 * N), cfg.dtype, scale=0.5),
        "conv_BC_b": jnp.zeros((2 * N,), cfg.dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm_w": jnp.ones((d_in,), cfg.dtype),
        "out_proj": dense_init(k[4], (d_in, D), cfg.dtype),
    }


def _causal_conv(x, conv_w, conv_b):
    """Depthwise causal conv, width W.  x: (B, S, CH)."""
    W = conv_w.shape[0]
    pad = jnp.pad(x, [(0, 0), (W - 1, 0), (0, 0)])
    out = sum(pad[:, i: i + x.shape[1]] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b)


def _segsum_decay(dA_chunk):
    """exp(cumsum difference) lower-triangular decay matrix.
    dA_chunk: (..., Q, H) → (..., Qi, Qj, H)."""
    Q = dA_chunk.shape[-2]
    cs = jnp.cumsum(dA_chunk, axis=-2)                    # (..., Q, H)
    diff = cs[..., :, None, :] - cs[..., None, :, :]      # (..., Qi, Qj, H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(tri[..., None], diff, -jnp.inf)
    return jnp.exp(diff)


def ssd_scan(cfg: ModelConfig, xs, dt, Bc, Cc, A, D_skip,
             init_state=None):
    """Chunked SSD.  xs: (B,S,H,P); dt: (B,S,H); Bc/Cc: (B,S,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    B_, S, H, P = xs.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    dA = dt * A                                            # (B,S,H) negative
    xs_c = xs.reshape(B_, nc, Q, H, P)
    dt_c = dt.reshape(B_, nc, Q, H)
    dA_c = dA.reshape(B_, nc, Q, H)
    B_c = Bc.reshape(B_, nc, Q, N)
    C_c = Cc.reshape(B_, nc, Q, N)

    # intra-chunk (dual / attention-like) term
    decay = _segsum_decay(dA_c)                            # (B,nc,Qi,Qj,H)
    scores = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)[..., None] * decay
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp",
                         scores, dt_c, xs_c)

    # chunk-final states
    cum = jnp.cumsum(dA_c, axis=2)                         # (B,nc,Q,H)
    total = cum[:, :, -1:]                                 # (B,nc,1,H)
    decay_to_end = jnp.exp(total - cum)                    # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcqh,bcqh,bcqhp,bcqn->bchpn",
                              decay_to_end, dt_c, xs_c, B_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(total[:, :, 0])                  # (B,nc,H)
    s0 = jnp.zeros((B_, H, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(state, inp):
        dec, new = inp                                     # (B,H), (B,H,P,N)
        prev = state
        state = state * dec[:, :, None, None] + new
        return state, prev

    (final_state, prev_states) = jax.lax.scan(
        step, s0, (chunk_decay.swapaxes(0, 1).astype(jnp.float32),
                   chunk_states.swapaxes(0, 1).astype(jnp.float32)))
    prev_states = prev_states.swapaxes(0, 1)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         C_c, jnp.exp(cum), prev_states.astype(cum.dtype))
    y = (y_intra + y_inter).reshape(B_, S, H, P)
    y = y + D_skip[None, None, :, None] * xs
    return y.astype(xs.dtype), final_state


def ssm_forward(p, cfg: ModelConfig, x, *, init_state=None):
    """Full-sequence Mamba2 block.  x: (B,S,D) → (y, (conv_tail, state))."""
    d_in, H, conv_ch = ssm_dims(cfg)
    P, N, W = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv_width
    Bsz, S, _ = x.shape
    z, xs = jnp.split(x @ p["in_zx"], [d_in], axis=-1)
    BC = x @ p["in_BC"]
    dt = x @ p["in_dt"]
    xs = _causal_conv(xs, p["conv_x"], p["conv_x_b"])
    BC = _causal_conv(BC, p["conv_BC"], p["conv_BC_b"])
    Bc, Cc = jnp.split(BC, [N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_scan(cfg, xs.reshape(Bsz, S, H, P),
                        dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                        A, p["D"], init_state=init_state)
    y = y.reshape(Bsz, S, d_in)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    # decode caches carry the raw (pre-conv) tails of x and BC
    pre_x = jnp.split(x @ p["in_zx"], [d_in], axis=-1)[1][:, -(W - 1):] \
        if W > 1 else jnp.zeros((Bsz, 0, d_in), x.dtype)
    pre_BC = (x @ p["in_BC"])[:, -(W - 1):] if W > 1 \
        else jnp.zeros((Bsz, 0, 2 * N), x.dtype)
    return y @ p["out_proj"], ((pre_x, pre_BC), state)


def ssm_decode(p, cfg: ModelConfig, x, cache):
    """Single-token recurrent update.  x: (B,1,D);
    cache: {"conv_x": (B,W-1,d_in), "conv_BC": (B,W-1,2N),
            "state": (B,H,P,N)}."""
    d_in, H, _ = ssm_dims(cfg)
    P, N, W = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv_width
    Bsz = x.shape[0]
    z, xs = jnp.split(x[:, 0] @ p["in_zx"], [d_in], axis=-1)
    BC = x[:, 0] @ p["in_BC"]
    dt = x[:, 0] @ p["in_dt"]

    wx = jnp.concatenate([cache["conv_x"], xs[:, None]], axis=1)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", wx, p["conv_x"])
                     + p["conv_x_b"])
    wbc = jnp.concatenate([cache["conv_BC"], BC[:, None]], axis=1)
    BC_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", wbc, p["conv_BC"])
                       + p["conv_BC_b"])
    Bc, Cc = jnp.split(BC_c, [N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                   # (B,H)
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    state = cache["state"] * dA[:, :, None, None] \
        + dt[:, :, None, None] * xh[..., None] * Bc[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", state, Cc.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    y = gated_rms_norm(y, z[:, None], p["norm_w"], cfg.norm_eps)
    new_cache = {"conv_x": wx[:, 1:], "conv_BC": wbc[:, 1:], "state": state}
    return y @ p["out_proj"], new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int):
    d_in, H, _ = ssm_dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in),
                            cfg.dtype),
        "conv_BC": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                              2 * cfg.ssm_state), cfg.dtype),
        "state": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
    }
