"""Unified language-model zoo: init / train forward / prefill / decode.

One functional API over six architecture families (see repro.configs):

    params            = init_params(rng, cfg)
    logits, aux       = forward(params, cfg, batch, kind="train"|"prefill")
    cache             = init_cache(cfg, batch, capacity, prefill_len)
    logits, new_cache = decode_step(params, cfg, tokens, cache, extras)

Layer stacks are scanned (``lax.scan`` over stacked params, with
``jax.checkpoint`` remat inside) wherever the stack is homogeneous —
dense, moe, ssm, hybrid, and the VLM's (4 self + 1 cross) super-blocks.
Whisper's 4+4 enc-dec layers are python loops.

Decode shapes: caches are ring buffers of ``capacity`` slots; a
``sliding_window`` config turns them into the SWA variant that makes
long_500k legal for full-attention architectures (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (add_bias, attention, attention_decode, blocked_attention,
                     cross_attention, dense_init, init_attention,
                     init_cross_attention, init_kv_cache, init_mla,
                     init_mla_cache, init_mlp, mla_attention, mla_decode,
                     mlp, rms_norm)

Params = Any


# ---------------------------------------------------------------------------
# per-family layer definitions
# ---------------------------------------------------------------------------

def _init_dense_layer(rng, cfg: ModelConfig, *, use_moe: bool):
    k = jax.random.split(rng, 3)
    p = {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
         "ln2": jnp.ones((cfg.d_model,), cfg.dtype)}
    p["attn"] = init_mla(k[0], cfg) if cfg.kv_lora_rank \
        else init_attention(k[0], cfg)
    if use_moe:
        p["moe"] = moe_lib.init_moe(k[1], cfg)
    else:
        p["mlp"] = init_mlp(k[1], cfg, cfg.d_ff)
    return p


def _dense_layer_fwd(p, cfg: ModelConfig, x, *, positions, window):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, _ = mla_attention(p["attn"], cfg, h, positions=positions)
    else:
        a, _ = attention(p["attn"], cfg, h, positions=positions,
                         window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, aux = moe_lib.moe_ffn(p["moe"], cfg, h)
    else:
        f, aux = mlp(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + f, aux


def _dense_layer_decode(p, cfg: ModelConfig, x, cache, *, window):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, cache = mla_decode(p["attn"], cfg, h, cache, window=window)
    else:
        a, cache = attention_decode(p["attn"], cfg, h, cache, window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_lib.moe_ffn(p["moe"], cfg, h)
    else:
        f = mlp(p["mlp"], cfg, h)
    return x + f, cache


def _init_ssm_layer(rng, cfg: ModelConfig):
    return {"ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "ssm": ssm_lib.init_ssm(rng, cfg)}


def _ssm_layer_fwd(p, cfg, x):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, _ = ssm_lib.ssm_forward(p["ssm"], cfg, h)
    return x + y


def _ssm_layer_decode(p, cfg, x, cache):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, cache = ssm_lib.ssm_decode(p["ssm"], cfg, h, cache)
    return x + y, cache


def _init_hybrid_layer(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 3)
    return {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": init_attention(k[0], cfg),
            "ssm": ssm_lib.init_ssm(k[1], cfg),
            "mlp": init_mlp(k[2], cfg, cfg.d_ff)}


def _hybrid_layer_fwd(p, cfg, x, *, positions, window):
    """Hymba parallel heads: attention ∥ SSD over the same normed input,
    mean-fused. [arXiv:2411.13676]"""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, _ = attention(p["attn"], cfg, h, positions=positions, window=window)
    s, _ = ssm_lib.ssm_forward(p["ssm"], cfg, h)
    x = x + 0.5 * (a + s)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["mlp"], cfg, h)


def _hybrid_layer_decode(p, cfg, x, cache, *, window):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, kv = attention_decode(p["attn"], cfg, h, cache["attn"], window=window)
    s, sc = ssm_lib.ssm_decode(p["ssm"], cfg, h, cache["ssm"])
    x = x + 0.5 * (a + s)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["mlp"], cfg, h), {"attn": kv, "ssm": sc}


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _stacked(rng, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def init_params(rng, cfg: ModelConfig) -> Params:
    keys = jax.random.split(rng, 8)
    p: dict = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            cfg.dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                  cfg.dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        n_dense = cfg.first_dense_layers if cfg.num_experts else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        if cfg.num_experts and n_dense:
            p["head_blocks"] = [
                _init_dense_layer(k, cfg, use_moe=False)
                for k in jax.random.split(keys[2], n_dense)]
        if cfg.num_experts:
            p["blocks"] = _stacked(
                keys[3], n_moe,
                lambda k: _init_dense_layer(k, cfg, use_moe=True))
        else:
            p["blocks"] = _stacked(
                keys[3], cfg.num_layers,
                lambda k: _init_dense_layer(k, cfg, use_moe=False))
    elif fam == "ssm":
        p["blocks"] = _stacked(keys[2], cfg.num_layers,
                               lambda k: _init_ssm_layer(k, cfg))
    elif fam == "hybrid":
        p["blocks"] = _stacked(keys[2], cfg.num_layers,
                               lambda k: _init_hybrid_layer(k, cfg))
    elif fam == "vlm":
        n_super = cfg.num_layers // cfg.cross_attn_every
        inner = cfg.cross_attn_every - 1

        def init_super(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "self": _stacked(k1, inner,
                                 lambda kk: _init_dense_layer(kk, cfg,
                                                              use_moe=False)),
                "cross": init_cross_attention(k2, cfg),
                "ln_cross": jnp.ones((cfg.d_model,), cfg.dtype),
                "gate": jnp.zeros((), cfg.dtype),
                "tail": _init_dense_layer(k3, cfg, use_moe=False),
            }

        p["blocks"] = _stacked(keys[2], n_super, init_super)
        p["vis_proj"] = dense_init(keys[4], (cfg.vision_dim, cfg.d_model),
                                   cfg.dtype)
    elif fam == "audio":
        p["enc_blocks"] = [
            _init_dense_layer(k, cfg, use_moe=False)
            for k in jax.random.split(keys[2], cfg.encoder_layers)]
        p["enc_norm"] = jnp.ones((cfg.d_model,), cfg.dtype)

        def init_dec(k):
            k1, k2 = jax.random.split(k)
            d = _init_dense_layer(k1, cfg, use_moe=False)
            d["cross"] = init_cross_attention(k2, cfg)
            d["ln_cross"] = jnp.ones((cfg.d_model,), cfg.dtype)
            return d

        p["dec_blocks"] = [init_dec(k)
                           for k in jax.random.split(keys[3], cfg.num_layers)]
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(f, cfg: ModelConfig):
    # prevent_cse=False is the documented setting for checkpoint-inside-scan
    # (the scan loop boundary already prevents the problematic CSE) and
    # avoids spurious saved f32 copies of the carry.
    return jax.checkpoint(f, prevent_cse=False) if cfg.remat else f


def forward(params: Params, cfg: ModelConfig, batch: dict,
            *, window: Optional[int] = None,
            constrain=None,
            constrain_block_params=None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  batch: {"tokens": (B,S) int32, and for
    vlm "vision": (B,Tv,vision_dim); for audio "frames": (B,Te,D)}.
    Returns (logits (B,S,V), moe_aux_loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    window = window if window is not None else cfg.sliding_window
    constrain = constrain or (lambda t: t)
    # with_sharding_constraint is its own transpose: constraining the
    # per-layer param slice inside the scan body ALSO pins the cotangent
    # (per-layer grad) sharding, turning the backward's all-reduces into
    # reduce-scatters (§Perf, nemotron/command-r train_4k iteration 3).
    cbp = constrain_block_params or (lambda t: t)
    x = constrain(params["embed"][tokens])
    positions = jnp.arange(S, dtype=jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "moe"):
        for lp in params.get("head_blocks", []):
            x, aux = _dense_layer_fwd(lp, cfg, x, positions=positions,
                                      window=window)
            aux_total += aux

        def blk(carry, lp):
            x, aux = carry
            x, a = _dense_layer_fwd(cbp(lp), cfg, x, positions=positions,
                                    window=window)
            return (constrain(x), aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(blk, cfg), (x, aux_total), params["blocks"])
    elif fam == "ssm":
        def blk(x, lp):
            return constrain(_ssm_layer_fwd(cbp(lp), cfg, x)), None

        x, _ = jax.lax.scan(_maybe_remat(blk, cfg), x, params["blocks"])
    elif fam == "hybrid":
        def blk(x, lp):
            return constrain(_hybrid_layer_fwd(cbp(lp), cfg, x,
                                               positions=positions,
                                               window=window)), None

        x, _ = jax.lax.scan(_maybe_remat(blk, cfg), x, params["blocks"])
    elif fam == "vlm":
        memory = batch["vision"] @ params["vis_proj"]

        def blk(x, lp):
            def self_blk(x, sp):
                x, _ = _dense_layer_fwd(sp, cfg, x, positions=positions,
                                        window=window)
                return x, None

            x, _ = jax.lax.scan(self_blk, x, lp["self"])
            h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + jnp.tanh(lp["gate"]) * cross_attention(
                lp["cross"], cfg, h, memory)
            x, _ = _dense_layer_fwd(lp["tail"], cfg, x, positions=positions,
                                    window=window)
            return constrain(x), None

        x, _ = jax.lax.scan(_maybe_remat(blk, cfg), x, params["blocks"])
    elif fam == "audio":
        enc = batch["frames"]
        enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
        for lp in params["enc_blocks"]:
            h = rms_norm(enc, lp["ln1"], cfg.norm_eps)
            q = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]),
                         lp["attn"].get("bq"))
            k = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]),
                         lp["attn"].get("bk"))
            v = add_bias(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"]),
                         lp["attn"].get("bv"))
            from .layers import apply_rope
            q = apply_rope(q, enc_pos, cfg.rope_theta)
            k = apply_rope(k, enc_pos, cfg.rope_theta)
            o = blocked_attention(q, k, v, q_positions=enc_pos,
                                  kv_positions=enc_pos, causal=False,
                                  window=None)
            o = add_bias(jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"]),
                         lp["attn"].get("bo"))
            enc = enc + o
            h = rms_norm(enc, lp["ln2"], cfg.norm_eps)
            enc = enc + mlp(lp["mlp"], cfg, h)
        enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
        for lp in params["dec_blocks"]:
            x, _ = _dense_layer_fwd(
                {k: v for k, v in lp.items()
                 if k in ("ln1", "ln2", "attn", "mlp")},
                cfg, x, positions=positions, window=window)
            h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + cross_attention(lp["cross"], cfg, h, enc)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux_total


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch, *, aux_weight: float = 0.01,
            constrain=None, constrain_logits=None,
            constrain_block_params=None):
    logits, aux = forward(params, cfg, batch, constrain=constrain,
                          constrain_block_params=constrain_block_params)
    if constrain_logits is not None:
        logits = constrain_logits(logits)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer, *, microbatches: int = 1,
                    constrain=None, constrain_logits=None,
                    accum_dtype=jnp.float32, constrain_grads=None,
                    constrain_block_params=None):
    """Returns train_step(params, opt_state, batch) -> (params, state,
    metrics).  ``microbatches`` > 1 enables gradient accumulation: the
    global batch is split along its leading dim and scanned, so peak
    activation memory scales with batch/microbatches (the knob that fits
    the 340B train_4k point into v5e HBM)."""

    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, constrain=constrain,
                          constrain_logits=constrain_logits,
                          constrain_block_params=constrain_block_params),
        has_aux=True)

    cg = constrain_grads or (lambda g: g)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (tot, metrics), grads = grad_fn(params, batch=batch)
            grads = cg(grads)
        else:
            B = batch["tokens"].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = jax.tree_util.tree_map(
                lambda a: a.reshape((microbatches, B // microbatches)
                                    + a.shape[1:]), batch)

            def acc_step(carry, b):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, batch=b)
                # pin per-microbatch grads to the parameter sharding so the
                # partitioner emits reduce-scatters, not 16x-bigger
                # all-reduces (§Perf iteration 2, nemotron train_4k)
                grads = cg(grads)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), mb)
            grads = jax.tree_util.tree_map(
                lambda g: (g / microbatches), grads)
            metrics = jax.tree_util.tree_map(
                lambda m: m / microbatches, metrics)
        params, opt_state = optimizer.step(params, grads, opt_state)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               *, prefill_len: int = 0, extras: dict | None = None):
    """Per-layer decode caches, stacked for scanning where the stack is.

    ``capacity`` should be min(seq_len, sliding_window or seq_len).
    For vlm/audio, ``extras`` provides the static memory (vision / encoder
    output) whose cross K/V are precomputed into the cache.
    """
    fam = cfg.family

    def stack(make, n):
        one = make()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if fam in ("dense", "moe"):
        mk = (lambda: init_mla_cache(cfg, batch, capacity, prefill_len)) \
            if cfg.kv_lora_rank else \
            (lambda: init_kv_cache(cfg, batch, capacity, prefill_len))
        n_dense = cfg.first_dense_layers if cfg.num_experts else 0
        n_scan = cfg.num_layers - n_dense
        cache = {"blocks": stack(mk, n_scan)}
        if n_dense:
            cache["head_blocks"] = [mk() for _ in range(n_dense)]
        return cache
    if fam == "ssm":
        return {"blocks": stack(lambda: ssm_lib.init_ssm_cache(cfg, batch),
                                cfg.num_layers)}
    if fam == "hybrid":
        def mk():
            return {"attn": init_kv_cache(cfg, batch, capacity, prefill_len),
                    "ssm": ssm_lib.init_ssm_cache(cfg, batch)}
        return {"blocks": stack(mk, cfg.num_layers)}
    if fam == "vlm":
        n_super = cfg.num_layers // cfg.cross_attn_every
        inner = cfg.cross_attn_every - 1

        def mk():
            return {
                "self": stack(lambda: init_kv_cache(cfg, batch, capacity,
                                                    prefill_len), inner),
                "tail": init_kv_cache(cfg, batch, capacity, prefill_len),
                "cross_k": jnp.zeros((batch, cfg.vision_tokens,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim), cfg.dtype),
                "cross_v": jnp.zeros((batch, cfg.vision_tokens,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim), cfg.dtype),
            }
        return {"blocks": stack(mk, n_super)}
    if fam == "audio":
        def mk():
            return {
                "self": init_kv_cache(cfg, batch, capacity, prefill_len),
                "cross_k": jnp.zeros((batch, cfg.encoder_seq,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim), cfg.dtype),
                "cross_v": jnp.zeros((batch, cfg.encoder_seq,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim), cfg.dtype),
            }
        return {"dec_blocks": [mk() for _ in range(cfg.num_layers)]}
    raise ValueError(fam)


def _cross_decode(p, cfg, x, k_cache, v_cache):
    """One-token cross-attention against precomputed memory K/V."""
    from .layers import decode_attention
    B = x.shape[0]
    q = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), p.get("bq"))
    T = k_cache.shape[1]
    valid = jnp.ones((B, T), bool)
    pos = jnp.zeros((B, T), jnp.int32)
    out = decode_attention(q, k_cache, v_cache,
                           q_position=jnp.zeros((B,), jnp.int32),
                           kv_positions=pos, window=None, kv_valid=valid)
    return add_bias(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), p.get("bo"))


def decode_step(params: Params, cfg: ModelConfig, tokens, cache,
                *, window: Optional[int] = None):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, cache)."""
    window = window if window is not None else cfg.sliding_window
    x = params["embed"][tokens]
    fam = cfg.family

    if fam in ("dense", "moe"):
        new_head = []
        for lp, lc in zip(params.get("head_blocks", []),
                          cache.get("head_blocks", [])):
            x, c = _dense_layer_decode(lp, cfg, x, lc, window=window)
            new_head.append(c)

        def blk(x, scanned):
            lp, lc = scanned
            x, c = _dense_layer_decode(lp, cfg, x, lc, window=window)
            return x, c

        x, new_blocks = jax.lax.scan(blk, x, (params["blocks"],
                                              cache["blocks"]))
        new_cache = {"blocks": new_blocks}
        if new_head:
            new_cache["head_blocks"] = new_head
    elif fam == "ssm":
        def blk(x, scanned):
            lp, lc = scanned
            x, c = _ssm_layer_decode(lp, cfg, x, lc)
            return x, c

        x, nb = jax.lax.scan(blk, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nb}
    elif fam == "hybrid":
        def blk(x, scanned):
            lp, lc = scanned
            x, c = _hybrid_layer_decode(lp, cfg, x, lc, window=window)
            return x, c

        x, nb = jax.lax.scan(blk, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nb}
    elif fam == "vlm":
        def blk(x, scanned):
            lp, lc = scanned

            def self_blk(x, s):
                sp, sc = s
                x, c = _dense_layer_decode(sp, cfg, x, sc, window=window)
                return x, c

            x, nself = jax.lax.scan(self_blk, x, (lp["self"], lc["self"]))
            h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + jnp.tanh(lp["gate"]) * _cross_decode(
                lp["cross"], cfg, h, lc["cross_k"], lc["cross_v"])
            x, ntail = _dense_layer_decode(lp["tail"], cfg, x, lc["tail"],
                                           window=window)
            return x, {"self": nself, "tail": ntail,
                       "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

        x, nb = jax.lax.scan(blk, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nb}
    elif fam == "audio":
        new_dec = []
        for lp, lc in zip(params["dec_blocks"], cache["dec_blocks"]):
            sub = {k: v for k, v in lp.items()
                   if k in ("ln1", "ln2", "attn", "mlp")}
            x, c = _dense_layer_decode(sub, cfg, x, lc["self"], window=window)
            h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + _cross_decode(lp["cross"], cfg, h, lc["cross_k"],
                                  lc["cross_v"])
            new_dec.append({"self": c, "cross_k": lc["cross_k"],
                            "cross_v": lc["cross_v"]})
        new_cache = {"dec_blocks": new_dec}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache):
        return decode_step(params, cfg, tokens, cache)
    return serve_step


def param_count(params: Params) -> int:
    return sum(int(np.prod(p.shape))
               for p in jax.tree_util.tree_leaves(params))
