"""Mixture-of-Experts FFN with sort-based token dispatch.

Design notes (TPU adaptation, see DESIGN.md):

* We deliberately avoid the GShard one-hot einsum dispatch — its dispatch
  einsum FLOPs dwarf the useful expert FLOPs and would corrupt the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio.  Instead tokens are *sorted by
  expert id* and gathered into per-expert capacity buffers, computed with
  batched expert einsums, and combined with a scatter-add.  Under GSPMD
  the expert dimension shards on the ``model``/``expert`` mesh axis, so
  dispatch/combine lower to all-to-all style collectives.
* Capacity: C = ceil(T·k/E · capacity_factor); overflowing tokens are
  dropped (standard token-dropping MoE), underflow slots are zero.
* Router: softmax over expert logits, top-k, probs renormalised over the
  selected experts; load-balance auxiliary loss (Switch-style) returned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .layers import dense_init, init_mlp, mlp


def init_moe(rng, cfg: ModelConfig):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    keys = jax.random.split(rng, 5)
    gated = cfg.activation == "silu_gated"
    p = {
        "router": dense_init(keys[0], (D, E), cfg.dtype, scale=0.02),
        "w_in": dense_init(keys[1], (E, D, F), cfg.dtype),
        "w_out": dense_init(keys[2], (E, F, D), cfg.dtype),
    }
    if gated:
        p["w_gate"] = dense_init(keys[3], (E, D, F), cfg.dtype)
    if cfg.num_shared_experts:
        shared_cfg = cfg
        p["shared"] = init_mlp(keys[4], shared_cfg,
                               cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def _expert_ffn(p, cfg: ModelConfig, xs):
    """xs: (E, C, D) → (E, C, D) via per-expert FFN."""
    h = jnp.einsum("ecd,edf->ecf", xs, p["w_in"])
    if cfg.activation == "silu_gated":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _route(p, cfg: ModelConfig, xt):
    """Router top-k + Switch aux loss.  xt: (T, D)."""
    E, K = cfg.num_experts, cfg.top_k
    logits = (xt @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                  # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))
    return top_p, top_e, aux


def _dispatch(cfg: ModelConfig, xt, top_p, top_e, C):
    """Sort tokens by expert, drop past capacity C, build (E, C, D)
    buffers.  Returns (buf, combine metadata)."""
    E, K = cfg.num_experts, cfg.top_k
    T, D = xt.shape
    TK = T * K
    flat_e = top_e.reshape(TK)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_p = top_p.reshape(TK).astype(xt.dtype)
    order = jnp.argsort(flat_e)                              # stable radix
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(TK, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)              # E*C = trash row
    gathered = xt[st] * keep[:, None].astype(xt.dtype)       # (TK, D)
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].add(gathered)
    return buf[:-1].reshape(E, C, D), (se, st, sp, pos, keep)


def _combine(cfg: ModelConfig, expert_out, meta, T):
    E = cfg.num_experts
    C = expert_out.shape[1]
    D = expert_out.shape[-1]
    se, st, sp, pos, keep = meta
    back = expert_out.reshape(E * C, D)[jnp.where(keep, se * C + pos, 0)]
    back = back * (sp * keep.astype(sp.dtype))[:, None]
    return jnp.zeros((T, D), expert_out.dtype).at[st].add(back)


def moe_ffn(p, cfg: ModelConfig, x):
    """x: (B, S, D) → (out, aux_loss).

    ``cfg.moe_groups > 1`` splits the token stream into that many groups
    (aligned with the data-sharding) so the argsort / gather / scatter of
    dispatch+combine stay shard-local; only the batched expert einsum
    communicates (all-to-all to the model/expert axis).  Group capacity
    C_g = ceil(T_g·k/E · capacity_factor) — standard GShard grouping."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    G = max(1, cfg.moe_groups)

    if G == 1:
        top_p, top_e, aux = _route(p, cfg, xt)
        C = int(np.ceil(T * K / E * cfg.capacity_factor))
        buf, meta = _dispatch(cfg, xt, top_p, top_e, C)
        expert_out = _expert_ffn(p, cfg, buf)
        out = _combine(cfg, expert_out, meta, T)
    else:
        assert T % G == 0, (T, G)
        Tg = T // G
        Cg = int(np.ceil(Tg * K / E * cfg.capacity_factor))
        xg = xt.reshape(G, Tg, D)

        def per_group(xt_g):
            top_p, top_e, aux_g = _route(p, cfg, xt_g)
            buf, meta = _dispatch(cfg, xt_g, top_p, top_e, Cg)
            return buf, meta, aux_g

        bufs, metas, auxs = jax.vmap(per_group)(xg)          # (G, E, Cg, D)
        aux = auxs.mean()
        # batched expert einsum: groups stay on the data axis, experts on
        # the model axis ⇒ the ONLY cross-shard exchange of the MoE layer
        expert_out = jnp.einsum("gecd,edf->gecf", bufs, p["w_in"])
        if cfg.activation == "silu_gated":
            expert_out = jax.nn.silu(expert_out) * jnp.einsum(
                "gecd,edf->gecf", bufs, p["w_gate"])
        elif cfg.activation == "squared_relu":
            expert_out = jnp.square(jax.nn.relu(expert_out))
        else:
            expert_out = jax.nn.gelu(expert_out)
        expert_out = jnp.einsum("gecf,efd->gecd", expert_out, p["w_out"])
        out = jax.vmap(lambda eo, m: _combine(cfg, eo, m, Tg))(
            expert_out, metas)
        out = out.reshape(T, D)

    if cfg.num_shared_experts:
        out = out + mlp(p["shared"], cfg, xt)
    return out.reshape(B, S, D), aux
