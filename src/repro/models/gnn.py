"""GraphConv and SAGEConv GNNs in pure JAX over padded sampler blocks.

The forward pass mirrors the paper's §3.2.2: layer ``l`` consumes the
``h^{l-1}`` embeddings of the nodes at hop ``L-(l-1)`` and produces
``h^l`` at hop ``L-l``; rows belonging to *remote* destination nodes are
overwritten from the client's pulled embedding cache instead of being
computed (their neighbourhoods live on other clients).

Everything is functional: parameters are pytrees, blocks are dicts of
padded arrays (see :func:`blocks_to_arrays`), and the train step is a
single jitted function per (shard, batch-size) shape signature.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.sampler import MiniBatch

Params = Any


# -- parameter init ------------------------------------------------------

def init_gnn(
    rng: jax.Array,
    conv: str,
    in_dim: int,
    hidden: int,
    out_dim: int,
    num_layers: int,
) -> Params:
    """Initialise an L-layer GNN.  ``conv`` ∈ {graphconv, sageconv}."""
    assert conv in ("graphconv", "sageconv"), conv
    dims = [in_dim] + [hidden] * (num_layers - 1) + [out_dim]
    layers = []
    for l in range(num_layers):
        rng, k1, k2 = jax.random.split(rng, 3)
        d_in, d_out = dims[l], dims[l + 1]
        scale = jnp.sqrt(2.0 / d_in)
        layer = {"w_neigh": jax.random.normal(k1, (d_in, d_out)) * scale,
                 "b": jnp.zeros((d_out,))}
        if conv == "sageconv":
            layer["w_self"] = jax.random.normal(k2, (d_in, d_out)) * scale
        layers.append(layer)
    # ``conv`` is static (a string) — callers pass it to forward/train_step
    # explicitly so the param pytree stays jit-able.
    return layers


# -- blocks as jit-able pytrees -------------------------------------------

def blocks_to_arrays(mb: MiniBatch) -> dict:
    """Convert a sampled :class:`MiniBatch` to a pytree of arrays."""
    return {
        "blocks": [
            {
                "edge_src": jnp.asarray(b.edge_src, jnp.int32),
                "edge_dst": jnp.asarray(b.edge_dst, jnp.int32),
                "edge_mask": jnp.asarray(b.edge_mask),
                "dst_remote_mask": jnp.asarray(b.dst_remote_mask),
                "dst_remote_slot": jnp.asarray(b.dst_remote_slot, jnp.int32),
                "dst_mask": jnp.asarray(b.dst_mask),
            }
            for b in mb.blocks
        ],
        "input_ids": jnp.asarray(mb.input_ids, jnp.int32),
        "seed_mask": jnp.asarray(mb.seed_mask),
        "seeds": jnp.asarray(mb.seeds, jnp.int32),
    }


def _segment_mean(vals, seg_ids, mask, num_segments):
    w = mask.astype(vals.dtype)
    summed = jax.ops.segment_sum(vals * w[:, None], seg_ids,
                                 num_segments=num_segments)
    cnt = jax.ops.segment_sum(w, seg_ids, num_segments=num_segments)
    return summed / jnp.maximum(cnt, 1.0)[:, None], cnt


def _layer_forward(layer, conv, h_src, blk, *, last: bool):
    n_dst = blk["dst_remote_mask"].shape[0]   # static padded dst size
    gathered = h_src[blk["edge_src"]]
    agg, cnt = _segment_mean(gathered, blk["edge_dst"], blk["edge_mask"], n_dst)
    h_self = h_src[:n_dst]
    if conv == "graphconv":
        # mean over N(u) ∪ {u} (right-normalised GCN over sampled blocks)
        mixed = (agg * cnt[:, None] + h_self) / (cnt[:, None] + 1.0)
        out = mixed @ layer["w_neigh"] + layer["b"]
    else:  # sageconv (mean aggregator)
        out = h_self @ layer["w_self"] + agg @ layer["w_neigh"] + layer["b"]
    if not last:
        out = jax.nn.relu(out)
    return out


def forward(
    params: Params,
    batch: dict,
    features: jax.Array,           # (num_local, F) shard feature table
    caches: Sequence[jax.Array],   # L-1 tables (num_remote_pad, hidden)
    *,
    conv: str,
) -> jax.Array:
    """Returns logits for the (padded) seed set."""
    layers = params
    L = len(layers)
    h = features[batch["input_ids"]]        # hop-L nodes are all local
    for l, (layer, blk) in enumerate(zip(layers, batch["blocks"]), start=1):
        out = _layer_forward(layer, conv, h, blk, last=(l == L))
        if l < L:
            # remote dst rows are served from the h^l cache, not computed
            cached = caches[l - 1][blk["dst_remote_slot"]]
            out = jnp.where(blk["dst_remote_mask"][:, None], cached, out)
        h = out
    return h


def loss_fn(params, batch, features, caches, labels, *, conv):
    logits = forward(params, batch, features, caches, conv=conv)
    seed_labels = labels[batch["seeds"]]
    mask = batch["seed_mask"].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, seed_labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)


@functools.partial(jax.jit, static_argnames=("lr", "conv"))
def sgd_train_step(params, batch, features, caches, labels, *, lr: float,
                   conv: str):
    loss, grads = jax.value_and_grad(
        functools.partial(loss_fn, conv=conv))(params, batch, features,
                                               caches, labels)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss


@functools.partial(jax.jit, static_argnames=("conv",))
def predict(params, batch, features, caches, *, conv):
    logits = forward(params, batch, features, caches, conv=conv)
    return jnp.argmax(logits, axis=-1)


# -- full-shard propagation for push / evaluation --------------------------

def full_propagate(
    params: Params,
    shard_arrays: dict,
    caches: Sequence[jax.Array] | None,
    *,
    conv: str,
) -> list[jax.Array]:
    """Compute h^1..h^L for ALL local vertices of a shard.

    Used (a) to produce push-node embeddings after a round, (b) in the
    pre-training bootstrap (``caches=None`` ⇒ remote neighbours masked,
    matching §3.2.1), and (c) for full-graph evaluation.

    ``shard_arrays`` holds the shard CSR flattened to an edge list:
      edge_src (E,), edge_dst (E,), src_is_remote (E,), num_local,
      features (num_local, F).
    Returns list of per-layer local embeddings [h^1, ..., h^L].
    """
    layers = params
    L = len(layers)
    num_local = shard_arrays["num_local"]
    e_src = shard_arrays["edge_src"]
    e_dst = shard_arrays["edge_dst"]
    remote_e = shard_arrays["src_is_remote"]

    h_local = shard_arrays["features"]
    outs = []
    for l, layer in enumerate(layers, start=1):
        if l == 1 or caches is None:
            # remote sources contribute nothing (h^0 private / no cache)
            mask = ~remote_e
            cache_tbl = jnp.zeros((1, h_local.shape[1]), h_local.dtype)
            src_tbl = jnp.concatenate([h_local, cache_tbl], axis=0)
            src_idx = jnp.where(remote_e, num_local, e_src)
        else:
            mask = jnp.ones_like(remote_e)
            src_tbl = jnp.concatenate([h_local, caches[l - 2]], axis=0)
            src_idx = e_src  # remote ids already offset past num_local
        gathered = src_tbl[src_idx]
        agg, cnt = _segment_mean(gathered, e_dst, mask, num_local)
        if conv == "graphconv":
            mixed = (agg * cnt[:, None] + h_local) / (cnt[:, None] + 1.0)
            out = mixed @ layer["w_neigh"] + layer["b"]
        else:
            out = h_local @ layer["w_self"] + agg @ layer["w_neigh"] + layer["b"]
        if l < L:
            out = jax.nn.relu(out)
        h_local = out
        outs.append(out)
    return outs


def shard_to_arrays(shard) -> dict:
    """Flatten a ClientShard's CSR (local destinations) to jit inputs."""
    e_dst = np.repeat(np.arange(shard.num_local), np.diff(shard.indptr))
    e_src = shard.indices.astype(np.int64)
    remote = e_src >= shard.num_local
    return {
        # remote src ids are already offset past num_local, which is where
        # full_propagate concatenates the cache table — no remap needed.
        "edge_src": jnp.asarray(e_src, jnp.int32),
        "edge_dst": jnp.asarray(e_dst, jnp.int32),
        "src_is_remote": jnp.asarray(remote),
        "num_local": shard.num_local,
        "features": jnp.asarray(shard.features, jnp.float32),
    }
