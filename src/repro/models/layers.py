"""Transformer building blocks shared by the architecture zoo.

Conventions: activations are (B, S, D); attention internals (B, S, H, dh);
KV caches (B, T, Hkv, dh) with an int32 write index.  Softmax statistics
are float32 regardless of param dtype.

Attention is *blocked* over the KV axis with an online-softmax
``lax.scan`` (flash-attention recurrence in stock XLA) so that prefill at
32k and train at 4k never materialise (S × S) score tensors.  The Pallas
sliding-window kernel in ``repro.kernels.swa_attention`` implements the
same contract for TPU; ``repro.kernels.ops`` dispatches between them.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# -- initialisers --------------------------------------------------------------

def dense_init(rng, shape, dtype, *, scale: float | None = None):
    fan_in = shape[0] if len(shape) <= 2 else int(np.prod(shape[:-1]))
    s = scale if scale is not None else (1.0 / np.sqrt(fan_in))
    return (jax.random.normal(rng, shape, jnp.float32) * s).astype(dtype)


def maybe_bias(cfg: ModelConfig, shape):
    return jnp.zeros(shape, cfg.dtype) if cfg.use_bias else None


def add_bias(x, b):
    return x if b is None else x + b


# -- norms ----------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def gated_rms_norm(x, z, weight, eps: float = 1e-5):
    """Mamba2 output norm: RMSNorm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    weight, eps)


# -- rotary embeddings -----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float, *, head_axis: bool = True):
    """x: (..., S, H, dh) if head_axis else (..., S, dh);
    positions: (..., S) broadcastable against x's leading dims."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    if head_axis:
        angles = angles[..., None, :]                  # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLPs -------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig, d_ff: int):
    D = cfg.d_model
    k = jax.random.split(rng, 3)
    p = {"w_out": dense_init(k[0], (d_ff, D), cfg.dtype),
         "b_out": maybe_bias(cfg, (D,)),
         "w_in": dense_init(k[1], (D, d_ff), cfg.dtype),
         "b_in": maybe_bias(cfg, (d_ff,))}
    if cfg.activation == "silu_gated":
        p["w_gate"] = dense_init(k[2], (D, d_ff), cfg.dtype)
    return p


def mlp(p, cfg: ModelConfig, x):
    h = add_bias(x @ p["w_in"], p.get("b_in"))
    if cfg.activation == "silu_gated":
        h = jax.nn.silu(h) * (x @ p["w_gate"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.activation)
    return add_bias(h @ p["w_out"], p.get("b_out"))


# -- blocked attention core --------------------------------------------------------

NEG_INF = -1e30


def blocked_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                      window: int | None, kv_block: int = 512,
                      kv_valid=None):
    """Online-softmax attention, blocked over KV.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh) with H = G·Hkv.
    positions: (Sq,) and (Skv,) absolute token indices (already offset for
    prefill continuation / ring buffers).  ``kv_valid``: optional (B, Skv)
    bool mask for partially-filled caches.
    Returns (B, Sq, H, dh).
    """
    B, Sq, H, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, Sq, Hkv, G, dh).astype(jnp.float32) * scale

    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    if pad:
        padk = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k = jnp.pad(k, padk)
        v = jnp.pad(v, padk)
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=jnp.iinfo(jnp.int32).max // 2)
        if kv_valid is None:
            kv_valid = jnp.arange(nb * kv_block) < Skv
            kv_valid = jnp.broadcast_to(kv_valid, (B, nb * kv_block))
        else:
            kv_valid = jnp.pad(kv_valid, [(0, 0), (0, pad)])
    elif kv_valid is None:
        kv_valid = jnp.ones((B, Skv), bool)

    kb = k.reshape(B, nb, kv_block, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, Hkv, dh).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(nb, kv_block)
    mb = kv_valid.reshape(B, nb, kv_block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry
        kb_, vb_, pb_, mb_ = blk
        s = jnp.einsum("bqkgd,btkd->bqkgt", qg, kb_.astype(jnp.float32))
        mask = mb_[:, None, None, None, :]
        if causal:
            mask = mask & (q_positions[None, :, None, None, None]
                           >= pb_[None, None, None, None, :])
        if window is not None:
            mask = mask & (q_positions[None, :, None, None, None] - window
                           < pb_[None, None, None, None, :])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgt,btkd->bqkgd", p, vb_.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, dh), jnp.float32)
    # remat each KV block: without this the scan's backward saves every
    # block's softmax numerator — i.e. the full (S × S) scores the blocking
    # exists to avoid (flash attention recomputes p in the backward pass).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kb, vb, pb, mb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, q_position, kv_positions,
                     window: int | None, kv_valid):
    """Single-token attention against a cache.

    q: (B, 1, H, dh); caches (B, T, Hkv, dh); q_position (B,) absolute;
    kv_positions (B, T) absolute; kv_valid (B, T)."""
    B, _, H, dh = q.shape
    _, T, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, Hkv, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    mask = kv_valid & (kv_positions <= q_position[:, None])
    if window is not None:
        mask = mask & (kv_positions > q_position[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# -- GQA attention layer --------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig):
    D, H, Hkv, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
    k = jax.random.split(rng, 4)
    return {
        "wq": dense_init(k[0], (D, H, dh), cfg.dtype),
        "wk": dense_init(k[1], (D, Hkv, dh), cfg.dtype),
        "wv": dense_init(k[2], (D, Hkv, dh), cfg.dtype),
        "wo": dense_init(k[3], (H, dh, D), cfg.dtype),
        "bq": maybe_bias(cfg, (H, dh)),
        "bk": maybe_bias(cfg, (Hkv, dh)),
        "bv": maybe_bias(cfg, (Hkv, dh)),
        "bo": maybe_bias(cfg, (D,)),
    }


def attention(p, cfg: ModelConfig, x, *, positions, window=None,
              kv_block: int = 512):
    """Full-sequence (train / prefill) GQA self-attention.
    Returns (out, (k, v)) so prefill can seed a cache."""
    q = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), p.get("bq"))
    k = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), p.get("bk"))
    v = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), p.get("bv"))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = blocked_attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            window=window, kv_block=kv_block)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return add_bias(out, p.get("bo")), (k, v)


def attention_decode(p, cfg: ModelConfig, x, cache, *, window=None):
    """One-token decode.  ``cache``: {"k","v": (B,T,Hkv,dh), "pos": (B,T)
    absolute positions, "index": (B,) ring write slot, "length": (B,)
    tokens seen}.  Returns (out, new_cache)."""
    B = x.shape[0]
    q = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), p.get("bq"))
    k = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), p.get("bk"))
    v = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), p.get("bv"))
    pos = cache["length"]                       # (B,) absolute position
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    T = cache["k"].shape[1]
    slot = cache["index"]                       # (B,)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    kv_pos = cache["pos"].at[bidx, slot].set(pos)
    kv_valid = cache["valid"].at[bidx, slot].set(True)
    out = decode_attention(q, k_cache, v_cache, q_position=pos,
                           kv_positions=kv_pos, window=window,
                           kv_valid=kv_valid)
    out = add_bias(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), p.get("bo"))
    new_cache = {"k": k_cache, "v": v_cache, "pos": kv_pos,
                 "valid": kv_valid, "index": (slot + 1) % T,
                 "length": pos + 1}
    return out, new_cache


def _cache_bookkeeping(batch: int, capacity: int, length: int):
    """Shared ring-buffer metadata for a cache that has already absorbed
    ``length`` tokens (length ≤ capacity for eager inits; dry-runs pass
    caches as ShapeDtypeStructs so contents never materialise)."""
    assert length <= capacity, "eager cache init expects length <= capacity"
    return {
        "pos": jnp.broadcast_to(jnp.arange(capacity, dtype=jnp.int32),
                                (batch, capacity)),
        "valid": jnp.broadcast_to(jnp.arange(capacity) < length,
                                  (batch, capacity)),
        "index": jnp.full((batch,), length % capacity, jnp.int32),
        "length": jnp.full((batch,), length, jnp.int32),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int,
                  prefill_len: int | None = None):
    """Empty (or "already saw prefill_len tokens") ring-buffer KV cache."""
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    out = {
        "k": jnp.zeros((batch, capacity, Hkv, dh), cfg.dtype),
        "v": jnp.zeros((batch, capacity, Hkv, dh), cfg.dtype),
    }
    out.update(_cache_bookkeeping(batch, capacity, prefill_len or 0))
    return out


# -- cross attention (VLM / enc-dec) -----------------------------------------------------

def init_cross_attention(rng, cfg: ModelConfig):
    return init_attention(rng, cfg)


def cross_attention(p, cfg: ModelConfig, x, memory, *, kv_block: int = 512):
    """Attend from x (B,Sq,D) to a static memory (B,Sm,D), non-causal."""
    Sm = memory.shape[1]
    q = add_bias(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), p.get("bq"))
    k = add_bias(jnp.einsum("bsd,dhk->bshk", memory, p["wk"]), p.get("bk"))
    v = add_bias(jnp.einsum("bsd,dhk->bshk", memory, p["wv"]), p.get("bv"))
    Sq = x.shape[1]
    out = blocked_attention(
        q, k, v, q_positions=jnp.zeros(Sq, jnp.int32),
        kv_positions=jnp.zeros(Sm, jnp.int32), causal=False, window=None,
        kv_block=kv_block)
    return add_bias(jnp.einsum("bshk,hkd->bsd", out, p["wo"]), p.get("bo"))


# -- MLA (deepseek multi-head latent attention) --------------------------------------------

def init_mla(rng, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.num_heads
    r, nope, vd = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.v_head_dim
    rp = cfg.qk_rope_head_dim
    k = jax.random.split(rng, 5)
    return {
        "wq": dense_init(k[0], (D, H, nope + rp), cfg.dtype),
        "w_dkv": dense_init(k[1], (D, r), cfg.dtype),
        "w_kr": dense_init(k[2], (D, rp), cfg.dtype),
        "w_uk": dense_init(k[3], (r, H, nope), cfg.dtype),
        "w_uv": dense_init(k[3], (r, H, vd), cfg.dtype),
        "wo": dense_init(k[4], (H, vd, D), cfg.dtype),
    }


def mla_attention(p, cfg: ModelConfig, x, *, positions, kv_block: int = 512):
    """Full-sequence MLA.  Returns (out, (c_kv, k_rope)) for cache seeding."""
    nope, rp = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = x @ p["w_dkv"]                                   # (B,S,r)
    k_rope = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta,
                        head_axis=False)          # (B,S,rp)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    # fold the shared-rope single head in as extra feature dims of k/q
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (rp,))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    vd = cfg.v_head_dim
    v_pad = jnp.pad(v, [(0, 0), (0, 0), (0, 0), (0, nope + rp - vd)]) \
        if vd < nope + rp else v
    out = blocked_attention(q_full, k_full, v_pad, q_positions=positions,
                            kv_positions=positions, causal=True, window=None,
                            kv_block=kv_block)[..., :vd]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (c_kv, k_rope)


def mla_decode(p, cfg: ModelConfig, x, cache, *, absorb: bool = False,
               window: int | None = None):
    """One-token MLA decode against the latent cache {c_kv, k_rope}.

    ``absorb=False``: reconstruct per-head K/V from c_kv each step (naive,
    paper-faithful baseline).  ``absorb=True``: fold w_uk into the query
    and w_uv into the output projection so attention runs directly in the
    512-d latent space — the DeepSeek-V2 matrix-absorption optimization
    (§Perf hillclimb).
    """
    B = x.shape[0]
    nope, rp, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                    cfg.v_head_dim)
    r = cfg.kv_lora_rank
    pos = cache["length"]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    c_kv_t = x @ p["w_dkv"]
    k_rope_t = apply_rope(x @ p["w_kr"], pos[:, None], cfg.rope_theta,
                          head_axis=False)
    T = cache["c_kv"].shape[1]
    slot = cache["index"]
    bidx = jnp.arange(B)
    c_cache = cache["c_kv"].at[bidx, slot].set(c_kv_t[:, 0])
    r_cache = cache["k_rope"].at[bidx, slot].set(k_rope_t[:, 0])
    kv_pos = cache["pos"].at[bidx, slot].set(pos)
    kv_valid = cache["valid"].at[bidx, slot].set(True)
    mask = kv_valid & (kv_pos <= pos[:, None])
    if window is not None:
        mask = mask & (kv_pos > pos[:, None] - window)

    if absorb:
        # score = (q_nope · w_uk)ᵀ c_kv + q_rope · k_rope : O(T·r) per head
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
        s = jnp.einsum("bshr,btr->bhst", q_lat, c_cache.astype(q_lat.dtype))
        s = s + jnp.einsum("bshk,btk->bhst", q_rope,
                           r_cache.astype(q_rope.dtype))
        s = (s / np.sqrt(nope + rp)).astype(jnp.float32)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", pr.astype(c_cache.dtype), c_cache)
        out = jnp.einsum("bshr,rhk->bshk", ctx, p["w_uv"])
    else:
        k_nope = jnp.einsum("btr,rhk->bthk", c_cache, p["w_uk"])
        v = jnp.einsum("btr,rhk->bthk", c_cache, p["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(r_cache[:, :, None, :],
                                      k_nope.shape[:3] + (rp,))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        v_pad = jnp.pad(v, [(0, 0), (0, 0), (0, 0), (0, nope + rp - vd)]) \
            if vd < nope + rp else v
        out = decode_attention(q_full, k_full, v_pad, q_position=pos,
                               kv_positions=kv_pos, window=window,
                               kv_valid=kv_valid)[..., :vd]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cache = {"c_kv": c_cache, "k_rope": r_cache, "pos": kv_pos,
                 "valid": kv_valid, "index": (slot + 1) % T,
                 "length": pos + 1}
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int,
                   prefill_len: int | None = None):
    out = {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), cfg.dtype),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_head_dim),
                            cfg.dtype),
    }
    out.update(_cache_bookkeeping(batch, capacity, prefill_len or 0))
    return out
