"""RunConfig: one declarative description of a federated deployment.

Every participant of a control-plane run — the coordinator CLI, each
worker CLI, tests, benchmarks — must construct *the same* graph,
partition, shards, samplers, and model init, or the distributed round
diverges from the in-process simulator.  RunConfig captures everything
those constructions depend on and rebuilds them deterministically
(synthetic graphs are generated from ``(preset, scale, graph_seed)``;
partitions/samplers/model init from ``seed``), so a JSON blob or an
argv vector fully pins a deployment.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core import FederatedGNNTrainer, Strategy, default_strategies


@dataclasses.dataclass
class RunConfig:
    #: synthetic preset name ("reddit", scaled by ``scale``/``graph_seed``)
    #: or an out-of-core graph spec "store:<dir>" — a prebuilt mmap
    #: GraphStore every participant opens read-only (its baked partition
    #: / shard files make a worker load exactly its clients' shards
    #: instead of regenerating the graph per process)
    graph: str = "reddit"
    scale: float = 0.05
    graph_seed: int = 3
    num_clients: int = 2
    strategy: str = "E"
    # Strategy field overrides (codec, delta_threshold, aggregation,
    # buffer_size, error_feedback, ...) applied via dataclasses.replace
    overrides: dict = dataclasses.field(default_factory=dict)
    conv: str = "graphconv"
    num_layers: int = 3
    hidden: int = 32
    fanout: int = 5
    batch_size: int = 64
    epochs_per_round: int = 3
    lr: float = 1e-2
    seed: int = 0
    rounds: int = 2
    embed_addrs: list = dataclasses.field(default_factory=list)
    #: dynamic graphs: a GrowthSchedule as a plain dict
    #: (``GrowthSchedule.to_dict()`` — JSON-safe, so a RunConfig blob
    #: still fully pins the deployment).  None = static graph.  Every
    #: participant builds its own GrowthRuntime from it, so workers in
    #: different processes grow identically without exchanging state.
    growth: Optional[dict] = None

    # -- construction ------------------------------------------------------

    def build_strategy(self) -> Strategy:
        base = default_strategies()[self.strategy]
        over = dict(self.overrides)
        if self.embed_addrs and "transport" not in over:
            over["transport"] = "tcp"
        return dataclasses.replace(base, **over) if over else base

    def build_graph(self):
        if self.graph.startswith("store:"):
            from repro.graphstore import open_store
            return open_store(self.graph[len("store:"):])
        from repro.graphs import make_graph
        return make_graph(self.graph, scale=self.scale,
                          seed=self.graph_seed)

    def build_trainer(self, *, embeddings: Optional[bool] = None,
                      only_clients: Optional[list] = None
                      ) -> FederatedGNNTrainer:
        """The full trainer a worker runs ``client_round`` on.  Pass
        ``embeddings=False`` for a participant that only needs model
        init + evaluation (the coordinator) — it skips the exchange and
        never touches the embed shards, while partition/model init stay
        identical.  ``only_clients`` builds samplers / caches /
        registrations for just those clients (the fed_worker path); on a
        ``store:`` graph with prebuilt shard files the worker then mmaps
        only its own shards and never re-scans the graph."""
        st = self.build_strategy()
        if embeddings is False:
            st = dataclasses.replace(st, use_embeddings=False,
                                     transport="auto")
        addrs = self.embed_addrs or None
        if not st.use_embeddings or st.transport != "tcp":
            addrs = None
        g = self.build_graph()
        part, shards = None, None
        if getattr(g, "is_store", False):
            part = g.load_partition(self.num_clients, self.seed)
            limit = st.retention_limit if st.use_embeddings else 0
            if part is not None and \
                    g.has_shards(self.num_clients, self.seed, limit):
                owned = range(self.num_clients) if only_clients is None \
                    else only_clients
                shards = [None] * self.num_clients
                for c in owned:
                    shards[c] = g.load_shard(c, self.num_clients,
                                             self.seed, limit)
        growth = None
        if self.growth:
            from repro.dyngraph import GrowthRuntime, GrowthSchedule
            growth = GrowthRuntime(
                GrowthSchedule.from_dict(self.growth), g,
                self.num_clients, method=st.restream,
                passes=st.restream_passes, seed=self.seed)
        return FederatedGNNTrainer(
            g, self.num_clients, st,
            conv=self.conv, num_layers=self.num_layers,
            hidden=self.hidden, fanout=self.fanout,
            batch_size=self.batch_size,
            epochs_per_round=self.epochs_per_round, lr=self.lr,
            transport_addrs=addrs, seed=self.seed,
            part=part, shards=shards, only_clients=only_clients,
            growth=growth)

    # -- (de)serialisation -------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, blob: str) -> "RunConfig":
        return cls(**json.loads(blob))

    # -- argparse plumbing (shared by both CLIs) ---------------------------

    @staticmethod
    def add_args(ap) -> None:
        ap.add_argument("--graph", default="reddit")
        ap.add_argument("--scale", type=float, default=0.05)
        ap.add_argument("--graph-seed", type=int, default=3)
        ap.add_argument("--clients", type=int, default=2,
                        help="total number of federated clients K")
        ap.add_argument("--strategy", default="E",
                        help="strategy name from default_strategies()")
        ap.add_argument("--set", action="append", default=[],
                        metavar="FIELD=VALUE", dest="overrides",
                        help="Strategy field override, JSON-valued "
                             "(e.g. --set codec='\"int8\"' "
                             "--set delta_threshold=0.05); bare strings "
                             "also accepted (--set codec=int8)")
        ap.add_argument("--conv", default="graphconv")
        ap.add_argument("--num-layers", type=int, default=3)
        ap.add_argument("--hidden", type=int, default=32)
        ap.add_argument("--fanout", type=int, default=5)
        ap.add_argument("--batch-size", type=int, default=64)
        ap.add_argument("--epochs", type=int, default=3)
        ap.add_argument("--lr", type=float, default=1e-2)
        ap.add_argument("--seed", type=int, default=0)
        ap.add_argument("--rounds", type=int, default=2)
        ap.add_argument("--embed", action="append", default=[],
                        metavar="HOST:PORT", dest="embed_addrs",
                        help="embed_server shard address (repeatable)")
        ap.add_argument("--growth", default=None, metavar="JSON",
                        help="GrowthSchedule as JSON (repro.dyngraph): "
                             "the run applies seeded growth events at "
                             "round boundaries")

    @classmethod
    def from_args(cls, args) -> "RunConfig":
        overrides = {}
        for item in args.overrides:
            key, _, val = item.partition("=")
            try:
                overrides[key] = json.loads(val)
            except json.JSONDecodeError:
                overrides[key] = val          # bare string convenience
        return cls(graph=args.graph, scale=args.scale,
                   graph_seed=args.graph_seed, num_clients=args.clients,
                   strategy=args.strategy, overrides=overrides,
                   conv=args.conv, num_layers=args.num_layers,
                   hidden=args.hidden, fanout=args.fanout,
                   batch_size=args.batch_size, epochs_per_round=args.epochs,
                   lr=args.lr, seed=args.seed, rounds=args.rounds,
                   embed_addrs=list(args.embed_addrs),
                   growth=json.loads(args.growth)
                   if getattr(args, "growth", None) else None)


class EvalHarness:
    """The coordinator's model-side hooks: deterministic init leaves and
    held-out evaluation, built from the same RunConfig as the workers
    (embeddings off — the coordinator never touches embed shards)."""

    def __init__(self, cfg: RunConfig):
        self.trainer = cfg.build_trainer(embeddings=False)
        self._evals = 0     # completed evaluations == closed sync rounds

    def init_leaves(self):
        return self.trainer.params_leaves()

    def evaluate_leaves(self, leaves) -> float:
        tr = self.trainer
        if tr.growth is not None:
            # sync aggregation evaluates exactly once per round, in
            # round order: evaluation #r closes round r, whose graph
            # carries epoch_for_round(r) — same jump the workers applied
            # at the top of the round, so the held-out sample tracks the
            # grown graph
            tr.apply_growth(tr.growth.epoch_for_round(self._evals),
                            self._evals)
        self._evals += 1
        return tr.evaluate(tr.leaves_to_params(leaves))


def make_coordinator_state(cfg: RunConfig, *, harness: EvalHarness | None
                           = None, net=None):
    """One CoordinatorState wired from a RunConfig's strategy — the
    single place the control-plane knobs (aggregation mode, FedBuff
    buffer, weight codec, client sampling) flow from Strategy into the
    coordinator, shared by the CLI, benchmarks, and tests so they can
    never drift."""
    from .coordinator import CoordinatorState   # avoid import cycle
    st = cfg.build_strategy()
    harness = EvalHarness(cfg) if harness is None else harness
    growth = None
    if cfg.growth:
        from repro.dyngraph import GrowthSchedule
        growth = GrowthSchedule.from_dict(cfg.growth)
    return CoordinatorState(
        num_clients=cfg.num_clients, num_rounds=cfg.rounds,
        mode=st.aggregation, buffer_size=st.buffer_size,
        staleness_decay=st.staleness_decay,
        weight_codec=st.weight_codec,
        sample_frac=st.sample_frac, sample_seed=cfg.seed,
        init_leaves=harness.init_leaves(),
        eval_fn=harness.evaluate_leaves, net=net, growth=growth)
