"""Coordinator wire protocol: JSON headers + raw tensor blocks.

Reuses the length-prefixed framing of :mod:`repro.exchange.wire` (one
``uint32 LE length | body`` frame per RPC, ``uint8 status`` responses)
with its own opcode space.  Every request/response body is::

    uint8 opcode (request) / status (response)
    uint32 LE header length | UTF-8 JSON header
    tensor blocks (wire.build_tensors)

JSON carries the small stuff (round indices, weights, losses, phase
timings); tensors carry model leaves *byte-exactly* — the JSON side
never touches float payloads, so a model served, trained, and
re-submitted round-trips bit-for-bit.

Blocking semantics live server-side: ``get_model`` and ``wait_pulled``
RPCs simply do not answer until their condition holds (each worker
connection has a dedicated server thread, mirroring embed_server).

Opcodes 16–31 belong to this plane; repro-lint (family WP) checks the
``build_body``/``parse_body`` layout and the pinned registry in
:mod:`repro.analysis.rules_wire` — keep both in sync when renumbering.
"""

from __future__ import annotations

import json
import socket
import struct

import numpy as np

from repro.exchange import wire

# -- opcodes (disjoint from the embedding plane's 1..5 for debuggability) ----

OP_HELLO = 16        # register worker + client ids, optionally seed model
OP_GET_MODEL = 17    # blocking in sync mode: current global model
OP_PULLED = 18       # sync: this worker's clients filled their caches
OP_WAIT_PULLED = 19  # sync: block until every active client pulled
OP_UPDATE = 20       # submit one client's trained params / async delta
OP_COORD_STATS = 21        # coordinator telemetry snapshot (JSON)
OP_COORD_SHUTDOWN = 22     # stop the service

_U32 = struct.Struct("<I")


# -- body build/parse ---------------------------------------------------------

def build_body(op_or_status: int, header: dict,
               tensors=()) -> bytes:
    blob = json.dumps(header, separators=(",", ":")).encode("utf-8")
    return (bytes([op_or_status]) + _U32.pack(len(blob)) + blob
            + (wire.build_tensors(tensors) if tensors else b""))


def parse_body(body: bytes) -> tuple[int, dict, list[np.ndarray]]:
    """→ (opcode/status, header, tensors).  Tensors absent → []."""
    view = memoryview(body)
    op = view[0]
    (hlen,) = _U32.unpack_from(view, 1)
    off = 1 + _U32.size
    header = json.loads(bytes(view[off:off + hlen]).decode("utf-8"))
    off += hlen
    tensors: list[np.ndarray] = []
    if off < len(view):
        tensors, _ = wire.parse_tensors(view, off)
    return op, header, tensors


STATUS_OK = wire.STATUS_OK
STATUS_ERR = wire.STATUS_ERR


def build_ok(header: dict | None = None, tensors=()) -> bytes:
    return build_body(STATUS_OK, header or {}, tensors)


def build_err(message: str) -> bytes:
    return build_body(STATUS_ERR, {"error": message})


def parse_reply(body: bytes) -> tuple[dict, list[np.ndarray]]:
    status, header, tensors = parse_body(body)
    if status != STATUS_OK:
        raise RuntimeError(f"coordinator error: {header.get('error', '?')}")
    return header, tensors


# -- client stub --------------------------------------------------------------

class CoordinatorClient:
    """One worker's connection to the coordinator.

    A single persistent socket; RPCs are strictly sequential (a worker
    is single-threaded), and the blocking calls (:meth:`get_model`,
    :meth:`wait_pulled`) park on the socket read until the coordinator
    answers — no client-side polling."""

    def __init__(self, addr, *, connect_timeout: float = 10.0):
        from repro.exchange.socket_transport import parse_address
        self.addr = parse_address(addr)
        self.sock = socket.create_connection(self.addr,
                                             timeout=connect_timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.sock.settimeout(None)      # blocking RPCs can span a round

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _rpc(self, op: int, header: dict,
             tensors=()) -> tuple[dict, list[np.ndarray]]:
        wire.send_frame(self.sock, build_body(op, header, tensors))
        resp = wire.recv_frame(self.sock)
        if resp is None:
            raise ConnectionError("coordinator closed connection")
        return parse_reply(resp)

    # -- RPC surface -------------------------------------------------------

    def hello(self, worker_id: str, client_ids: list[int],
              init_leaves=None) -> dict:
        """Register (or *re*-register: a re-hello with the same
        ``worker_id``/``client_ids`` on a fresh connection is a worker
        re-join, and catches up from the current model).  The first
        worker to carry ``init_leaves`` seeds the global model (every
        worker inits identically from the shared seed, so any of them
        is authoritative).  ``has_init`` is true only for a *non-empty*
        leaf list — an empty list is "no init", not a zero-parameter
        model."""
        leaves = list(init_leaves) if init_leaves is not None else []
        h, _ = self._rpc(OP_HELLO,
                         {"worker_id": worker_id,
                          "client_ids": [int(c) for c in client_ids],
                          "has_init": len(leaves) > 0},
                         leaves)
        return h

    def get_model(self, round_idx: int, *,
                  have_version: int = -1) -> tuple[dict, list[np.ndarray]]:
        """Sync: blocks until round ``round_idx`` is open (the previous
        round aggregated).  Async: returns the latest model at once.
        ``have_version`` is the serial of the model view this worker
        already holds (-1 = none): when the coordinator runs a weight
        codec and its served-view record matches, the response is a
        codec-encoded version diff (header kind="delta") instead of the
        full model.  Header carries {round, version, serial, done,
        kind, [codec, shapes], [sampled]}."""
        return self._rpc(OP_GET_MODEL, {"round": int(round_idx),
                                        "have_version": int(have_version)})

    def pulled(self, round_idx: int, client_ids: list[int]) -> None:
        self._rpc(OP_PULLED, {"round": int(round_idx),
                              "client_ids": [int(c) for c in client_ids]})

    def wait_pulled(self, round_idx: int) -> None:
        """Blocks until every active client reported pulled for the
        round — the all-pulled-before-anyone-pushes barrier that keeps
        the embedding plane static within a sync round."""
        self._rpc(OP_WAIT_PULLED, {"round": int(round_idx)})

    def update(self, header: dict, leaves) -> dict:
        """Submit one client's update.  Sync headers carry
        {round, client_id, weight, loss, modelled_s, measured_s} with
        full param leaves; async carries {version, ...} with delta
        leaves (kind="delta")."""
        h, _ = self._rpc(OP_UPDATE, header, leaves)
        return h

    def stats(self) -> dict:
        h, _ = self._rpc(OP_COORD_STATS, {})
        return h

    def shutdown(self) -> None:
        try:
            self._rpc(OP_COORD_SHUTDOWN, {})
        except (ConnectionError, OSError, RuntimeError):
            pass
        self.close()
