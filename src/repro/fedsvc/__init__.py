"""Federated control plane: coordinator service + client workers.

PR 2 made the *embedding* plane real (live TCP embed_server shards);
this package makes the *weight* plane real.  Instead of
``FederatedGNNTrainer.run_round`` iterating clients sequentially and
FedAvg-aggregating inline, the deployment decomposes into:

  coordinator.py — threaded TCP service (length-prefixed framing reused
                   from repro.exchange.wire) that registers workers,
                   serves the current global model, collects per-round
                   client updates, and aggregates with pluggable
                   policies: synchronous FedAvg (bit-compatible with the
                   in-process trainer) or asynchronous FedBuff-style
                   buffered aggregation with staleness-weighted deltas
                   (Strategy.buffer_size / staleness_decay).
  worker.py      — a client process wrapping one or more clients' share
                   of the trainer round (sampling, pull/dynamic-pull/
                   push through ExchangeClient + TcpTransport, local
                   epochs, overlap push) via the refactored
                   ``FederatedGNNTrainer.client_round``; scenario
                   injection (pacing multiplier, straggler delay,
                   dropout probability) with dual modelled/measured
                   round-time ledgers, same discipline as TcpTransport.
  protocol.py    — the coordinator wire protocol: JSON headers + raw
                   tensor blocks, byte-exact model round-trips.
  aggregation.py — the pure math, shared by the in-process trainer and
                   the coordinator so the two paths cannot drift.
  runtime.py     — RunConfig: one declarative description of a
                   deployment that every participant (coordinator CLI,
                   worker CLI, tests, benchmarks) rebuilds
                   deterministically.

CLIs live in repro.launch.fed_coordinator / repro.launch.fed_worker;
``benchmarks/bench_control_plane.py`` compares sync vs async
time-to-accuracy under injected stragglers.
"""

# Lazy exports (PEP 562): importing repro.fedsvc.aggregation from
# repro.core must not drag in the worker (which imports repro.core).
_EXPORTS = {
    "fedavg_leaves": "aggregation",
    "staleness_scale": "aggregation",
    "apply_buffered_deltas": "aggregation",
    "CoordinatorClient": "protocol",
    "CoordinatorState": "coordinator",
    "serve_in_thread": "coordinator",
    "FedWorker": "worker",
    "WorkerScenario": "worker",
    "run_in_thread": "worker",
    "RunConfig": "runtime",
    "EvalHarness": "runtime",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
