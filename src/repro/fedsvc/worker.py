"""FedWorker: the client-process side of the control plane.

A worker owns one or more clients of the deployment and runs their
share of every round through the refactored
:meth:`repro.core.federated.FederatedGNNTrainer.client_round` —
sampling, pull/dynamic-pull through ExchangeClient (TcpTransport
against the embed shards), local epochs, overlap push planning — and
exchanges *weights* with the coordinator over
:class:`repro.fedsvc.protocol.CoordinatorClient`.

Sync round protocol (bit-compatible with the in-process simulator)::

    get_model(r)            # blocks until round r open (+ assembly)
    fill caches (pull)      # the round's only embedding reads
    pulled(r)               # non-blocking notify
    client_round(...)       # local epochs; push planned, not applied
    wait_pulled(r)          # barrier: server static within the round
    apply push plans        # embedding writes land
    update(r, params, ...)  # coordinator FedAvgs when all K arrived

Async (FedBuff-style): no barriers — pull, train, push, submit
``delta = local − base`` tagged with the model version it trained
from, then immediately fetch the newest model and go again.

Scenario injection (:class:`WorkerScenario`): a pacing multiplier and a
fixed straggler delay stretch this worker's round both in *measured*
wall-clock (real sleeps) and in the *modelled* ledger (the same
multiplier applied to the NetworkModel-based ``client_time``), so the
two ledgers stay comparable — the TcpTransport discipline.  A dropout
probability makes the worker die mid-round (after the pull barrier,
before its update), which exercises the coordinator's deregistration
path.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import FederatedGNNTrainer

from .protocol import CoordinatorClient
from .runtime import RunConfig


@dataclasses.dataclass
class WorkerScenario:
    """Injected heterogeneity for one worker."""
    pacing: float = 1.0         # >1: this worker is uniformly slower
    straggler_s: float = 0.0    # fixed extra seconds per round
    dropout_prob: float = 0.0   # per-round chance of dying mid-round
    seed: int = 0

    def round_delay(self, measured_train_s: float) -> float:
        return max(0.0, (self.pacing - 1.0) * measured_train_s) \
            + self.straggler_s


class WorkerDropout(Exception):
    """Raised internally when the scenario kills the worker mid-round."""


class FedWorker:
    def __init__(self, cfg: RunConfig, client_ids: list[int],
                 coordinator_addr, *, worker_id: str | None = None,
                 scenario: WorkerScenario | None = None,
                 trainer: FederatedGNNTrainer | None = None):
        self.cfg = cfg
        self.client_ids = sorted(int(c) for c in client_ids)
        self.addr = coordinator_addr
        self.worker_id = worker_id or \
            "worker-" + "-".join(str(c) for c in self.client_ids)
        self.scenario = scenario or WorkerScenario()
        self._rng = np.random.default_rng(self.scenario.seed)
        self.trainer = trainer if trainer is not None else cfg.build_trainer()
        self.records: list[dict] = []     # one per completed local round
        self.dropped = False              # scenario killed this worker
        self.disconnected = False         # coordinator went away mid-run

    # -- lifecycle ---------------------------------------------------------

    def run(self) -> list[dict]:
        """Train until the coordinator reports done (or the scenario
        kills this worker).  Returns the per-round records."""
        tr = self.trainer
        # §3.2.1 pretrain: seed the embed shards with this worker's
        # rows *before* registering — the coordinator's assembly gate
        # guarantees nobody pulls until every worker got here.
        tr.pretrain_round(self.client_ids)
        client = CoordinatorClient(self.addr)
        try:
            hello = client.hello(self.worker_id, self.client_ids,
                                 init_leaves=tr.params_leaves())
            if hello["mode"] == "sync":
                self._run_sync(client, start_round=int(hello["round"]))
            else:
                self._run_async(client)
        except WorkerDropout:
            self.dropped = True
        except (ConnectionError, OSError):
            # the coordinator stopped (timeout, lingered out, or died)
            # mid-RPC: end gracefully, keeping the completed records
            self.disconnected = True
        finally:
            client.close()
        return self.records

    def _maybe_drop(self) -> None:
        if self.scenario.dropout_prob > 0 \
                and self._rng.random() < self.scenario.dropout_prob:
            raise WorkerDropout(self.worker_id)

    # -- sync --------------------------------------------------------------

    def _run_sync(self, client: CoordinatorClient, start_round: int) -> None:
        tr = self.trainer
        r = start_round
        while True:
            head, leaves = client.get_model(r)
            if head["done"]:
                return
            r = int(head["round"])
            t_start = time.perf_counter()
            params = tr.leaves_to_params(leaves)
            tr.set_round_tau(r, head.get("accs", ()))
            for ci in self.client_ids:
                tr._fill_cache(ci)
            client.pulled(r, self.client_ids)
            # dropout lands after the pull barrier contribution and
            # before any update — the nastiest spot for the coordinator
            self._maybe_drop()
            results = [tr.client_round(ci, params, fill_cache=False)
                       for ci in self.client_ids]
            t_train = time.perf_counter() - t_start
            delay = self.scenario.round_delay(t_train)
            if delay > 0:
                time.sleep(delay)
            client.wait_pulled(r)
            for res in results:
                if res.push_plan is not None:
                    tr.ex_clients[res.client_id].apply_push(res.push_plan)
            measured = time.perf_counter() - t_start
            for res in results:
                client.update(
                    {"round": r, "client_id": res.client_id,
                     "weight": res.weight, "loss": res.loss,
                     "modelled_s": res.client_time * self.scenario.pacing
                     + self.scenario.straggler_s,
                     "measured_s": measured},
                    tr.params_leaves(res.params))
            self.records.append({
                "round": r, "clients": self.client_ids,
                "measured_s": measured,
                "modelled_s": max(res.client_time for res in results)
                * self.scenario.pacing + self.scenario.straggler_s,
                "losses": [res.loss for res in results]})
            r += 1

    # -- async -------------------------------------------------------------

    def _run_async(self, client: CoordinatorClient) -> None:
        tr = self.trainer
        it = 0
        while True:
            head, leaves = client.get_model(0)
            if head["done"]:
                return
            version = int(head["version"])
            base = leaves
            params = tr.leaves_to_params(leaves)
            tr.set_round_tau(it, head.get("accs", ()))
            self._maybe_drop()
            head = {}
            for ci in self.client_ids:
                # delay baseline is per client: each client's update is
                # its own async round, and pacing must not compound over
                # earlier clients' train time + injected sleeps
                t_client = time.perf_counter()
                res = tr.client_round(ci, params)
                # no barrier by design: async trades the static-server
                # invariant for wall-clock, so the push lands at once
                if res.push_plan is not None:
                    tr.ex_clients[ci].apply_push(res.push_plan)
                delay = self.scenario.round_delay(
                    time.perf_counter() - t_client)
                if delay > 0:
                    time.sleep(delay)
                measured = time.perf_counter() - t_client
                delta = [np.asarray(l) - np.asarray(b) for l, b in
                         zip(tr.params_leaves(res.params), base)]
                head = client.update(
                    {"version": version, "client_id": res.client_id,
                     "weight": res.weight, "loss": res.loss,
                     "modelled_s": res.client_time * self.scenario.pacing
                     + self.scenario.straggler_s,
                     "measured_s": measured},
                    delta)
                self.records.append({
                    "iteration": it, "client": ci, "version": version,
                    "measured_s": measured,
                    "modelled_s": res.client_time * self.scenario.pacing
                    + self.scenario.straggler_s,
                    "losses": [res.loss]})
            if head.get("done"):
                return
            it += 1


def run_in_thread(worker: FedWorker) -> threading.Thread:
    """Start ``worker.run()`` on a daemon thread (tests/benchmarks run
    several workers inside one process; each owns its own trainer, and
    they share state only through the coordinator + embed shards — the
    same isolation real processes have)."""
    t = threading.Thread(target=worker.run, name=worker.worker_id,
                         daemon=True)
    t.start()
    return t
