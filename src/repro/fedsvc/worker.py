"""FedWorker: the client-process side of the control plane.

A worker owns one or more clients of the deployment and runs their
share of every round through the refactored
:meth:`repro.core.federated.FederatedGNNTrainer.client_round` —
sampling, pull/dynamic-pull through ExchangeClient (TcpTransport
against the embed shards), local epochs, overlap push planning — and
exchanges *weights* with the coordinator over
:class:`repro.fedsvc.protocol.CoordinatorClient`.

Sync round protocol (bit-compatible with the in-process simulator)::

    get_model(r)            # blocks until round r open (+ assembly)
    fill caches (pull)      # the round's only embedding reads
    pulled(r)               # non-blocking notify
    client_round(...)       # local epochs; push planned, not applied
    wait_pulled(r)          # barrier: server static within the round
    apply push plans        # embedding writes land
    update(r, params, ...)  # coordinator FedAvgs when all K arrived

Async (FedBuff-style): no barriers — pull, train, push, submit
``delta = local − base`` tagged with the model version it trained
from, then immediately fetch the newest model and go again.

Weight wire (Strategy.weight_codec): when a weight codec is configured
the worker ships each client's update as a codec-encoded delta
(local − held model) with a per-client :class:`LeafErrorFeedback`
residual carry, and consumes get_model responses that may be version
diffs against the model view it already holds — the coordinator tracks
that view bit-identically, so diff chains never drift.

Client sampling: a get_model response may carry the ``sampled`` client
set of the current round (sync) / model version (async); the worker
trains only those of its clients.  Sync: a worker with no sampled
client skips the round entirely (no pull, no barrier, no update) and
parks on the next round's get_model.  Async: the coordinator parks an
unsampled worker *inside* get_model until a version samples it, so
unsampled workers are rate-limited rather than left spinning.

Scenario injection (:class:`WorkerScenario`): a pacing multiplier and a
fixed straggler delay stretch this worker's round both in *measured*
wall-clock (real sleeps) and in the *modelled* ledger (the same
multiplier applied to the NetworkModel-based ``client_time``), so the
two ledgers stay comparable — the TcpTransport discipline.  A dropout
probability (or a deterministic ``drop_round``) makes the worker die
mid-round (after the pull barrier, before its update), which exercises
the coordinator's deregistration path; with ``rejoin`` it comes back
after ``rejoin_delay_s`` on a fresh connection, re-hellos with the same
ids, and catches up from the current model.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import FederatedGNNTrainer
from repro.exchange.codec import decode_leaves, encode_leaves
from repro.exchange.delta import LeafErrorFeedback
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

_BARRIER_S = REGISTRY.histogram("worker.barrier_s")
_ROUND_S = REGISTRY.histogram("worker.round_s")
_ROUNDS = REGISTRY.counter("worker.rounds")

from repro.dyngraph import wire as dyn_wire

from .aggregation import leaf_add, leaf_sub
from .protocol import CoordinatorClient
from .runtime import RunConfig


@dataclasses.dataclass
class WorkerScenario:
    """Injected heterogeneity for one worker."""
    pacing: float = 1.0         # >1: this worker is uniformly slower
    straggler_s: float = 0.0    # fixed extra seconds per round
    pull_delay_s: float = 0.0   # extra seconds in the pull phase (sync:
                                # lands before `pulled`, so it is what
                                # everyone else's wait_pulled barrier sees)
    dropout_prob: float = 0.0   # per-round chance of dying mid-round
    seed: int = 0
    # deterministic churn: die exactly once, mid-round `drop_round`
    # (sync) / mid-iteration `drop_round` (async); with rejoin=True the
    # worker reconnects after rejoin_delay_s instead of staying dead
    drop_round: Optional[int] = None
    rejoin: bool = False
    rejoin_delay_s: float = 0.5

    def round_delay(self, measured_train_s: float) -> float:
        return max(0.0, (self.pacing - 1.0) * measured_train_s) \
            + self.straggler_s


class WorkerDropout(Exception):
    """Raised internally when the scenario kills the worker mid-round."""


class FedWorker:
    def __init__(self, cfg: RunConfig, client_ids: list[int],
                 coordinator_addr, *, worker_id: str | None = None,
                 scenario: WorkerScenario | None = None,
                 trainer: FederatedGNNTrainer | None = None):
        self.cfg = cfg
        self.client_ids = sorted(int(c) for c in client_ids)
        self.addr = coordinator_addr
        self.worker_id = worker_id or \
            "worker-" + "-".join(str(c) for c in self.client_ids)
        self.scenario = scenario or WorkerScenario()
        self._rng = np.random.default_rng(self.scenario.seed)
        # shard-local trainer: samplers / caches / exchange registrations
        # only for the owned clients, and on a store: graph the worker
        # mmaps just its own prebuilt shards (shared `trainer` instances
        # — the in-thread deployments — keep their full build)
        self.trainer = trainer if trainer is not None \
            else cfg.build_trainer(only_clients=self.client_ids)
        st = self.trainer.strategy
        self.weight_codec: str | None = st.weight_codec
        self._wef: dict[int, LeafErrorFeedback] = {
            ci: LeafErrorFeedback() for ci in self.client_ids
        } if (self.weight_codec is not None
              and st.weight_error_feedback) else {}
        self._view: list[np.ndarray] | None = None  # model we hold
        self._view_serial = -1
        self.records: list[dict] = []     # one per completed local round
        self.dropped = False              # scenario killed this worker
        self.disconnected = False         # coordinator went away mid-run
        self.rejoins = 0                  # completed re-join cycles
        self._drop_fired = False          # drop_round fires exactly once

    # -- lifecycle ---------------------------------------------------------

    def run(self) -> list[dict]:
        """Train until the coordinator reports done (or the scenario
        kills this worker).  Returns the per-round records."""
        tr = self.trainer
        # §3.2.1 pretrain: seed the embed shards with this worker's
        # rows *before* registering — the coordinator's assembly gate
        # guarantees nobody pulls until every worker got here.
        tr.pretrain_round(self.client_ids)
        first = True
        while True:
            try:
                client = CoordinatorClient(self.addr)
            except (ConnectionError, OSError):
                if first:
                    raise              # a dead address is a setup error
                self.disconnected = True   # coordinator gone mid-rejoin
                return self.records
            first = False
            try:
                hello = client.hello(self.worker_id, self.client_ids,
                                     init_leaves=tr.params_leaves())
                if hello["mode"] == "sync":
                    self._run_sync(client, start_round=int(hello["round"]))
                else:
                    self._run_async(client)
                return self.records
            except WorkerDropout:
                self.dropped = True
                if not self.scenario.rejoin:
                    return self.records
            except (ConnectionError, OSError):
                # the coordinator stopped (timeout, lingered out, or
                # died) mid-RPC: end gracefully, keeping the records
                self.disconnected = True
                return self.records
            finally:
                client.close()
            # re-join: fresh connection, same ids.  The held model view
            # and EF residuals describe a conversation that died with
            # the old connection — drop them and catch up from the
            # coordinator's current full model.
            self._view, self._view_serial = None, -1
            for ef in self._wef.values():
                ef.reset()
            time.sleep(self.scenario.rejoin_delay_s)
            self.dropped = False
            self.rejoins += 1

    def _maybe_drop(self, round_idx: int) -> None:
        sc = self.scenario
        if sc.drop_round is not None and not self._drop_fired \
                and round_idx == sc.drop_round:
            self._drop_fired = True
            raise WorkerDropout(self.worker_id)
        if sc.dropout_prob > 0 and self._rng.random() < sc.dropout_prob:
            raise WorkerDropout(self.worker_id)

    # -- weight wire -------------------------------------------------------

    def _fetch_model(self, client: CoordinatorClient, want_round: int
                     ) -> tuple[dict, list[np.ndarray]]:
        """get_model + view upkeep: apply a version diff to the held
        view, or adopt a full model; either way the result is the exact
        leaves the coordinator records as this worker's served view."""
        head, tensors = client.get_model(want_round,
                                         have_version=self._view_serial)
        if head.get("kind") == "delta":
            leaves = leaf_add(self._view,
                              decode_leaves(head["codec"], tensors,
                                            head["shapes"]))
        else:
            leaves = tensors
        self._view = leaves
        self._view_serial = int(head.get("serial", -1))
        return head, leaves

    def _update_payload(self, ci: int, params_leaves: list[np.ndarray]
                        ) -> tuple[dict, list]:
        """One client's update for the wire: raw full leaves (legacy),
        or a codec-encoded delta vs the held view with EF carry."""
        if self.weight_codec is None:
            return {}, params_leaves
        delta = leaf_sub(params_leaves, self._view)
        ef = self._wef.get(ci)
        comp = ef.compensate(delta) if ef is not None else delta
        tensors, shapes = encode_leaves(self.weight_codec, comp)
        if ef is not None:
            ef.commit(comp, decode_leaves(self.weight_codec, tensors,
                                          shapes))
        return {"kind": "delta", "codec": self.weight_codec,
                "shapes": shapes}, tensors

    # -- sync --------------------------------------------------------------

    def _run_sync(self, client: CoordinatorClient, start_round: int) -> None:
        tr = self.trainer
        r = start_round
        while True:
            with TRACE.span("worker.get_model", args={"round": r}):
                head, leaves = self._fetch_model(client, r)
            if head["done"]:
                return
            r = int(head["round"])
            TRACE.set_context(round=r, worker=self.worker_id)
            # dynamic graphs: apply this round's growth epoch BEFORE the
            # sampled-skip — every worker must check into the growth
            # barrier (an unsampled worker skipping it would wedge the
            # sampled workers waiting on its boundary registrations)
            ge = int(head.get("growth_epoch", 0))
            if ge > 0 and tr.growth is not None:
                with TRACE.span("worker.growth", args={"epoch": ge}):
                    tr.apply_growth(ge, r)
                    dyn_wire.growth_rpc(
                        client.sock,
                        {"worker_id": self.worker_id, "round": r,
                         "epoch": ge,
                         "num_vertices": int(tr.g.num_vertices),
                         "num_edges": int(tr.g.num_edges)})
            sampled = head.get("sampled")
            mine = self.client_ids if sampled is None else \
                [c for c in self.client_ids if c in sampled]
            if not mine:
                # none of our clients drawn this round: skip straight
                # to the next round's get_model (which blocks until the
                # sampled subset finishes aggregating)
                r += 1
                continue
            t_start = time.perf_counter()
            params = tr.leaves_to_params(leaves)
            tr.set_round_tau(r, head.get("accs", ()))
            with TRACE.span("worker.pull", args={"clients": mine}):
                for ci in mine:
                    tr._fill_cache(ci)
                if self.scenario.pull_delay_s > 0:
                    time.sleep(self.scenario.pull_delay_s)
                client.pulled(r, mine)
            # dropout lands after the pull barrier contribution and
            # before any update — the nastiest spot for the coordinator
            self._maybe_drop(r)
            with TRACE.span("worker.train", args={"clients": mine}):
                results = [tr.client_round(ci, params, fill_cache=False)
                           for ci in mine]
            t_train = time.perf_counter() - t_start
            delay = self.scenario.round_delay(t_train)
            if delay > 0:
                time.sleep(delay)
            # the barrier wait is coordination stall, not this worker's
            # work: measured_s must not charge the slowest straggler's
            # round to every client (round_measured_s = max over
            # clients would then exceed any single worker's own work)
            t_barrier = time.perf_counter()
            with TRACE.span("worker.barrier"):
                client.wait_pulled(r)
            barrier_s = time.perf_counter() - t_barrier
            _BARRIER_S.observe(barrier_s)
            with TRACE.span("worker.push"):
                for res in results:
                    if res.push_plan is not None:
                        tr.ex_clients[res.client_id].apply_push(
                            res.push_plan)
            measured = time.perf_counter() - t_start - barrier_s
            _ROUNDS.inc()
            _ROUND_S.observe(measured)
            with TRACE.span("worker.update"):
                for res in results:
                    extra, payload = self._update_payload(
                        res.client_id, tr.params_leaves(res.params))
                    client.update(
                        {"round": r, "client_id": res.client_id,
                         "weight": res.weight, "loss": res.loss,
                         "modelled_s": res.client_time
                         * self.scenario.pacing
                         + self.scenario.straggler_s
                         + self.scenario.pull_delay_s,
                         "measured_s": measured, "barrier_s": barrier_s,
                         **extra},
                        payload)
            self.records.append({
                "round": r, "clients": mine,
                "measured_s": measured, "barrier_s": barrier_s,
                "modelled_s": max(res.client_time for res in results)
                * self.scenario.pacing + self.scenario.straggler_s
                + self.scenario.pull_delay_s,
                "losses": [res.loss for res in results]})
            r += 1

    # -- async -------------------------------------------------------------

    def _run_async(self, client: CoordinatorClient) -> None:
        tr = self.trainer
        it = 0
        while True:
            head, leaves = self._fetch_model(client, 0)
            if head["done"]:
                return
            version = int(head["version"])
            sampled = head.get("sampled")
            mine = self.client_ids if sampled is None else \
                [c for c in self.client_ids if c in sampled]
            if not mine:
                # the coordinator parks unsampled workers in get_model,
                # so this only happens when the version moved between
                # its wakeup and our read: refetch for the new version
                it += 1
                continue
            base = leaves
            params = tr.leaves_to_params(leaves)
            tr.set_round_tau(it, head.get("accs", ()))
            self._maybe_drop(it)
            head = {}
            for ci in mine:
                # delay baseline is per client: each client's update is
                # its own async round, and pacing must not compound over
                # earlier clients' train time + injected sleeps
                t_client = time.perf_counter()
                TRACE.set_context(round=it, worker=self.worker_id)
                with TRACE.span("worker.train",
                                args={"client": ci, "version": version}):
                    res = tr.client_round(ci, params)
                # no barrier by design: async trades the static-server
                # invariant for wall-clock, so the push lands at once
                with TRACE.span("worker.push"):
                    if res.push_plan is not None:
                        tr.ex_clients[ci].apply_push(res.push_plan)
                delay = self.scenario.round_delay(
                    time.perf_counter() - t_client)
                if delay > 0:
                    time.sleep(delay)
                measured = time.perf_counter() - t_client
                _ROUNDS.inc()
                _ROUND_S.observe(measured)
                if self.weight_codec is None:
                    extra, payload = {}, leaf_sub(
                        tr.params_leaves(res.params), base)
                else:
                    # _update_payload's delta base is the held view,
                    # which IS this iteration's base model
                    extra, payload = self._update_payload(
                        ci, tr.params_leaves(res.params))
                head = client.update(
                    {"version": version, "client_id": res.client_id,
                     "weight": res.weight, "loss": res.loss,
                     "modelled_s": res.client_time * self.scenario.pacing
                     + self.scenario.straggler_s,
                     "measured_s": measured, **extra},
                    payload)
                self.records.append({
                    "iteration": it, "client": ci, "version": version,
                    "measured_s": measured,
                    "modelled_s": res.client_time * self.scenario.pacing
                    + self.scenario.straggler_s,
                    "losses": [res.loss]})
            if head.get("done"):
                return
            it += 1


def run_in_thread(worker: FedWorker) -> threading.Thread:
    """Start ``worker.run()`` on a daemon thread (tests/benchmarks run
    several workers inside one process; each owns its own trainer, and
    they share state only through the coordinator + embed shards — the
    same isolation real processes have)."""
    t = threading.Thread(target=worker.run, name=worker.worker_id,
                         daemon=True)
    t.start()
    return t
