"""Threaded TCP weight-aggregation coordinator.

The control-plane counterpart of ``repro.launch.embed_server``: one
accept loop, one thread per worker connection, a lock + condition
variable over the shared round state.  Blocking RPCs (``get_model``,
``wait_pulled``) park their connection thread on the condition until
the round advances — workers never poll.

Aggregation policies (Strategy.aggregation):

  sync  — barriered FedAvg.  A round aggregates when every *sampled,
          active* client's update arrived, in ascending client-id order
          through
          :func:`repro.fedsvc.aggregation.fedavg_leaves` — the exact
          function the in-process trainer uses, so a multi-process sync
          round reproduces ``FederatedGNNTrainer.run_round`` numerics.
  async — FedBuff-style buffered aggregation.  Updates carry deltas
          (local − base model); every ``buffer_size`` arrivals the
          model moves by the staleness-discounted weighted mean of the
          buffered deltas (``staleness_decay ** staleness``) and the
          version bumps.  No barriers: fast workers never wait for
          stragglers, which is the whole point.

Dropout and churn: a worker whose connection dies mid-round is
deregistered; the pull barrier and the aggregation trigger re-evaluate
against the surviving client set, its not-yet-aggregated updates are
dropped (an orphaned update must never fold into FedAvg), and a sync
round only ever aggregates over ``sampled ∩ active ∩ updates``.  A
re-``hello`` with the same worker id / client ids on a fresh connection
is a *re-join*: the worker catches up from the current model and its
clients count again.

Client sampling (Strategy.sample_frac): the coordinator draws
ceil(frac·K) clients deterministically from ``sample_seed`` and the
round index (sync) / model version (async).  Sync: only the sampled
subset pulls, barriers, and aggregates.  Async: get_model *parks* a
worker none of whose clients are sampled at the current version until
a version where one is (rate-limiting, not just filtering), and an
update from a client that was not sampled at the version it trained
from is refused (``accepted: False``) — it neither buffers nor charges
the weight ledger.  A version whose entire sample died is redrawn from
the survivors on disconnect, so sampling can never wedge the buffer.

Weight-wire compression (Strategy.weight_codec): get_model responses
are codec-encoded version diffs against a per-worker *served view* (the
exact leaves the worker holds, tracked bit-identically on both ends),
and updates arrive as codec-encoded deltas the coordinator reconstructs
against the same view.  Wire bytes both directions are recorded per
aggregation next to a codec-aware modelled transfer time.

Dual ledgers, same discipline as TcpTransport: every aggregation
records the *modelled* round time (max over client-reported modelled
times + modelled model exchange + measured agg/eval compute) next to
the *measured* wall clock since serving began.
"""

from __future__ import annotations

import math
import socket
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.cost_model import NetworkModel
from repro.dyngraph import wire as dyn_wire
from repro.exchange import wire
from repro.exchange.codec import decode_leaves, encode_leaves
from repro.obsv import teleserve
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

from . import protocol
from .aggregation import (apply_buffered_deltas, fedavg_leaves, leaf_add,
                          staleness_scale)

_AGGS = REGISTRY.counter("coord.aggregations")
_AGG_S = REGISTRY.histogram("coord.agg_s")
_BARRIER_S = REGISTRY.histogram("coord.barrier_wait_s")
_WEIGHT_BYTES = REGISTRY.counter("coord.weight_bytes")


class CoordinatorState:
    """Shared state of one coordinator service."""

    def __init__(self, *, num_clients: int, num_rounds: int,
                 mode: str = "sync", buffer_size: int = 2,
                 staleness_decay: float = 0.5,
                 weight_codec: Optional[str] = None,
                 sample_frac: Optional[float] = None,
                 sample_seed: int = 0,
                 init_leaves: Optional[Sequence[np.ndarray]] = None,
                 eval_fn: Optional[Callable[[list[np.ndarray]], float]] = None,
                 net: NetworkModel | None = None,
                 growth=None):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown aggregation mode {mode!r}")
        if sample_frac is not None and not 0.0 < sample_frac <= 1.0:
            raise ValueError(f"sample_frac {sample_frac!r} not in (0, 1]")
        if growth is not None and mode != "sync":
            # growth epochs are keyed to the sync round index; async
            # versions have no shared round boundary to apply deltas at
            raise ValueError("dynamic-graph growth requires sync "
                             "aggregation")
        self.num_clients = num_clients
        self.num_rounds = num_rounds          # sync: rounds; async: aggs
        self.mode = mode
        self.buffer_size = max(1, buffer_size)
        self.staleness_decay = staleness_decay
        self.weight_codec = weight_codec
        self.sample_frac = sample_frac
        self.sample_seed = sample_seed
        self.eval_fn = eval_fn
        self.net = net or NetworkModel()
        # growth schedule (anything with epoch_for_round) or None;
        # immutable — read without the lock
        self.growth = growth

        self.cond = threading.Condition()
        self.stop = threading.Event()
        # every mutable field below is shared across connection threads
        self.leaves: Optional[list[np.ndarray]] = (     # guarded-by: self.cond
            None if init_leaves is None
            else [np.asarray(l) for l in init_leaves])
        self.round = 0                # sync round index; guarded-by: self.cond
        self.version = 0              # async agg count; guarded-by: self.cond
        self.serial = 0               # bumps per agg; guarded-by: self.cond
        self.workers: dict[str, set[int]] = {}    # worker -> clients; guarded-by: self.cond
        self._conn_worker: dict[int, str] = {}    # conn id -> worker; guarded-by: self.cond
        self._worker_conn: dict[str, int] = {}    # worker -> live conn; guarded-by: self.cond
        self.pulled: set[int] = set()             # this round; guarded-by: self.cond
        self.updates: dict[int, dict] = {}        # cid -> record; guarded-by: self.cond
        self.buffer: list[dict] = []              # async pending; guarded-by: self.cond
        self.history: list[dict] = []             # per aggregation; guarded-by: self.cond
        self.acc_history: list[float] = []        # guarded-by: self.cond
        self.cum_modelled_s = 0.0                 # guarded-by: self.cond
        self._t0: Optional[float] = None  # first model served; guarded-by: self.cond
        self._assembled = False   # all K registered; guarded-by: self.cond
        self._aggregating = False  # async drain in flight; guarded-by: self.cond
        # weight codec: per-worker (serial, leaves) of the view that
        # worker holds — version diffs are computed/reconstructed
        # against it, and it tracks the worker's copy bit-identically
        self._served: dict[str, tuple[int, list[np.ndarray]]] = {}  # guarded-by: self.cond
        self._samples: dict[int, set[int]] = {}         # guarded-by: self.cond
        # dynamic graphs: highest growth epoch each worker reported
        # applied — the growth barrier predicate reads it
        self.grown: dict[str, int] = {}                 # guarded-by: self.cond
        # weight-plane wire ledger (payload bytes of get_model responses
        # and update requests), per aggregation and cumulative
        self.weight_bytes_cum = 0                       # guarded-by: self.cond
        self._dl_bytes = self._ul_bytes = 0             # guarded-by: self.cond
        self._dl_max = self._ul_max = 0                 # guarded-by: self.cond

    # -- helpers (call with self.cond held) --------------------------------

    @property
    def active_clients(self) -> set[int]:  # guarded-by: self.cond
        out: set[int] = set()
        for cids in self.workers.values():
            out |= cids
        return out

    @property
    def assembled(self) -> bool:  # guarded-by: self.cond
        """Latches True once every client id registered.  get_model
        gates on this so no worker starts round 0 before all workers
        finished their pretrain pushes (a later dropout must not
        un-assemble an already-running deployment)."""
        if not self._assembled \
                and len(self.active_clients) == self.num_clients:
            self._assembled = True
        return self._assembled

    @property
    def done(self) -> bool:  # guarded-by: self.cond
        count = self.round if self.mode == "sync" else self.version
        return count >= self.num_rounds

    def _num_params(self) -> int:  # guarded-by: self.cond
        return sum(int(np.prod(l.shape)) for l in self.leaves or [])

    def _wall(self) -> float:  # guarded-by: self.cond
        return 0.0 if self._t0 is None else time.perf_counter() - self._t0

    def _wait(self, predicate) -> None:  # guarded-by: self.cond
        while not predicate() and not self.stop.is_set():
            self.cond.wait(timeout=0.2)
        if self.stop.is_set() and not predicate():
            raise ConnectionError("coordinator stopping")

    def _sampled(self, idx: int) -> set[int]:  # guarded-by: self.cond
        """The client set aggregation step ``idx`` runs over — the round
        index in sync mode, the model version in async (call with cond
        held).  Drawn lazily from the clients active at draw time —
        deterministic in (sample_seed, idx) — and cached so barrier,
        aggregation, and every worker's get_model agree."""
        if self.sample_frac is None:
            return self.active_clients
        sel = self._samples.get(idx)
        if sel is None:
            pool = sorted(self.active_clients)
            if not pool:
                return set()               # nobody yet: don't cache
            # ceil(frac·K) as documented; the epsilon keeps float noise
            # (0.2 * 5 == 1.0000000000000002) from bumping a whole client
            k = max(1, math.ceil(self.sample_frac * self.num_clients
                                 - 1e-9))
            rng = np.random.default_rng((self.sample_seed, idx))
            sel = set(int(c) for c in
                      rng.choice(pool, size=min(k, len(pool)),
                                 replace=False))
            self._samples[idx] = sel
        return sel

    # -- weight-plane wire ledger ------------------------------------------

    def _charge_wire(self, direction: str, nbytes: int) -> None:  # guarded-by: self.cond
        """Record one weight-plane message (call with cond held)."""
        if direction == "down":
            self._dl_bytes += nbytes
            self._dl_max = max(self._dl_max, nbytes)
        else:
            self._ul_bytes += nbytes
            self._ul_max = max(self._ul_max, nbytes)
        self.weight_bytes_cum += nbytes
        _WEIGHT_BYTES.inc(nbytes)

    def _weight_ledger(self) -> dict:  # guarded-by: self.cond
        """Close out this aggregation's weight-wire ledger: actual bytes
        both directions plus the codec-aware modelled exchange time (the
        critical path is one largest download + one largest upload, the
        per-client exchange of the historical ``2·model_transfer_time``
        — now priced at the effective bytes/param actually framed)."""
        n = max(1, self._num_params())
        modelled = (
            self.net.model_transfer_time(n, bytes_per_scalar=self._dl_max / n)
            + self.net.model_transfer_time(n,
                                           bytes_per_scalar=self._ul_max / n))
        out = {"weight_down_bytes": self._dl_bytes,
               "weight_up_bytes": self._ul_bytes,
               "weight_bytes": self._dl_bytes + self._ul_bytes,
               "weight_modelled_s": modelled}
        self._dl_bytes = self._ul_bytes = 0
        self._dl_max = self._ul_max = 0
        return out

    # -- aggregation -------------------------------------------------------

    def _maybe_aggregate_sync(self) -> None:  # guarded-by: self.cond
        if self.done:
            return
        active = self.active_clients
        eligible = self._sampled(self.round) & active
        # aggregate over the surviving sampled set only: an update whose
        # worker deregistered mid-round is an orphan and must not fold
        # into FedAvg (the old `active <= updates` check let it through)
        if not eligible or not eligible <= set(self.updates):
            return
        ups = [self.updates[cid] for cid in sorted(eligible)]
        t0 = time.perf_counter()
        with TRACE.span("coord.aggregate",
                        args={"round": self.round, "mode": "sync",
                              "clients": len(ups)}):
            self.leaves = fedavg_leaves([u["leaves"] for u in ups],
                                        [u["weight"] for u in ups])
            acc = self.eval_fn(self.leaves) if self.eval_fn \
                else float("nan")
        ledger = self._weight_ledger()
        _AGGS.inc()
        _AGG_S.observe(time.perf_counter() - t0)
        agg_s = time.perf_counter() - t0 + ledger["weight_modelled_s"]
        round_modelled = max(u["modelled_s"] for u in ups) + agg_s
        self.cum_modelled_s += round_modelled
        self.acc_history.append(acc)
        self.history.append({
            "round": self.round, "mode": "sync", "accuracy": acc,
            "clients": sorted(eligible),
            "mean_loss": float(np.mean([u["loss"] for u in ups])),
            "round_modelled_s": round_modelled,
            "cum_modelled_s": self.cum_modelled_s,
            "round_measured_s": max(u["measured_s"] for u in ups) + agg_s,
            "max_barrier_s": max(u.get("barrier_s", 0.0) for u in ups),
            "wall_s": self._wall(),
            **ledger,
        })
        self.round += 1
        self.serial += 1
        self.pulled.clear()
        self.updates.clear()
        self.cond.notify_all()

    def _maybe_aggregate_async(self) -> None:  # guarded-by: self.cond
        """Drain the buffer under the lock, but fold + evaluate OUTSIDE
        it — the whole point of async mode is that workers never wait,
        and a full-graph eval under the coordinator's one condition
        lock would stall every concurrent RPC.  ``_aggregating`` keeps
        drains strictly sequential (the model moves one buffer at a
        time); updates arriving during a drain just queue for the next
        one, which the loop picks up after publishing."""
        while not self.done and not self._aggregating \
                and len(self.buffer) >= self.buffer_size:
            ups, self.buffer = self.buffer, []
            version = self.version
            base = self.leaves                # replaced, never mutated
            self._aggregating = True
            self.cond.release()
            try:
                t0 = time.perf_counter()
                with TRACE.span("coord.aggregate",
                                args={"version": version, "mode": "async",
                                      "buffered": len(ups)}):
                    scaled = [(u["weight"],
                               staleness_scale(version - u["version"],
                                               self.staleness_decay),
                               u["leaves"]) for u in ups]
                    leaves = apply_buffered_deltas(base, scaled)
                    acc = self.eval_fn(leaves) if self.eval_fn \
                        else float("nan")
                compute_s = time.perf_counter() - t0
                _AGGS.inc()
                _AGG_S.observe(compute_s)
            finally:
                self.cond.acquire()
                self._aggregating = False
            ledger = self._weight_ledger()
            agg_s = compute_s + ledger["weight_modelled_s"]
            self.leaves = leaves
            # async rounds overlap across workers: the modelled ledger
            # advances by the slowest *buffered* contribution amortized
            # over the buffer — with no barrier, client rounds pipeline,
            # so the marginal cost per aggregation is one buffer drain,
            # not a max-over-everyone round.
            round_modelled = max(u["modelled_s"] for u in ups) \
                / max(1, len(ups)) + agg_s
            self.cum_modelled_s += round_modelled
            self.acc_history.append(acc)
            self.history.append({
                "round": self.version, "mode": "async", "accuracy": acc,
                "clients": sorted(u["client_id"] for u in ups),
                "staleness": [version - u["version"] for u in ups],
                "mean_loss": float(np.mean([u["loss"] for u in ups])),
                "round_modelled_s": round_modelled,
                "cum_modelled_s": self.cum_modelled_s,
                "round_measured_s": max(u["measured_s"] for u in ups)
                + agg_s,
                "wall_s": self._wall(),
                **ledger,
            })
            self.version += 1
            self.serial += 1
            self.cond.notify_all()

    # -- connection lifecycle ----------------------------------------------

    def disconnect(self, conn_id: int) -> None:
        """Connection died (worker dropout): deregister its clients and
        let any barrier / aggregation blocked on them re-evaluate.  A
        stale connection of a worker that already re-registered on a
        newer one must NOT deregister the live worker."""
        with self.cond:
            worker = self._conn_worker.pop(conn_id, None)
            if worker is None or self._worker_conn.get(worker) != conn_id:
                return
            self._worker_conn.pop(worker, None)
            self.workers.pop(worker, None)
            self._served.pop(worker, None)    # re-join gets a full model
            self.grown.pop(worker, None)      # re-join re-reports its epoch
            if self.mode == "sync":
                # orphaned updates: a deregistered client's pending
                # update must not survive into any aggregation — if all
                # workers die, stale updates would otherwise wedge the
                # round (or worse, aggregate the moment one re-joins)
                active = self.active_clients
                for cid in [c for c in self.updates if c not in active]:
                    del self.updates[cid]
                # a sampled round whose entire sample died can never
                # complete: skip ahead so survivors re-draw next round
                while (not self.done and self.sample_frac is not None
                       and self.active_clients
                       and not (self._sampled(self.round)
                                & self.active_clients)):
                    self.round += 1
                    self.pulled.clear()
                    self.updates.clear()
                self._maybe_aggregate_sync()
            else:
                # async: a version whose entire sample died would park
                # every survivor in get_model forever — redraw it from
                # the clients still standing
                if (not self.done and self.sample_frac is not None
                        and self.active_clients
                        and not (self._sampled(self.version)
                                 & self.active_clients)):
                    self._samples.pop(self.version, None)
                    self._sampled(self.version)
            self.cond.notify_all()

    # -- request dispatch --------------------------------------------------

    def handle(self, conn_id: int, body: bytes) -> bytes:
        """One request body → one response body (never raises; blocking
        ops wait on the condition inside)."""
        # shared telemetry opcodes first: their bodies don't follow the
        # fedsvc `op | header_len | JSON` layout, so they must not reach
        # protocol.parse_body
        telemetry = teleserve.handle_telemetry(body)
        if telemetry is not None:
            return telemetry
        # dynamic-graph band (48..63): dyngraph wire layout, exchange
        # status replies — must not reach protocol.parse_body either
        if body and dyn_wire.GROWTH_LO <= body[0] <= dyn_wire.GROWTH_HI:
            try:
                return self._op_growth(body)
            except ConnectionError:
                raise                  # let the conn loop tear down
            except Exception as e:
                return wire.build_err(f"{type(e).__name__}: {e}")
        try:
            op, header, tensors = protocol.parse_body(body)
        except Exception as e:
            return protocol.build_err(f"bad request: {type(e).__name__}: {e}")
        try:
            if op == protocol.OP_HELLO:
                return self._op_hello(conn_id, header, tensors)
            if op == protocol.OP_GET_MODEL:
                return self._op_get_model(conn_id, header)
            if op == protocol.OP_PULLED:
                return self._op_pulled(header)
            if op == protocol.OP_WAIT_PULLED:
                return self._op_wait_pulled(header)
            if op == protocol.OP_UPDATE:
                return self._op_update(conn_id, header, tensors)
            if op == protocol.OP_COORD_STATS:
                return self._op_stats()
            if op == protocol.OP_COORD_SHUTDOWN:
                self.stop.set()
                with self.cond:
                    self.cond.notify_all()
                return protocol.build_ok()
            return protocol.build_err(f"unknown opcode {op}")
        except ConnectionError:
            raise                      # let the conn loop tear down
        except Exception as e:
            return protocol.build_err(f"{type(e).__name__}: {e}")

    def _op_hello(self, conn_id: int, header: dict, tensors) -> bytes:
        worker = str(header["worker_id"])
        cids = set(int(c) for c in header["client_ids"])
        bad = [c for c in cids if not 0 <= c < self.num_clients]
        if bad:
            return protocol.build_err(
                f"client ids {sorted(bad)} out of range for "
                f"num_clients={self.num_clients}")
        if header.get("has_init") and not tensors:
            # an empty init would seed a zero-parameter model and the
            # coordinator would happily serve it; refuse loudly instead
            return protocol.build_err(
                "has_init with no model leaves: empty init rejected")
        with self.cond:
            taken = set()
            for w, o in self.workers.items():
                if w != worker:
                    taken |= o & cids
            if taken:
                return protocol.build_err(
                    f"client ids {sorted(taken)} already registered "
                    "to another worker")
            resumed = worker in self.workers
            self.workers[worker] = cids
            self._conn_worker[conn_id] = worker
            self._worker_conn[worker] = conn_id
            # fresh registration or re-join: whatever view we tracked
            # for this worker id is gone with the old process/connection
            self._served.pop(worker, None)
            if header.get("has_init") and self.leaves is None:
                self.leaves = [np.asarray(t) for t in tensors]
            if self._t0 is None:
                self._t0 = time.perf_counter()
            self.cond.notify_all()
            return protocol.build_ok({
                "round": self.round, "version": self.version,
                "mode": self.mode, "num_clients": self.num_clients,
                "num_rounds": self.num_rounds, "resumed": resumed})

    def _op_get_model(self, conn_id: int, header: dict) -> bytes:
        want = int(header.get("round", 0))
        have = int(header.get("have_version", -1))
        with self.cond:
            if self.mode == "sync":
                self._wait(lambda: self.assembled
                           and (self.round >= want or self.done))
            else:
                # async + sampling: an unsampled worker parks here until
                # a version samples one of its clients — that is what
                # rate-limits it (merely filtering in the worker would
                # let it spin on get_model at full speed)
                def _async_ready() -> bool:
                    if not (self.assembled and self.leaves is not None):
                        return False
                    if self.done or self.sample_frac is None:
                        return True
                    cids = self.workers.get(
                        self._conn_worker.get(conn_id), set())
                    return not cids or \
                        bool(cids & self._sampled(self.version))
                self._wait(_async_ready)
            if self.leaves is None:
                return protocol.build_err("no model: no worker sent init "
                                          "leaves yet")
            # raw path: snapshot refs only — aggregation *replaces*
            # self.leaves, never mutates it, so the (large) tensor
            # serialization runs outside the coordinator's one condition
            # lock.  The codec path below instead encodes under the
            # lock: the per-worker served view must advance atomically
            # with the diff, and at GNN model sizes (tens of kB) the
            # encode is microseconds — revisit with per-worker locks if
            # models grow orders of magnitude.
            leaves = self.leaves
            head = {"round": self.round, "version": self.version,
                    "serial": self.serial, "done": self.done,
                    "accs": list(self.acc_history)}
            if self.growth is not None:
                # every worker of this round sees the same epoch, so
                # they all check into the growth barrier (or all skip)
                head["growth_epoch"] = int(
                    self.growth.epoch_for_round(self.round))
            if self.sample_frac is not None and not self.done:
                head["sampled"] = sorted(self._sampled(
                    self.round if self.mode == "sync" else self.version))
            worker = self._conn_worker.get(conn_id)
            served = self._served.get(worker) if worker else None
            if self.weight_codec is not None and worker is not None:
                if served is not None and served[0] == have:
                    # version diff against the exact view this worker
                    # holds; the new view is base + decode(diff) on
                    # BOTH ends (leaf_add), so they stay bit-identical
                    # and codec error self-corrects next diff
                    diff = [np.asarray(c, np.float32) - b
                            for c, b in zip(leaves, served[1])]
                    payload, shapes = encode_leaves(self.weight_codec, diff)
                    view = leaf_add(served[1],
                                    decode_leaves(self.weight_codec,
                                                  payload, shapes))
                    head.update(kind="delta", codec=self.weight_codec,
                                shapes=shapes)
                else:
                    # first fetch or re-join: full raw model, which
                    # becomes the worker's view as-is
                    payload, view = leaves, leaves
                    head["kind"] = "full"
                self._served[worker] = (self.serial, view)
            else:
                payload = leaves
                head["kind"] = "full"
            self._charge_wire("down", wire.tensors_nbytes(payload))
        return protocol.build_ok(head, payload)

    def _op_growth(self, body: bytes) -> bytes:
        """Growth barrier: a worker reports the growth epoch it just
        applied locally; the reply is withheld until every registered
        worker has applied that epoch, so no worker pulls embeddings
        across a half-grown deployment (a boundary row registered by
        one worker must exist before a neighbour's pull).  A dropped
        worker leaves ``self.workers`` in :meth:`disconnect`, which
        notifies the condition and lets the barrier re-evaluate."""
        _, header = dyn_wire.parse_growth_request(body)
        worker = str(header["worker_id"])
        epoch = int(header["epoch"])
        with self.cond, TRACE.span(
                "coord.growth",
                args={"round": int(header.get("round", -1)),
                      "epoch": epoch}):
            self.grown[worker] = max(epoch, self.grown.get(worker, 0))
            self.cond.notify_all()
            self._wait(lambda: all(self.grown.get(w, 0) >= epoch
                                   for w in self.workers))
        return wire.build_ok()

    def _op_pulled(self, header: dict) -> bytes:
        rnd = int(header["round"])
        with self.cond:
            if rnd == self.round:
                self.pulled |= set(int(c) for c in header["client_ids"])
                self.cond.notify_all()
            return protocol.build_ok()

    def _op_wait_pulled(self, header: dict) -> bytes:
        rnd = int(header["round"])
        t0 = time.perf_counter()
        with self.cond, TRACE.span("coord.barrier", args={"round": rnd}):
            # barrier: every *surviving sampled* client pulled, or the
            # round already moved on (a late waiter must not deadlock)
            self._wait(lambda: self.round != rnd
                       or (self._sampled(rnd)
                           & self.active_clients) <= self.pulled)
            _BARRIER_S.observe(time.perf_counter() - t0)
            return protocol.build_ok()

    def _op_update(self, conn_id: int, header: dict, tensors) -> bytes:
        tensors = [np.asarray(t) for t in tensors]
        rec = {
            "client_id": int(header["client_id"]),
            "weight": float(header["weight"]),
            "loss": float(header.get("loss", float("nan"))),
            "modelled_s": float(header.get("modelled_s", 0.0)),
            "measured_s": float(header.get("measured_s", 0.0)),
            "barrier_s": float(header.get("barrier_s", 0.0)),
        }
        codec = header.get("codec") if header.get("kind") == "delta" \
            else None
        with self.cond:
            if codec is not None:
                delta = decode_leaves(codec, tensors, header["shapes"])
            if self.mode == "sync":
                rnd = int(header["round"])
                if rnd != self.round:
                    return protocol.build_err(
                        f"update for round {rnd} but coordinator is at "
                        f"round {self.round}")
                if codec is not None:
                    # codec-encoded delta vs the worker's served view:
                    # reconstruct the full local params for FedAvg
                    worker = self._conn_worker.get(conn_id)
                    served = self._served.get(worker) if worker else None
                    if served is None:
                        return protocol.build_err(
                            "delta update without a served model view "
                            "(get_model must precede update)")
                    rec["leaves"] = leaf_add(served[1], delta)
                else:
                    rec["leaves"] = tensors
                # charge only accepted updates: a refused or ignored
                # payload must not inflate the round's weight ledger
                # (the bytes the int8-vs-raw comparison is made of)
                self._charge_wire("up", wire.tensors_nbytes(tensors))
                self.updates[rec["client_id"]] = rec
                self._maybe_aggregate_sync()
            else:
                version = int(header["version"])
                if self.sample_frac is not None and \
                        rec["client_id"] not in self._sampled(version):
                    # not sampled at the version it trained from: the
                    # update neither buffers nor charges the wire ledger
                    # (it should not have been computed — the get_model
                    # park exists so this only happens on races)
                    return protocol.build_ok(
                        {"round": self.round, "version": self.version,
                         "done": self.done, "accepted": False})
                # async updates are deltas by construction; a codec just
                # changes the wire form, so the decode is all it takes
                rec["leaves"] = delta if codec is not None else tensors
                rec["version"] = version
                self._charge_wire("up", wire.tensors_nbytes(tensors))
                self.buffer.append(rec)
                self._maybe_aggregate_async()
            return protocol.build_ok({"round": self.round,
                                      "version": self.version,
                                      "done": self.done,
                                      "accepted": True})

    def _op_stats(self) -> bytes:
        with self.cond:
            return protocol.build_ok({
                "mode": self.mode, "round": self.round,
                "version": self.version, "serial": self.serial,
                "done": self.done,
                "weight_codec": self.weight_codec,
                "sample_frac": self.sample_frac,
                "weight_bytes_cum": self.weight_bytes_cum,
                "workers": {w: sorted(c) for w, c in self.workers.items()},
                "accs": list(self.acc_history),
                "cum_modelled_s": self.cum_modelled_s,
                "wall_s": self._wall(),
                "history": [{k: v for k, v in h.items()}
                            for h in self.history],
            })


# -- service plumbing (mirrors launch/embed_server) ---------------------------

class CoordinatorHandle:
    """A running coordinator: address for workers, ``stop()``/``join()``
    for teardown, ``state`` for in-process inspection."""

    def __init__(self, state: CoordinatorState, sock: socket.socket,
                 thread: threading.Thread):
        self.state = state
        self._sock = sock
        self._thread = thread
        self.host, self.port = sock.getsockname()[:2]

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    def join(self, timeout: float = 60.0) -> bool:
        """Wait until training is done (all rounds aggregated)."""
        deadline = time.monotonic() + timeout
        with self.state.cond:
            while not self.state.done and not self.state.stop.is_set():
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self.state.cond.wait(timeout=min(0.2, left))
        return self.state.done

    def stop(self, timeout: float = 5.0) -> None:
        self.state.stop.set()
        with self.state.cond:
            self.state.cond.notify_all()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


def _client_loop(conn: socket.socket, conn_id: int,
                 state: CoordinatorState) -> None:
    try:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not state.stop.is_set():
            body = wire.recv_frame(conn)
            if body is None:
                break
            wire.send_frame(conn, state.handle(conn_id, body))
    except (ConnectionError, OSError):
        pass
    finally:
        state.disconnect(conn_id)
        try:
            conn.close()
        except OSError:
            pass


def _accept_loop(listener: socket.socket, state: CoordinatorState) -> None:
    listener.settimeout(0.2)
    threads: list[threading.Thread] = []
    conn_id = 0
    while not state.stop.is_set():
        try:
            conn, _ = listener.accept()
        except socket.timeout:
            continue
        except OSError:
            break
        conn_id += 1
        t = threading.Thread(target=_client_loop,
                             args=(conn, conn_id, state), daemon=True)
        t.start()
        threads.append(t)
    try:
        listener.close()
    except OSError:
        pass
    for t in threads:
        t.join(0.5)


def serve_in_thread(state: CoordinatorState, *, host: str = "127.0.0.1",
                    port: int = 0) -> CoordinatorHandle:
    """Start the coordinator on a background thread (ephemeral port by
    default) and return its handle."""
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((host, port))
    listener.listen(64)
    thread = threading.Thread(target=_accept_loop, args=(listener, state),
                              daemon=True)
    thread.start()
    return CoordinatorHandle(state, listener, thread)
