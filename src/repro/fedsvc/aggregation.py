"""Weight-aggregation math, shared by trainer and coordinator.

Everything here operates on *leaves*: the flat list of numpy arrays a
params pytree flattens to (``jax.tree_util.tree_flatten`` order).  The
in-process :class:`repro.core.federated.FederatedGNNTrainer` and the TCP
:mod:`repro.fedsvc.coordinator` both call :func:`fedavg_leaves`, which
is what makes the multi-process sync path numerically interchangeable
with the single-process simulator — there is one FedAvg, not two.

Float discipline: all arithmetic stays in the leaf dtype (float32 for
every GNN param).  Weights are rounded to float32 before multiplying —
the same rounding jax's weak-typed ``python_float * f32_array`` does —
so numpy-side aggregation reproduces the historical jnp tree_map
bit-for-bit.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def fedavg_leaves(leaves_list: Sequence[Sequence[np.ndarray]],
                  weights: Sequence[float]) -> list[np.ndarray]:
    """Weighted FedAvg over per-client leaf lists.

    ``leaves_list[k][i]`` is client k's i-th leaf; ``weights[k]`` its
    aggregation weight (train-vertex count).  Clients must be passed in
    a canonical order (ascending client id) — float addition is not
    associative, and the order is part of the contract."""
    assert len(leaves_list) == len(weights) > 0
    wsum = np.float32(sum(weights))
    out = []
    for group in zip(*leaves_list):
        acc = sum(np.float32(w) * np.asarray(l)
                  for w, l in zip(weights, group))
        out.append(np.asarray(acc / wsum))
    return out


def leaf_sub(a: Sequence[np.ndarray],
             b: Sequence[np.ndarray]) -> list[np.ndarray]:
    """Leaf-wise ``a − b`` in float32 — the model delta a worker ships."""
    assert len(a) == len(b)
    return [np.asarray(x, np.float32) - np.asarray(y, np.float32)
            for x, y in zip(a, b)]


def leaf_add(base: Sequence[np.ndarray],
             delta: Sequence[np.ndarray]) -> list[np.ndarray]:
    """Leaf-wise ``base + delta`` in float32.

    Worker and coordinator both reconstruct a delta-shipped model with
    this exact function (same float ops, same order), which is what
    keeps the coordinator's per-worker served view bit-identical to the
    model the worker actually holds — the invariant the version-diff
    weight wire rests on."""
    assert len(base) == len(delta)
    return [np.asarray(b, np.float32) + np.asarray(d, np.float32)
            for b, d in zip(base, delta)]


def staleness_scale(staleness: int, decay: float) -> float:
    """FedBuff-style staleness discount: ``decay ** staleness``.

    ``staleness`` is how many aggregations the global model advanced
    between the worker pulling its base model and its update arriving;
    0 ⇒ fresh update, full weight."""
    return float(decay) ** max(0, int(staleness))


def apply_buffered_deltas(
        model_leaves: Sequence[np.ndarray],
        updates: Sequence[tuple[float, float, Sequence[np.ndarray]]],
) -> list[np.ndarray]:
    """Fold one buffer of async updates into the global model.

    ``updates`` rows are ``(weight, scale, delta_leaves)`` where
    ``delta = local_params - base_model`` computed client-side and
    ``scale`` is the staleness discount.  The model moves by the
    scaled-weighted mean of the deltas:

        model += Σ_k w_k·s_k·Δ_k / Σ_k w_k·s_k

    which reduces to sync FedAvg when every update is fresh (s=1) and
    every client participated in the buffer.  A drain whose scaled
    weights all vanish (e.g. staleness_decay=0 and only stale updates)
    moves the model by nothing — the limit behaviour, not a NaN."""
    assert updates
    ws = [np.float32(w) * np.float32(s) for w, s, _ in updates]
    wsum = np.float32(sum(float(w) for w in ws))
    if wsum == 0.0:
        return [np.asarray(b) for b in model_leaves]
    out = []
    for i, base in enumerate(model_leaves):
        step = sum(w * np.asarray(d[i]) for w, (_, _, d) in
                   zip(ws, updates))
        out.append(np.asarray(np.asarray(base) + step / wsum))
    return out
