"""Checkpointing: flat-npz pytree snapshots with a JSON manifest.

No external deps (orbax unavailable offline).  Leaves are saved as
``<idx>.npy`` entries inside one .npz; the manifest records the treedef
(via jax.tree_util serialization of key paths), dtypes and shapes, so a
restore can rebuild the exact pytree and validate compatibility.
Sharded restore: pass ``like=`` (a pytree of arrays or ShapeDtypeStructs
with shardings) and each leaf is device_put onto its target sharding.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(path: str | pathlib.Path, tree: Any, *, step: int = 0,
                extra: Optional[dict] = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    # npz has no native bfloat16 — store extended dtypes as f32 and let the
    # manifest dtype drive the restore cast.
    def _np(l):
        a = np.asarray(l)
        return a.astype(np.float32) if a.dtype.kind == "V" or \
            str(a.dtype) == "bfloat16" else a

    arrays = {f"leaf_{i}": _np(l) for i, l in enumerate(leaves)}
    np.savez(path.with_suffix(".npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "extra": extra or {},
    }
    path.with_suffix(".json").write_text(json.dumps(manifest))


def load_pytree(path: str | pathlib.Path, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs;
    leaves with .sharding are device_put accordingly)."""
    path = pathlib.Path(path)
    manifest = json.loads(path.with_suffix(".json").read_text())
    data = np.load(path.with_suffix(".npz"))
    paths, leaves, treedef = _flatten(like)
    assert paths == manifest["paths"], "checkpoint/model structure mismatch"
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert list(arr.shape) == manifest["shapes"][i]
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        jarr = jax.numpy.asarray(arr).astype(target_dtype)
        sharding = getattr(leaf, "sharding", None)
        out.append(jax.device_put(jarr, sharding) if sharding is not None
                   else jarr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


# convenience aliases
save = save_pytree
restore = load_pytree
