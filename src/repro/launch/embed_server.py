"""Threaded TCP embedding server — one listener per shard.

The live counterpart of the paper's Redis instance (§5.1): a process
that owns one :class:`~repro.core.embedding_server.EmbeddingServer`
table set and serves ``register`` / ``write`` / ``gather`` over the
length-prefixed binary protocol in :mod:`repro.exchange.wire`.  Codec
payloads (fp32 / fp16 / int8+scales) travel as the actual bytes the
analytic :class:`NetworkModel` charges for, so modelled and measured
network time can finally be calibrated against each other
(``benchmarks/bench_wire.py``).

Topology: run S listeners (one per shard) and point
:class:`repro.exchange.socket_transport.TcpTransport` at all of them —
the client hashes vertex ids across shards exactly like
``ShardedTransport``, so the stored state is bit-identical to the
in-process transports.

Concurrency: one accept loop + one thread per connection; requests on a
single connection are answered in arrival order (pipelining-safe), and
a lock serialises table access across connections.

CLI (one shard)::

    python -m repro.launch.embed_server --port 7040 \
        --num-layers 3 --hidden 32

Tests and benchmarks use :func:`serve_in_thread`, which binds an
ephemeral port and returns a stoppable handle.
"""

from __future__ import annotations

import argparse
import socket
import threading

import numpy as np

from repro.core.embedding_server import EmbeddingServer
from repro.exchange import wire
from repro.exchange.codec import get_codec
from repro.obsv import teleserve
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

_REQS = REGISTRY.counter("embed.requests")
_OP_SPAN = {wire.OP_REGISTER: "embed.register", wire.OP_WRITE: "embed.write",
            wire.OP_GATHER: "embed.gather", wire.OP_VGATHER: "embed.vgather",
            wire.OP_EMBED_STATS: "embed.stats"}


class _ServerState:
    """Shared state of one listener: the tables + their lock."""

    def __init__(self, num_layers: int, hidden: int, *,
                 device_tables: bool = False):
        self.store = EmbeddingServer(num_layers, hidden,    # guarded-by: self.lock
                                     device_tables=device_tables)
        self.lock = threading.Lock()
        self.stop = threading.Event()

    def handle(self, body: bytes) -> bytes:
        """One request body → one response body (never raises)."""
        telemetry = teleserve.handle_telemetry(body)
        if telemetry is not None:
            return telemetry
        try:
            op, req = wire.parse_request(body)
        except Exception as e:                              # malformed frame
            return wire.build_err(f"bad request: {type(e).__name__}: {e}")
        _REQS.inc()
        # bounded: every value in _OP_SPAN is a literal span name
        with TRACE.span(_OP_SPAN.get(op, "embed.op")):  # repro-lint: disable=TL001
            return self._dispatch(op, req)

    def _dispatch(self, op: int, req: dict) -> bytes:
        try:
            if op == wire.OP_REGISTER:
                with self.lock:
                    self.store.register(req["global_ids"])
                return wire.build_ok()
            if op == wire.OP_WRITE:
                return self._handle_write(req)
            if op == wire.OP_GATHER:
                return self._handle_gather(req)
            if op == wire.OP_VGATHER:
                return self._handle_vgather(req)
            if op == wire.OP_EMBED_STATS:
                with self.lock:
                    payload = wire.build_stats_payload(
                        self.store.L, self.store.hidden,
                        len(self.store._row), self.store.memory_bytes())
                return wire.build_ok(payload)
            if op == wire.OP_EMBED_SHUTDOWN:
                self.stop.set()
                return wire.build_ok()
            return wire.build_err(f"unknown opcode {op}")
        except Exception as e:
            return wire.build_err(f"{type(e).__name__}: {e}")

    def _handle_write(self, req: dict) -> bytes:
        codec, gids = req["codec"], req["global_ids"]
        with self.lock:     # geometry reads; decode work stays unlocked
            hidden, num_layers = self.store.hidden, self.store.L
            on_device = self.store.device_tables
        n = len(gids)
        if req["num_blocks"] != num_layers - 1:
            return wire.build_err(
                f"write carries {req['num_blocks']} layer blocks, server "
                f"stores {num_layers - 1}")
        cdc = get_codec(codec)
        block = wire.payload_nbytes(codec, n, hidden)
        buf, values = req["payload"], []
        if len(buf) != block * req["num_blocks"]:
            return wire.build_err(
                f"write payload is {len(buf)} B, expected "
                f"{block * req['num_blocks']} B "
                f"({req['num_blocks']}×{block})")
        fused = codec == "int8" and on_device
        for l in range(req["num_blocks"]):
            payload = wire.decode_block(codec, buf[l * block:(l + 1) * block],
                                        n, hidden)
            if fused:
                # ship the wire form straight to the fused decode+scatter
                # — the payload crosses host→device exactly once
                values.append(tuple(np.ascontiguousarray(p)
                                    for p in payload))
            else:
                values.append(np.asarray(cdc.decode(payload), np.float32))
        with self.lock:
            if fused:
                self.store.write_quantized(gids, values)
            else:
                self.store.write(gids, values)
        return wire.build_ok()

    def _handle_gather(self, req: dict) -> bytes:
        codec, gids = req["codec"], req["global_ids"]
        cdc = get_codec(codec)
        with self.lock:
            if codec == "int8" and self.store.device_tables:
                # fused gather+encode on the resident table; the
                # device→host crossing happens once, inside
                # encode_block's tobytes
                payloads = self.store.gather_quantized(gids, req["layers"])
                rows = None
            else:
                payloads = None
                rows = self.store.gather(gids, req["layers"])
        # gather returns fresh copies, so encoding runs unlocked
        if payloads is not None:
            blocks = [wire.encode_block(codec, p) for p in payloads]
        else:
            blocks = [wire.encode_block(codec, cdc.encode(r)) for r in rows]
        return wire.build_ok(b"".join(blocks))

    def _handle_vgather(self, req: dict) -> bytes:
        codec, gids = req["codec"], req["global_ids"]
        cdc = get_codec(codec)
        with self.lock:
            ver, _stale, vals = self.store.gather_if_stale(
                gids, req["have_versions"], req["layers"])
        blocks = [wire.encode_block(codec, cdc.encode(r)) for r in vals]
        return wire.build_ok(ver.tobytes() + b"".join(blocks))


class EmbedServerHandle:
    """A running listener: address for clients, ``stop()`` for teardown."""

    def __init__(self, state: _ServerState, sock: socket.socket,
                 thread: threading.Thread):
        self._state = state
        self._sock = sock
        self._thread = thread
        self.host, self.port = sock.getsockname()[:2]

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    @property
    def store(self) -> EmbeddingServer:
        return self._state.store

    def stop(self, timeout: float = 5.0) -> None:
        self._state.stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


def _client_loop(conn: socket.socket, state: _ServerState) -> None:
    try:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not state.stop.is_set():
            body = wire.recv_frame(conn)
            if body is None:
                break
            wire.send_frame(conn, state.handle(body))
    except (ConnectionError, OSError):
        pass                                      # client went away
    finally:
        try:
            conn.close()
        except OSError:
            pass


def _accept_loop(listener: socket.socket, state: _ServerState) -> None:
    listener.settimeout(0.2)                      # poll the stop flag
    threads: list[threading.Thread] = []
    while not state.stop.is_set():
        try:
            conn, _ = listener.accept()
        except socket.timeout:
            continue
        except OSError:
            break                                 # listener closed
        t = threading.Thread(target=_client_loop, args=(conn, state),
                             daemon=True)
        t.start()
        threads.append(t)
    try:
        listener.close()
    except OSError:
        pass
    for t in threads:
        t.join(0.5)


def serve_in_thread(num_layers: int, hidden: int, *,
                    host: str = "127.0.0.1",
                    port: int = 0,
                    device_tables: bool = False) -> EmbedServerHandle:
    """Start one shard listener on a background thread (ephemeral port
    by default) and return its handle."""
    state = _ServerState(num_layers, hidden, device_tables=device_tables)
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((host, port))
    listener.listen(64)
    thread = threading.Thread(target=_accept_loop, args=(listener, state),
                              daemon=True)
    thread.start()
    return EmbedServerHandle(state, listener, thread)


def serve(num_layers: int, hidden: int, *, host: str = "127.0.0.1",
          port: int = 7040, device_tables: bool = False) -> None:
    """Blocking single-shard server (the CLI entrypoint)."""
    handle = serve_in_thread(num_layers, hidden, host=host, port=port,
                             device_tables=device_tables)
    TRACE.set_process(f"embed_server:{handle.port}")
    print(f"embed_server listening on {handle.host}:{handle.port} "
          f"(L={num_layers}, hidden={hidden}"
          f"{', device tables' if device_tables else ''})", flush=True)
    try:
        while not handle._state.stop.is_set():
            handle._state.stop.wait(0.5)
    except KeyboardInterrupt:
        pass
    finally:
        handle.stop()


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="TCP embedding-server shard (repro.exchange wire "
                    "protocol)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7040)
    ap.add_argument("--num-layers", type=int, default=3,
                    help="GNN depth L; the server stores L-1 tables")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--device-tables", action="store_true",
                    help="hold the layer tables as device (jax) arrays "
                         "and serve int8 gathers/writes through the "
                         "fused kernels (bit-identical values)")
    args = ap.parse_args(argv)
    serve(args.num_layers, args.hidden, host=args.host, port=args.port,
          device_tables=args.device_tables)


if __name__ == "__main__":
    main()
