import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# This file is the ONLY place the 512 placeholder devices are forced —
# smoke tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) combination:
  lower `train_step`/`prefill`/`serve_step` with production shardings,
  compile, and record memory_analysis + cost_analysis + the collective
  bytes parsed from the partitioned HLO.  Failures here (sharding
  mismatch, OOM at compile, unsupported collective) are bugs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all          # full sweep
Results are appended to results/dryrun.json (one record per combo).
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.hlo_census import census
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_result_bytes(type_str: str) -> int:
    """Bytes of an HLO result type like 'f32[16,128]' or a tuple."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum per-partition operand/result bytes of every collective op in the
    partitioned HLO (the collective roofline numerator)."""
    out = {c: 0 for c in _COLLECTIVES}
    ops = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"\S+\s*=\s*((?:\([^)]*\)|\S+))\s+(\S+?)(?:-start)?\(",
                     line)
        if not m:
            continue
        result_type, opname = m.groups()
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "."):
                out[c] += _parse_result_bytes(result_type)
                ops[c] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["op_counts"] = ops
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str,
            *, microbatches=None, seq_parallel=None,
            fsdp_threshold=5e9, moe_groups=None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if moe_groups is not None and cfg.num_experts:
        cfg = _dc.replace(cfg, moe_groups=moe_groups)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": n_dev, "ok": False}
    t0 = time.perf_counter()
    try:
        bundle = build_step(cfg, shape, mesh, microbatches=microbatches,
                            seq_parallel=seq_parallel,
                            fsdp_threshold=fsdp_threshold)
        with mesh:
            lowered = bundle.lower()
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text)
        cen = census(hlo_text)
        rec.update({
            "ok": True,
            "lower_compile_s": round(time.perf_counter() - t0, 1),
            "memory": {k: int(getattr(mem, k))
                       for k in ("argument_size_in_bytes",
                                 "output_size_in_bytes",
                                 "temp_size_in_bytes",
                                 "generated_code_size_in_bytes")
                       if hasattr(mem, k)},
            # cost_analysis counts while bodies ONCE (loop-trip blind);
            # kept for reference.  The census numbers are loop-corrected.
            "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
            "bytes_accessed": float(cost.get("bytes accessed", -1.0))
            if cost else -1.0,
            "collectives": coll,
            "census": {"flops": cen["flops"],
                       "hbm_bytes": cen["hbm_bytes"],
                       "collective_total": cen["collective_total"],
                       "collective_bytes": cen["collective_bytes"]},
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "seq_parallel": bundle.rules.seq_parallel,
            "fsdp": bundle.rules.fsdp,
        })
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=6)
        rec["lower_compile_s"] = round(time.perf_counter() - t0, 1)
    return rec


def append_result(rec: dict, out_path: pathlib.Path):
    out_path.parent.mkdir(parents=True, exist_ok=True)
    data = []
    if out_path.exists():
        data = json.loads(out_path.read_text())
    # replace any previous record for the same combo+variant
    key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("variant", ""))
    data = [d for d in data
            if (d["arch"], d["shape"], d["mesh"], d.get("variant", "")) != key]
    data.append(rec)
    out_path.write_text(json.dumps(data, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-parallel", type=int, default=None,
                    help="override: 0/1")
    ap.add_argument("--fsdp-threshold", type=float, default=5e9)
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--variant", default="",
                    help="label for §Perf experiment records")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                for m in ("single", "multi"):
                    combos.append((a, s, m))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, args.mesh)]

    out_path = pathlib.Path(args.out)
    sp = None if args.seq_parallel is None else bool(args.seq_parallel)
    for arch, shape, meshk in combos:
        rec = run_one(arch, shape, meshk, microbatches=args.microbatches,
                      seq_parallel=sp, fsdp_threshold=args.fsdp_threshold,
                      moe_groups=args.moe_groups)
        if args.variant:
            rec["variant"] = args.variant
        append_result(rec, out_path)
        status = "OK " if rec["ok"] else "FAIL"
        extra = "" if rec["ok"] else f"  {rec['error'][:120]}"
        print(f"{status} {arch:24s} {shape:12s} {meshk:6s} "
              f"{rec['lower_compile_s']:6.1f}s{extra}", flush=True)
        if rec["ok"]:
            mem = rec["memory"]
            print(f"     mem: args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB  "
                  f"flops={rec['flops']:.3e}  "
                  f"coll={rec['collectives']['total']/2**30:.3f}GiB", flush=True)


if __name__ == "__main__":
    main()
