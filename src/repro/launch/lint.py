"""repro-lint CLI: ``python -m repro.launch.lint [--root DIR] [...]``.

Pure-stdlib entry point for the analyzer in ``repro.analysis`` — safe
to run in a bare CI container with no jax/numpy installed.

Exit codes: 0 clean, 1 findings, 2 usage/IO error.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="static analysis for wire-protocol, lock-discipline, "
                    "JAX-hygiene, and telemetry invariants")
    ap.add_argument("--root", default=".",
                    help="tree to analyze (default: cwd)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule families to run "
                         "(WP,LD,JX,TM,TL); default all")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="also scan directories named 'fixtures' "
                         "(deliberately broken test inputs)")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root)
    if not root.is_dir():
        print(f"repro-lint: not a directory: {root}", file=sys.stderr)
        return 2

    from repro.analysis import run_analysis
    select = args.select.split(",") if args.select else None
    result = run_analysis(root, select=select,
                          exclude_fixtures=not args.include_fixtures)

    if args.format == "json":
        print(json.dumps(
            {"findings": [f.to_json() for f in result.findings],
             "stats": result.stats}, indent=2, sort_keys=True))
    else:
        for f in result.findings:
            print(f.render())
        n = len(result.findings)
        print(f"repro-lint: {n} finding{'s' if n != 1 else ''} in "
              f"{result.stats['files_scanned']} files")
    return 0 if result.clean else 1


if __name__ == "__main__":
    sys.exit(main())
