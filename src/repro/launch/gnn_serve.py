"""CLI: GNN inference-serving frontend.

Trains a federated run from the shared RunConfig flags (so the served
model is pinned by the same argv contract as ``fedrun``), exports the
trained parameters + final-epoch boundary embeddings into the serving
plane (:meth:`FederatedGNNTrainer.export_for_serving`), and answers
``OP_PREDICT`` queries over TCP until an ``OP_EMBED_SHUTDOWN`` frame arrives.

    python -m repro.launch.gnn_serve --port 7060 \
        --graph reddit --scale 0.05 --graph-seed 3 \
        --clients 2 --strategy E --rounds 2 \
        --cache-rows 50000 --serve-fanout 10 --depth-schedule 1,2,3

Query it with :class:`repro.gnnserve.frontend.GnnServeClient` or the
open-loop bench (``benchmarks/bench_gnnserve.py``).
"""

from __future__ import annotations

import argparse
import json
import time

from repro.fedsvc.runtime import RunConfig
from repro.gnnserve import build_serving
from repro.gnnserve.frontend import serve_in_thread
from repro.obsv.trace import TRACE


def build_plane_from_cfg(cfg: RunConfig, *, cache_rows: int,
                         serve_fanout: int, batch_size: int,
                         depth_schedule=None, quiet: bool = False):
    """Train ``cfg.rounds`` rounds in-process, export, build the plane.
    Shared with the bench so CLI and bench serve the identical model."""
    trainer = cfg.build_trainer()
    trainer.pretrain_round()
    for rnd in range(cfg.rounds):
        stats = trainer.run_round(rnd, 0.0)
        if not quiet:
            print(f"round {rnd}: acc={stats.accuracy:.4f}", flush=True)
    bundle = trainer.export_for_serving()
    plane = build_serving(bundle, cache_rows=cache_rows,
                          serve_fanout=serve_fanout, batch_size=batch_size,
                          depth_schedule=depth_schedule)
    return trainer, plane


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="GNN node-prediction serving frontend")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on start)")
    ap.add_argument("--cache-rows", type=int, default=100_000,
                    help="hot-embedding cache capacity (rows, LRU)")
    ap.add_argument("--serve-fanout", type=int, default=10,
                    help="deterministic per-hop neighbour cap at serve time")
    ap.add_argument("--serve-batch", type=int, default=64,
                    help="padded forward batch size of the query batcher")
    ap.add_argument("--depth-schedule", default=None,
                    help="comma-separated ascending early-exit depths "
                         "ending at num-layers (default 1,..,L)")
    RunConfig.add_args(ap)
    args = ap.parse_args(argv)

    cfg = RunConfig.from_args(args)
    sched = None
    if args.depth_schedule:
        sched = [int(d) for d in args.depth_schedule.split(",")]
    t0 = time.perf_counter()
    _trainer, plane = build_plane_from_cfg(
        cfg, cache_rows=args.cache_rows, serve_fanout=args.serve_fanout,
        batch_size=args.serve_batch, depth_schedule=sched)
    print(f"trained + exported in {time.perf_counter() - t0:.1f}s",
          flush=True)

    handle = serve_in_thread(plane, host=args.host, port=args.port)
    TRACE.set_process(f"gnn_serve:{handle.port}")
    print(f"gnn_serve listening on {handle.host}:{handle.port} "
          f"shards={sorted(plane.engines)} "
          f"schedule={next(iter(plane.engines.values())).depth_schedule}",
          flush=True)
    try:
        while not handle._state.stop.is_set():
            time.sleep(0.2)
    except KeyboardInterrupt:
        pass
    finally:
        handle.stop()
        print(json.dumps(plane.stats()), flush=True)


if __name__ == "__main__":
    main()
