import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ^ placeholder devices, same contract as dryrun.py (first lines, see there).

"""Federated multi-pod dry-run: the paper's technique ON the pod axis.

Lowers + compiles one federated round of `core.fedopt` for the multi-pod
mesh with the silo dimension sharded over `pod`: each pod trains its own
silo replica for `local_steps`, then the delta aggregation is the
cross-pod collective.  This is the OptimES mapping of DESIGN.md §3 made
concrete: the embedding/model exchange that EmbC routes through a server
becomes a `pod`-axis mean; delta top-k sparsification is the §4.1 pruning
analogue (communicated bytes scale with the kept fraction).

Usage:
  PYTHONPATH=src python -m repro.launch.fedrun --arch smollm-360m \
      [--local-steps 4] [--topk 0.1]
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed import sharding as sh
from repro.launch.hlo_census import census
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw


def build_fed_round(cfg, mesh, *, local_steps: int, topk: float | None,
                    batch: int, seq: int):
    """One jittable federated round over silo-stacked state.

    Returns (fn, in_shardings, abstract_inputs)."""
    n_pods = mesh.shape["pod"]
    rules = sh.make_rules(mesh, cfg)
    opt = adamw(1e-3)
    inner = lm.make_train_step(cfg, opt)

    def silo_round(params, opt_state, batches):
        def body(carry, b):
            p, s = carry
            p, s, m = inner(p, s, b)
            return (p, s), m["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), batches)
        return params, opt_state, losses.mean()

    def fed_round(stacked_params, stacked_opt, anchor, batches):
        params, opt_state, loss = jax.vmap(silo_round)(
            stacked_params, stacked_opt, batches)
        delta = jax.tree_util.tree_map(
            lambda p, a: (p - a[None]).mean(axis=0), params, anchor)
        if topk:
            def sparsify(d):
                if d.ndim == 0:
                    return d
                mag = jnp.abs(d.astype(jnp.float32))
                thr = jnp.quantile(mag.reshape(-1), 1.0 - topk)
                return jnp.where(mag >= thr, d, 0).astype(d.dtype)
            delta = jax.tree_util.tree_map(sparsify, delta)
        new_anchor = jax.tree_util.tree_map(
            lambda a, d: a + d.astype(a.dtype), anchor, delta)
        return new_anchor, loss.mean()

    # shapes/shardings: silo dim over 'pod'; within a silo the params use
    # the standard (data, model) rules
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    pspecs = sh.param_specs(rules, pshapes)

    def pod_stack_spec(spec):
        inner_spec = [ax for ax in spec]
        # drop 'pod' from any dp tuples inside, then lead with 'pod'
        cleaned = []
        for ax in inner_spec:
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a != "pod") or None
                if ax is not None and len(ax) == 1:
                    ax = ax[0]
            cleaned.append(ax)
        return NamedSharding(mesh, P(*(("pod",) + tuple(cleaned))))

    stack = lambda tree: jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype), tree)
    stacked_pspecs = jax.tree_util.tree_map(
        pod_stack_spec, pspecs, is_leaf=lambda x: isinstance(x, P))
    oshapes = jax.eval_shape(opt.init, pshapes)
    ospecs = sh.opt_specs(rules, oshapes, pspecs)
    stacked_ospecs = jax.tree_util.tree_map(
        pod_stack_spec, ospecs, is_leaf=lambda x: isinstance(x, P))
    anchor_specs = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))

    batches = {
        "tokens": jax.ShapeDtypeStruct((n_pods, local_steps, batch, seq),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_pods, local_steps, batch, seq),
                                       jnp.int32),
    }
    bspec = {k: NamedSharding(mesh, P("pod", None, "data", None))
             for k in batches}
    return (fed_round,
            (stacked_pspecs, stacked_ospecs, anchor_specs, bspec),
            (stack(pshapes), stack(oshapes), pshapes, batches))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--topk", type=float, default=None)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--out", default="results/fedrun.json")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=True)
    fn, shardings, inputs = build_fed_round(
        cfg, mesh, local_steps=args.local_steps, topk=args.topk,
        batch=args.batch, seq=args.seq)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings).lower(
            *inputs).compile()
    mem = compiled.memory_analysis()
    cen = census(compiled.as_text())
    rec = {
        "arch": args.arch, "local_steps": args.local_steps,
        "topk": args.topk,
        "args_gib": mem.argument_size_in_bytes / 2**30,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "census_flops": cen["flops"],
        "collective_total": cen["collective_total"],
        "collective_bytes": cen["collective_bytes"],
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(out.read_text()) if out.exists() else []
    data.append(rec)
    out.write_text(json.dumps(data, indent=1))
    print(f"OK fed_round {args.arch} local_steps={args.local_steps} "
          f"topk={args.topk}")
    print(f"   args={rec['args_gib']:.2f}GiB temp={rec['temp_gib']:.2f}GiB "
          f"coll={rec['collective_total']/50e9:.2f}s "
          f"flops={rec['census_flops']/197e12:.2f}s")


if __name__ == "__main__":
    main()
