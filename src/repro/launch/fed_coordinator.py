"""CLI: the weight-aggregation coordinator service.

One coordinator per deployment.  It never touches the embed shards —
it holds the global model, gates the sync barriers (or drains the
async buffer), FedAvg-aggregates, and evaluates on the held-out test
set, exactly like the aggregation server of the in-process simulator.

    python -m repro.launch.fed_coordinator --port 7050 \
        --graph reddit --scale 0.05 --graph-seed 3 --clients 2 \
        --strategy E --rounds 2

then point workers (repro.launch.fed_worker) at host:7050.  Sync/async,
the FedBuff knobs, weight-wire compression, and per-round client
sampling all come from the strategy:
``--set aggregation='"async"' --set buffer_size=2
--set staleness_decay=0.5 --set weight_codec=int8
--set sample_frac=0.5``.

The process exits once all rounds aggregated (plus a short linger so
workers can observe the done flag), printing one JSON line per
aggregation: round, accuracy, modelled round time, measured wall
clock.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.fedsvc.coordinator import serve_in_thread
from repro.fedsvc.runtime import RunConfig, make_coordinator_state
from repro.obsv.trace import TRACE


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Federated weight-aggregation coordinator "
                    "(repro.fedsvc protocol)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7050)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="give up if training has not finished by then")
    ap.add_argument("--linger", type=float, default=3.0,
                    help="seconds to keep serving after done, so every "
                         "worker observes the done flag")
    ap.add_argument("--out", default=None,
                    help="write the aggregation history as JSON here")
    RunConfig.add_args(ap)
    args = ap.parse_args(argv)

    cfg = RunConfig.from_args(args)
    strategy = cfg.build_strategy()
    state = make_coordinator_state(cfg)
    handle = serve_in_thread(state, host=args.host, port=args.port)
    TRACE.set_process(f"fed_coordinator:{handle.port}")
    print(f"fed_coordinator listening on {handle.host}:{handle.port} "
          f"(mode={strategy.aggregation}, clients={cfg.num_clients}, "
          f"rounds={cfg.rounds}, weight_codec={strategy.weight_codec}, "
          f"sample_frac={strategy.sample_frac})", flush=True)
    try:
        finished = handle.join(timeout=args.timeout)
        with state.cond:
            history = list(state.history)
        for h in history:
            print(json.dumps(h), flush=True)
        if args.out:
            out = pathlib.Path(args.out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(history, indent=1))
        print("fed_coordinator " + ("DONE" if finished else "TIMEOUT"),
              flush=True)
        time.sleep(args.linger)
    except KeyboardInterrupt:
        pass
    finally:
        handle.stop()


if __name__ == "__main__":
    main()
