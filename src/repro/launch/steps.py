"""Sharded step builders: assemble (fn, in_shardings, out_shardings,
abstract inputs) for train / prefill / decode of any (arch × shape × mesh).

Used by launch/dryrun.py (lower+compile on the production mesh) and by
launch/train.py / launch/serve.py (real execution on host devices).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.distributed import sharding as sh
from repro.models import lm
from repro.optim import adafactor, adamw

# long-context attention variant: ring-buffer sliding window (DESIGN §4)
LONG_CONTEXT_WINDOW = 8192

# grad-accumulation factor for train_4k, keyed by d_model class; keeps
# per-chip saved activations in budget (see DESIGN §6 napkin math).
def default_microbatches(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 16384:
        return 16
    if cfg.d_model >= 8192:
        # §Perf (command-r): with seq-parallel off, G=16 keeps the saved
        # activations inside HBM while FSDP gather traffic stays 3.6x
        # below the old G=8+seq-parallel baseline.
        return 16
    if cfg.family in ("ssm", "hybrid"):
        return 8           # SSD intra-chunk buffers dominate saved memory
    if cfg.d_model >= 6144 or cfg.family == "vlm":
        return 8
    if cfg.num_experts:
        # §Perf: expert weights are model-sharded (not FSDP-gathered), so
        # extra microbatches cost no additional collective traffic — G=8
        # halves phi3.5's saved activations for free (15.9 → 8.9 GiB)
        return 8
    return 4


def shape_variant(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context SWA variant for attention architectures."""
    if shape.name == "long_500k" and cfg.family != "ssm" \
            and cfg.sliding_window is None:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def cache_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    cap = shape.seq_len
    if cfg.sliding_window is not None:
        cap = min(cap, cfg.sliding_window)
    return cap


def make_optimizer(cfg: ModelConfig):
    return adafactor(1e-3) if cfg.optimizer == "adafactor" else adamw(3e-4)


def _named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class StepBundle:
    fn: Any
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple          # ShapeDtypeStructs, positional
    cfg: ModelConfig
    rules: sh.ShardingRules

    donate: tuple = ()

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jitted().lower(*self.abstract_inputs)


def _batch_struct(cfg: ModelConfig, shape: InputShape, *, seq: int,
                  with_labels: bool):
    b = shape.global_batch
    out = {"tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, seq), jnp.int32)
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.vision_dim), cfg.dtype)
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape —
    weak-type-correct, shardable, no device allocation."""
    cfg = shape_variant(cfg, shape)
    if shape.kind == "train":
        return _batch_struct(cfg, shape, seq=shape.seq_len, with_labels=True)
    if shape.kind == "prefill":
        return _batch_struct(cfg, shape, seq=shape.seq_len, with_labels=False)
    # decode: one new token + cache of seq_len
    cap = cache_capacity(cfg, shape)
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, cap,
                              prefill_len=min(shape.seq_len, cap) - 1))
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"tokens": toks, "cache": cache}


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               *, microbatches: int | None = None,
               seq_parallel: bool | None = None,
               fsdp_threshold: float = 5e9) -> StepBundle:
    cfg = shape_variant(cfg, shape)
    # grouped MoE dispatch aligned with the data axis is the framework
    # default (§Perf: 5.3x collective / 2.8x memory on deepseek train_4k);
    # moe_groups=1 reproduces the paper-faithful global-dispatch baseline.
    if cfg.num_experts and cfg.moe_groups == 0:
        data = mesh.shape.get("data", 1)
        tokens = shape.global_batch * (1 if shape.kind == "decode"
                                       else shape.seq_len)
        if data > 1 and tokens % data == 0:
            cfg = dataclasses.replace(cfg, moe_groups=data)
    rules = sh.make_rules(mesh, cfg, seq_parallel=seq_parallel,
                          fsdp_threshold=fsdp_threshold)
    constrain = functools.partial(sh.logical_constraint, rules,
                                  kind="residual")

    pshapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = sh.param_specs(rules, pshapes)
    bspecs = sh.batch_specs(rules, cfg, shape)

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = sh.opt_specs(rules, oshapes, pspecs)
        mb = microbatches if microbatches is not None \
            else default_microbatches(cfg, shape)
        # 340B-class configs accumulate grads in bf16 (adafactor's update
        # clipping tolerates it); everything else keeps f32 accumulation.
        accum = jnp.bfloat16 if cfg.optimizer == "adafactor" \
            else jnp.float32
        pspecs_named = _named(mesh, pspecs)

        def constrain_grads(grads):
            return jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, pspecs_named)

        # per-layer slice specs for the scanned stack: drop the leading
        # (layer) axis of each stacked spec
        def constrain_block_params(lp):
            if "blocks" not in pshapes or not isinstance(pspecs, dict):
                return lp
            bspec = pspecs.get("blocks")
            if bspec is None:
                return lp

            def drop_lead(s):
                return NamedSharding(mesh, P(*list(s)[1:]))

            layer_specs = jax.tree_util.tree_map(
                drop_lead, bspec, is_leaf=lambda x: isinstance(x, P))
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, lp, layer_specs)

        step = lm.make_train_step(
            cfg, opt, microbatches=mb, constrain=constrain,
            constrain_logits=functools.partial(sh.logical_constraint, rules,
                                               kind="logits"),
            accum_dtype=accum, constrain_grads=constrain_grads,
            constrain_block_params=constrain_block_params)
        batch = _batch_struct(cfg, shape, seq=shape.seq_len,
                              with_labels=True)
        return StepBundle(
            fn=step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                          _named(mesh, bspecs)),
            out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                           None),
            abstract_inputs=(pshapes, oshapes, batch),
            cfg=cfg, rules=rules, donate=(0, 1))

    if shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = lm.forward(params, cfg, batch, constrain=constrain)
            return sh.logical_constraint(rules, logits, "logits")

        batch = _batch_struct(cfg, shape, seq=shape.seq_len,
                              with_labels=False)
        return StepBundle(
            fn=prefill,
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            out_shardings=None,
            abstract_inputs=(pshapes, batch),
            cfg=cfg, rules=rules)

    # decode
    cap = cache_capacity(cfg, shape)
    cshapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, cap,
                              prefill_len=min(shape.seq_len, cap) - 1))
    cspecs = sh.cache_specs(rules, cfg, cshapes, shape.global_batch)
    tok_spec = P(rules.dp_axes if shape.global_batch
                 % rules.axis_size(rules.dp_axes) == 0 else None, None)

    def serve_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache)

    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return StepBundle(
        fn=serve_step,
        in_shardings=(_named(mesh, pspecs), NamedSharding(mesh, tok_spec),
                      _named(mesh, cspecs)),
        out_shardings=(None, _named(mesh, cspecs)),
        abstract_inputs=(pshapes, toks, cshapes),
        cfg=cfg, rules=rules, donate=(2,))
