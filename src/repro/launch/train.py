"""Training launcher: run any zoo architecture on the local host devices.

Production launches use the same StepBundle the dry-run compiles (the
in/out shardings carry over); on this CPU container the default is the
reduced config of the chosen arch with a host mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 20 --batch 8 --seq 128 [--full-config]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.data import synthetic_batches
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_optimizer
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (needs real hardware)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config \
        else get_reduced(args.arch)
    if cfg.family in ("ssm", "hybrid"):
        args.seq = max(args.seq, cfg.ssm_chunk)
        args.seq -= args.seq % cfg.ssm_chunk
    mesh = make_host_mesh()
    print(f"arch={cfg.name} family={cfg.family} params≈{cfg.param_count():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    step = jax.jit(lm.make_train_step(cfg, opt))

    gen = synthetic_batches(cfg, batch=args.batch, seq=args.seq, seed=0)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = next(gen)
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            jax.block_until_ready(metrics["loss"])
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"aux {float(metrics['aux']):.4f}  "
                  f"{(time.perf_counter() - t0):.1f}s")
    print("done")


if __name__ == "__main__":
    main()
