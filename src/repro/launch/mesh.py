"""Production meshes (TPU v5e target).

Kept as FUNCTIONS so importing this module never touches jax device
state; only ``launch/dryrun.py`` (which forces 512 host devices in its
first two lines) should build the production meshes.
"""

from __future__ import annotations

import inspect

import jax
from jax.sharding import AbstractMesh


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-tolerant AbstractMesh constructor.

    jax <= 0.4.x takes a single ``((name, size), ...)`` shape tuple;
    jax >= 0.5 takes ``(axis_sizes, axis_names)`` positionally.  Tests
    and dry-runs build abstract meshes on 1 CPU device, so this is the
    one choke point for that API drift (see tests/test_distributed.py).
    """
    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axes, shape)))
    return AbstractMesh(tuple(shape), tuple(axes))


def _make_device_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with a fallback for jax builds that predate it
    (same positional ``(axis_shapes, axis_names)`` order either way)."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    import numpy as np
    devs = np.asarray(jax.devices()).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_device_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): (data=N, model=1)."""
    n = len(jax.devices())
    return _make_device_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~)
HBM_BYTES = 16 * 1024**3        # 16 GiB
