"""Production meshes (TPU v5e target).

Kept as FUNCTIONS so importing this module never touches jax device
state; only ``launch/dryrun.py`` (which forces 512 host devices in its
first two lines) should build the production meshes.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): (data=N, model=1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~)
HBM_BYTES = 16 * 1024**3        # 16 GiB
