"""obs_dump: scrape every endpoint of a deployment into one timeline.

Every TCP plane in the repro answers the shared telemetry opcodes
(:mod:`repro.obsv.teleserve`): embed shards on their data port, the
fedsvc coordinator on its control port, the gnnserve frontend on its
scoring port, and fed_worker processes on the telemetry-only listener
``--obs-port`` starts.  This CLI scrapes them all, aligns each
process's private ``perf_counter`` clock via the scrape-time handshake,
and writes

  * one Chrome trace-event JSON (``--out``) — open it in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing`` to see a whole
    federated round across all processes on one timeline, and
  * one merged metrics table (``--metrics-out``, ``-`` = stdout).

Example, against a 6-process deployment (coordinator + 2 workers + 2
embed shards + serving frontend)::

    python -m repro.launch.obs_dump \
        --coordinator 127.0.0.1:7050 \
        --embed 127.0.0.1:7040 --embed 127.0.0.1:7041 \
        --worker 127.0.0.1:7060 --worker 127.0.0.1:7061 \
        --serve 127.0.0.1:7070 \
        --out trace.json --metrics-out -

Spans only appear when the scraped process has tracing enabled —
launch it with ``REPRO_TRACE=1``.  Metrics are always on.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obsv import teleserve


def collect_endpoints(args) -> list[tuple[str, str]]:
    """→ [(label, addr)] in a stable scrape order."""
    out: list[tuple[str, str]] = []
    if args.coordinator:
        out.append(("coordinator", args.coordinator))
    for i, a in enumerate(args.embed or []):
        out.append((f"embed{i}", a))
    for i, a in enumerate(args.worker or []):
        out.append((f"worker{i}", a))
    if args.serve:
        out.append(("serve", args.serve))
    for spec in args.endpoint or []:
        label, _, addr = spec.partition("=")
        if not addr:
            label, addr = spec, spec
        out.append((label, addr))
    return out


def dump(endpoints: list[tuple[str, object]]) -> tuple[dict, str]:
    """Scrape ``[(label, addr)]`` → (chrome trace doc, metrics table).
    The library entrypoint tests and notebooks use directly."""
    scrapes = teleserve.scrape_all(endpoints)
    return teleserve.merge_scrapes(scrapes)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Scrape OP_METRICS/OP_TRACE from every endpoint of "
                    "a deployment; merge into one Chrome trace + one "
                    "metrics table")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT")
    ap.add_argument("--embed", action="append", metavar="HOST:PORT",
                    help="embed-server shard (repeatable)")
    ap.add_argument("--worker", action="append", metavar="HOST:PORT",
                    help="fed_worker --obs-port listener (repeatable)")
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="gnnserve scoring frontend")
    ap.add_argument("--endpoint", action="append",
                    metavar="LABEL=HOST:PORT",
                    help="any other telemetry-speaking endpoint "
                         "(repeatable)")
    ap.add_argument("--out", default="trace.json",
                    help="Chrome trace-event JSON output path")
    ap.add_argument("--metrics-out", default="-",
                    help="metrics table output path ('-' = stdout)")
    args = ap.parse_args(argv)

    endpoints = collect_endpoints(args)
    if not endpoints:
        ap.error("no endpoints given")
    trace_doc, table = dump(endpoints)
    with open(args.out, "w") as f:
        json.dump(trace_doc, f)
    n_ev = sum(1 for e in trace_doc["traceEvents"] if e["ph"] == "X")
    n_proc = sum(1 for e in trace_doc["traceEvents"] if e["ph"] == "M")
    print(f"obs_dump: {len(endpoints)} endpoints scraped, {n_proc} "
          f"process tracks, {n_ev} spans → {args.out}", flush=True)
    if args.metrics_out == "-":
        sys.stdout.write(table + "\n")
    else:
        with open(args.metrics_out, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
