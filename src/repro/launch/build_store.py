"""CLI: build an out-of-core graph store + partition + client shards.

One command takes a graph family to a ready-to-serve store directory:
the mmap CSR lands via the chunked streaming builder (never holding the
edge list), the partition via the single-pass streaming LDG (or the
in-memory BFS partitioner for small graphs), and the per-client shards
via the streaming halo extractor — after which every ``fed_worker``
points at it with ``--graph store:<dir>`` and mmaps only its own
clients' shards.

    # 1M-vertex R-MAT, 8 client shards
    python -m repro.launch.build_store --out /tmp/rmat20 \
        --rmat-scale 20 --edge-factor 8 --seed 1 --clients 8

    # a Table-1 preset, bit-identical to the in-memory generator
    python -m repro.launch.build_store --out /tmp/reddit \
        --preset reddit --scale 0.05 --graph-seed 3 --clients 2

Prints one JSON line of build/partition stats (vertices, edges,
throughput, edge cut, peak RSS) — ``benchmarks/bench_scaling.py``
parses it from a subprocess so builder RSS is measured in isolation.
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time

import numpy as np


def _status_kb(field: str) -> float | None:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field):
                    return float(line.split()[1])
    except OSError:
        pass
    return None


_rss_samples: list[float] = []


def _sample_rss() -> None:
    cur = _status_kb("VmRSS")
    if cur is not None:
        _rss_samples.append(cur)


def _start_rss_sampler(period_s: float = 0.05):
    """Background VmRSS sampler — catches transient peaks (bucket sort
    temporaries) that phase-boundary samples would miss."""
    import threading
    stop = threading.Event()

    def loop():
        while not stop.is_set():
            _sample_rss()
            stop.wait(period_s)

    threading.Thread(target=loop, daemon=True).start()
    return stop


def _peak_rss_mb() -> float:
    """Peak RSS of this process: the kernel's high-water mark when
    exposed, else the max of the per-phase VmRSS samples.  getrusage is
    last resort only — under some sandboxes a fork()ed child *inherits*
    the parent's ru_maxrss, which makes a slim builder spawned from a
    fat benchmark process look enormous."""
    hwm = _status_kb("VmHWM")
    if hwm is not None:
        return hwm / 1024
    if _rss_samples:
        return max(_rss_samples) / 1024
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Build an mmap graph store (+ partition + shards)")
    ap.add_argument("--out", required=True, help="store directory")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--preset", help="synthetic preset (DC-SBM, "
                                      "bit-identical to make_graph)")
    src.add_argument("--rmat-scale", type=int,
                     help="R-MAT: V = 2**scale (Graph500 kernel 1)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="preset vertex-count multiplier")
    ap.add_argument("--graph-seed", type=int, default=3,
                    help="generator seed (matches RunConfig --graph-seed)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="partition seed (matches RunConfig --seed)")
    ap.add_argument("--clients", type=int, default=0,
                    help="partition + build shards for K clients (0: skip)")
    ap.add_argument("--partitioner", choices=("ldg", "bfs"), default="ldg")
    ap.add_argument("--retention", default="inf",
                    help="retention limit baked into the shards "
                         "(int, or 'inf' for P_inf/EmbC)")
    args = ap.parse_args(argv)

    from repro.graphstore import (build_rmat_store, build_sbm_store,
                                  ldg_partition, stream_client_shards)

    _sample_rss()
    _sampler_stop = _start_rss_sampler()
    t0 = time.perf_counter()
    if args.preset is not None:
        store = build_sbm_store(args.out, args.preset, scale=args.scale,
                                seed=args.graph_seed)
    else:
        store = build_rmat_store(args.out, args.rmat_scale,
                                 edge_factor=args.edge_factor,
                                 seed=args.graph_seed)
    t_build = time.perf_counter() - t0
    _sample_rss()
    build_rss_kb = max(_rss_samples, default=0.0)

    stats = {
        "path": store.path,
        "num_vertices": store.num_vertices,
        "num_edges": store.num_edges,
        "build_s": round(t_build, 3),
        "build_edges_per_s": round(store.num_edges / max(t_build, 1e-9)),
        "build_peak_rss_mb": round(build_rss_kb / 1024, 1),
    }

    if args.clients > 0:
        k = args.clients
        t0 = time.perf_counter()
        if args.partitioner == "ldg":
            part = ldg_partition(store, k, seed=args.seed)
        else:
            from repro.graphs import bfs_partition
            part = bfs_partition(store, k, seed=args.seed)
        t_part = time.perf_counter() - t0
        _sample_rss()
        store.save_partition(part, k, args.seed)

        limit = None if args.retention == "inf" else int(args.retention)
        t0 = time.perf_counter()
        # one shard resident at a time: k cheap mmap passes instead of
        # holding every shard's edges — this keeps the whole pipeline's
        # RSS bounded by one shard, not the graph
        pulls: list[np.ndarray] = []
        for c in range(k):
            sh = stream_client_shards(store, part, client_ids=[c],
                                      retention_limit=limit,
                                      seed=args.seed)[0]
            store.save_shard(sh, k, args.seed, limit)
            pulls.append(sh.pull_nodes)
            del sh
        # reciprocal push sets, exactly as the trainer recomputes them:
        # client c pushes what the others retained
        root = store.shards_dir(k, args.seed, limit)
        for c in range(k):
            wanted = [p[part[p] == c]
                      for j, p in enumerate(pulls) if j != c]
            push = np.unique(np.concatenate(wanted)) \
                if wanted else np.zeros(0, np.int64)
            np.save(os.path.join(root, f"shard{c}", "push_nodes.npy"),
                    push)
        store.finalize_shards(k, args.seed, limit, k)
        t_shard = time.perf_counter() - t0
        _sample_rss()

        boundary = int(sum(len(p) for p in pulls))
        sizes = np.bincount(part, minlength=k)
        stats.update({
            "clients": k,
            "partition_s": round(t_part, 3),
            "partition_vertices_per_s":
                round(store.num_vertices / max(t_part, 1e-9)),
            "shard_s": round(t_shard, 3),
            "part_sizes": [int(s) for s in sizes],
            "boundary_pull_nodes": boundary,
        })

    _sampler_stop.set()
    stats["peak_rss_mb"] = round(_peak_rss_mb(), 1)
    json.dump(stats, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
