"""Loop-aware census of a partitioned HLO module.

``compiled.cost_analysis()`` counts each while-loop *body* once, so for
scanned models (layer scan × microbatch scan × attention KV scan) FLOPs,
bytes and collective payloads are under-reported by the product of trip
counts.  This module parses the HLO text, recovers each loop's trip count
from its condition computation, propagates multipliers through the call
graph, and produces execution-weighted totals:

  flops            — 2·M·N·K per dot (einsums lower to dots), × trips
  hbm_bytes        — operand+result bytes of top-level instructions per
                     computation (fusion boundaries ≈ materialisation
                     points), × trips
  collective_bytes — result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     × trips

All quantities are per-partition (the HLO is post-SPMD).
Calibration: for an unscanned matmul this reproduces cost_analysis
exactly; for a scanned 2-layer model it reports 2× the body (verified in
tests/test_roofline.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "u64": 8, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s]+?))\s+"
    r"([\w\-]+)\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    text: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    types: dict[str, str]           # symbol -> result type (incl. params)
    is_entry: bool = False


_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^()]*\)|\w+\[[\d,]*\]"
                       r"(?:\{[\d,]*\})?))")


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {},
                                  is_entry=line.lstrip().startswith("ENTRY"))
                # header parameter types: "(name: type, name: type)"
                hdr = line[line.index("("):]
                for pname, ptype in _PARAM_RE.findall(hdr.split("->")[0]):
                    cur.types[pname] = ptype
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3), line)
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.result_type
    return comps


def _called(instr: Instr) -> list[tuple[str, str]]:
    """(kind, computation) pairs invoked by this instruction.

    The attribute value is either a single ``%name`` or a braced list
    ``{%a, %b}``; stopping at the brace/name boundary keeps the *next*
    attribute (``metadata=...`` etc.) from leaking into the names."""
    out = []
    for attr in ("condition", "body", "calls", "to_apply",
                 "branch_computations"):
        m = re.search(attr + r"=(?:\{([^}]*)\}|%?([\w\.\-]+))", instr.text)
        if m:
            names = m.group(1) if m.group(1) is not None else m.group(2)
            for name in names.split(","):
                out.append((attr, name.strip().lstrip("%")))
    return out


def _operands(instr: Instr) -> list[str]:
    """Top-level operand tokens of ``op(...)`` — commas inside brackets
    (inline shapes like ``f32[8,16]{1,0}``) and nested parens (tuple
    types) do not split."""
    rest = instr.text.split(instr.op + "(", 1)
    if len(rest) != 2:
        return []
    s = rest[1]
    out, tok, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in "}]":
            depth -= 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        elif ch == "," and depth == 0:
            out.append("".join(tok).strip())
            tok = []
            continue
        tok.append(ch)
    if tok and "".join(tok).strip():
        out.append("".join(tok).strip())
    return out


_INLINE_TYPE = re.compile(r"(\w+\[[\d,]*\](?:\{[\d,]*\})?)")


def _operand_type(tok: str, types: dict[str, str]) -> str | None:
    """Resolve one operand token to its type string: inline type when the
    dump carries one, else the symbol table."""
    m = _INLINE_TYPE.search(tok)
    if m:
        return m.group(1)
    m = re.search(r"%?([\w\.\-]+)\s*$", tok)
    if m and m.group(1) in types:
        return types[m.group(1)]
    return None


_KNOWN_TRIPS = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')


def _instr_trip_count(instr: Instr) -> int | None:
    """Trip count XLA stamped on the while itself
    (``backend_config={"known_trip_count":{"n":"5"}}``) — authoritative
    when present."""
    m = _KNOWN_TRIPS.search(instr.text)
    return int(m.group(1)) if m else None


def _trip_count(cond: Computation) -> int:
    """Recover the loop bound from the condition computation.

    XLA canonical counted loops compare the induction variable against an
    s32 constant; in scheduled dumps the compare is often wrapped in a
    kLoop fusion whose constant operand lives in the condition
    computation, so we take the largest plausible integer constant there.
    Falls back to 1 (cost_analysis semantics) when absent."""
    best = 0
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.text)
            if m:
                v = int(m.group(1))
                if 0 < v < 10_000_000:
                    best = max(best, v)
    return best if best else 1


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    """2 × (product of result dims) × (product of contraction dims).
    Operand types are resolved through the computation's symbol table
    (scheduled dumps don't inline operand types)."""
    shapes = _shape_dims(instr.result_type)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    result_elems = 1
    for d in rdims:
        result_elems *= d
    lhs_dims: list[int] = []
    ops = _operands(instr)
    if ops:
        lhs_type = _operand_type(ops[0], types)
        if lhs_type:
            sh = _shape_dims(lhs_type)
            if sh:
                lhs_dims = sh[0][1]
    mdim = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", instr.text)
    contraction = 1
    if mdim and lhs_dims:
        for ax in mdim.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_dims):
                contraction *= lhs_dims[ax]
    return 2.0 * result_elems * contraction


def _operand_bytes(instr: Instr, types: dict[str, str]) -> int:
    """Total bytes of the instruction's operands (inline types when the
    dump carries them, symbol-table resolved otherwise)."""
    total = 0
    for tok in _operands(instr):
        if _INLINE_TYPE.search(tok):
            total += _type_bytes(tok)
            continue
        m = re.match(r"\s*%?([\w\.\-]+)", tok)
        if m and m.group(1) in types:
            total += _type_bytes(types[m.group(1)])
    return total


def census(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0,
                "collective_bytes": {c: 0.0 for c in COLLECTIVES},
                "collective_total": 0.0}

    # multipliers per computation: DFS from entry through call sites
    mult: dict[str, float] = defaultdict(float)

    def visit(comp: Computation, m: float):
        mult[comp.name] += m
        for ins in comp.instrs:
            calls = _called(ins)
            if ins.op == "while":
                body = next((n for k, n in calls if k == "body"), None)
                cond = next((n for k, n in calls if k == "condition"), None)
                trips = _instr_trip_count(ins)
                if trips is None:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                if cond in comps:
                    visit(comps[cond], m * (trips + 1))
                if body in comps:
                    visit(comps[body], m * trips)
            elif ins.op in ("fusion",):
                continue  # fusion internals are not HBM/collective events
            elif ins.op in ("conditional",):
                for k, n in calls:
                    if n in comps:
                        visit(comps[n], m)  # assume each branch once
            else:
                for k, n in calls:
                    if k in ("calls", "to_apply") and n in comps:
                        visit(comps[n], m)

    visit(entry, 1.0)

    flops = 0.0
    hbm = 0.0
    coll = {c: 0.0 for c in COLLECTIVES}
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, comp.types)
            if ins.op in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast"):
                continue
            hbm += m * _type_bytes(ins.result_type)
            for c in COLLECTIVES:
                if ins.op == c or ins.op.startswith(c + "."):
                    # wire-byte semantics: ring all-reduce moves ~2× the
                    # full tensor per chip; all-gather moves the gathered
                    # result; reduce-scatter moves the full OPERAND.
                    rb = _type_bytes(ins.result_type)
                    ob = _operand_bytes(ins, comp.types)
                    wire = max(rb, ob) * (2 if c == "all-reduce" else 1)
                    coll[c] += m * wire
                    break
    # fusions: count dot flops inside fusion bodies at the caller's rate
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op == "fusion":
                called = _called(ins)
                for k, n in called:
                    if k == "calls" and n in comps:
                        sub_c = comps[n]
                        for sub in sub_c.instrs:
                            if sub.op == "dot":
                                flops += m * _dot_flops(sub, sub_c.types)
    return {"flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll,
            "collective_total": sum(coll.values())}


def compiled_flops(compiled) -> float:
    """``cost_analysis()['flops']`` across jax versions: 0.4.x returns a
    list of per-program dicts, >=0.5 a single dict; either may omit the
    key for trivial programs."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))
