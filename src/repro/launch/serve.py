"""Serving launcher: batched decode of any zoo architecture.

Prefill is run through the forward path to seed logits (greedy prompt
consumption via repeated decode keeps the code path single — the decode
step is exactly what the dry-run lowers for decode_32k / long_500k).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --batch 4 --prompt 32 --generate 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.data import synthetic_request_stream
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--generate", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config \
        else get_reduced(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    capacity = args.prompt + args.generate
    if cfg.sliding_window:
        capacity = min(capacity, cfg.sliding_window)
    cache = lm.init_cache(cfg, args.batch, capacity)
    dec = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))

    prompts = next(synthetic_request_stream(
        cfg, batch=args.batch, prompt_len=args.prompt, seed=0))
    toks = jnp.asarray(prompts[:, :1], jnp.int32)

    t0 = time.perf_counter()
    generated = []
    for step in range(args.prompt + args.generate - 1):
        logits, cache = dec(params, toks, cache)
        if step < args.prompt - 1:           # teacher-force the prompt
            toks = jnp.asarray(prompts[:, step + 1: step + 2], jnp.int32)
        else:                                # greedy generation
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            generated.append(np.asarray(toks)[:, 0])
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    n_tok = args.batch * (args.prompt + args.generate - 1)
    print(f"arch={cfg.name} served {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s on CPU)")
    gen = np.stack(generated, axis=1)
    print("sample generations (token ids):")
    for row in gen[: min(2, args.batch)]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
