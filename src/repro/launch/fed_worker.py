"""CLI: one federated client-worker process.

Owns one or more clients of the deployment, rebuilds the identical
graph/partition/model from the shared RunConfig flags, trains its
clients' share of every round through
``FederatedGNNTrainer.client_round``, exchanges embeddings with the
embed shards (``--embed``, repeatable) and weights with the coordinator
(``--coordinator``).

    python -m repro.launch.fed_worker --coordinator 127.0.0.1:7050 \
        --client-ids 0 --graph reddit --scale 0.05 --graph-seed 3 \
        --clients 2 --strategy E --rounds 2 \
        --embed 127.0.0.1:7040 --embed 127.0.0.1:7041

Scenario injection: ``--pacing 2.0`` makes this worker a uniform 2×
straggler, ``--straggler-s`` adds a fixed per-round delay, and
``--dropout-prob`` gives it a per-round chance of dying mid-round —
all three are reflected in both the measured wall clock (real sleeps)
and the modelled round-time ledger it reports to the coordinator.

Churn: ``--drop-round N`` kills the worker deterministically mid-round
N (after its pull, before its update — the spot that stresses the
coordinator most); adding ``--rejoin`` makes it come back after
``--rejoin-delay-s`` seconds on a fresh connection, re-hello with the
same client ids, and catch up from the coordinator's current model —
the worker re-join path end to end.
"""

from __future__ import annotations

import argparse
import json

from repro.fedsvc.runtime import RunConfig
from repro.fedsvc.worker import FedWorker, WorkerScenario
from repro.obsv import teleserve
from repro.obsv.trace import TRACE


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        description="Federated client worker (repro.fedsvc protocol)")
    ap.add_argument("--coordinator", required=True, metavar="HOST:PORT")
    ap.add_argument("--client-ids", required=True,
                    help="comma-separated client indices this worker owns")
    ap.add_argument("--worker-id", default=None)
    ap.add_argument("--pacing", type=float, default=1.0)
    ap.add_argument("--straggler-s", type=float, default=0.0)
    ap.add_argument("--dropout-prob", type=float, default=0.0)
    ap.add_argument("--scenario-seed", type=int, default=0)
    ap.add_argument("--drop-round", type=int, default=None,
                    help="die deterministically mid-round N (once)")
    ap.add_argument("--rejoin", action="store_true",
                    help="reconnect + re-hello after a drop instead of "
                         "staying dead")
    ap.add_argument("--rejoin-delay-s", type=float, default=0.5)
    ap.add_argument("--obs-port", type=int, default=None,
                    help="run a telemetry-only listener on this port "
                         "(OP_METRICS/OP_TRACE) so obs_dump can scrape "
                         "this worker — workers are otherwise pure "
                         "clients with no port of their own")
    RunConfig.add_args(ap)
    args = ap.parse_args(argv)

    cfg = RunConfig.from_args(args)
    client_ids = [int(c) for c in args.client_ids.split(",") if c != ""]
    scenario = WorkerScenario(pacing=args.pacing,
                              straggler_s=args.straggler_s,
                              dropout_prob=args.dropout_prob,
                              seed=args.scenario_seed,
                              drop_round=args.drop_round,
                              rejoin=args.rejoin,
                              rejoin_delay_s=args.rejoin_delay_s)
    worker = FedWorker(cfg, client_ids, args.coordinator,
                       worker_id=args.worker_id, scenario=scenario)
    TRACE.set_process(f"fed_worker:{worker.worker_id}")
    obs = None
    if args.obs_port is not None:
        obs = teleserve.serve_telemetry(port=args.obs_port)
        print(f"fed_worker telemetry on {obs.host}:{obs.port}",
              flush=True)
    print(f"fed_worker {worker.worker_id} clients={client_ids} "
          f"coordinator={args.coordinator}", flush=True)
    try:
        records = worker.run()
    finally:
        if obs is not None:
            obs.stop()
    for rec in records:
        print(json.dumps(rec), flush=True)
    status = "DROPPED" if worker.dropped else \
        "DISCONNECTED" if worker.disconnected else "DONE"
    rejoined = f" rejoins={worker.rejoins}" if worker.rejoins else ""
    print(f"fed_worker {worker.worker_id} {status}{rejoined}", flush=True)


if __name__ == "__main__":
    main()
