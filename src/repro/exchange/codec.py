"""Wire codecs for remote-embedding exchange.

A codec defines what an (n, hidden) fp32 block of embedding rows looks
like on the wire: its encoded payload, the fp32 values the receiver
reconstructs, and the effective bytes/scalar the NetworkModel charges.
All codecs are **row-independent and deterministic** — encoding a row
does not depend on its neighbours — which is the property that makes
sharded transports bit-identical to single-shard ones (the rows can be
split across servers in any way without changing the reconstruction).

Codecs:
  fp32 — passthrough (seed behavior), 4 B/scalar
  fp16 — IEEE half precision, 2 B/scalar; exact on representable values
  int8 — per-row symmetric quantization via the Pallas kernel
         (repro.kernels.quantize; jnp oracle on CPU), 1 B/scalar plus an
         amortized 4 B/row fp32 scale; max abs error ≤ row absmax / 254
"""

from __future__ import annotations

import abc

import numpy as np


class WireCodec(abc.ABC):
    """Encode/decode one (n, hidden) fp32 layer block for the wire."""

    name: str = "?"
    wire_arrays: int = 1       # arrays per encoded block (int8: values+scales)

    @abc.abstractmethod
    def encode(self, x: np.ndarray):
        """fp32 (n, hidden) → wire payload (codec-specific)."""

    @abc.abstractmethod
    def decode(self, payload) -> np.ndarray:
        """wire payload → fp32 (n, hidden) as reconstructed by the
        receiver."""

    @abc.abstractmethod
    def bytes_per_scalar(self, hidden: int) -> float:
        """Effective wire bytes per fp32 scalar (row overheads amortized
        over ``hidden``) — drives NetworkModel byte accounting."""

    def roundtrip(self, x: np.ndarray) -> np.ndarray:
        """The values the far side sees after one wire crossing."""
        return self.decode(self.encode(x))

    # -- device path (jax Arrays end to end) --------------------------------

    def encode_dev(self, x):
        """Device-path encode: jax Array in → jax wire array(s) out,
        value-identical to :meth:`encode`.  The base implementation
        stages through the host encode; codecs with a real device
        kernel (int8) override it."""
        import jax.numpy as jnp
        payload = self.encode(np.asarray(x, np.float32))
        if isinstance(payload, tuple):
            return tuple(jnp.asarray(p) for p in payload)
        return jnp.asarray(payload)

    def decode_dev(self, payload):
        """Device-path decode: wire array(s) → fp32 jax Array,
        value-identical to :meth:`decode`."""
        import jax.numpy as jnp
        if isinstance(payload, tuple):
            payload = tuple(np.asarray(p) for p in payload)
        else:
            payload = np.asarray(payload)
        return jnp.asarray(np.asarray(self.decode(payload), np.float32))

    def roundtrip_dev(self, x):
        """Device-path wire crossing — bit-identical values to
        :meth:`roundtrip` (codecs are deterministic)."""
        return self.decode_dev(self.encode_dev(x))

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class Fp32Codec(WireCodec):
    """Seed behavior: raw fp32 rows, lossless."""

    name = "fp32"

    def encode(self, x):
        return np.asarray(x, np.float32)

    def decode(self, payload):
        return payload

    def bytes_per_scalar(self, hidden: int) -> float:
        return 4.0


class Fp16Codec(WireCodec):
    """IEEE half-precision rows: 2 B/scalar, exact on fp16-representable
    values, relative error ≤ 2^-11 otherwise."""

    name = "fp16"

    def encode(self, x):
        return np.asarray(x, np.float16)

    def decode(self, payload):
        return payload.astype(np.float32)

    def bytes_per_scalar(self, hidden: int) -> float:
        return 2.0


class Int8Codec(WireCodec):
    """Per-row symmetric int8 quantization (scale = row absmax / 127).

    Encode/decode run through the kernel dispatcher so the Pallas path is
    the measured hot loop on TPU and the jnp oracle elsewhere."""

    name = "int8"
    wire_arrays = 2

    def __init__(self, use_pallas="auto"):
        self.use_pallas = use_pallas

    def encode(self, x):
        from repro.kernels import ops
        values, scales = ops.quantize_int8(np.asarray(x, np.float32),
                                           use_pallas=self.use_pallas)
        return np.asarray(values), np.asarray(scales)

    def decode(self, payload):
        from repro.kernels import ops
        values, scales = payload
        return np.asarray(
            ops.dequantize_int8(values, scales, use_pallas=self.use_pallas),
            np.float32)

    def encode_dev(self, x):
        import jax.numpy as jnp

        from repro.kernels import ops
        return ops.quantize_int8(jnp.asarray(x, jnp.float32),
                                 use_pallas=self.use_pallas)

    def decode_dev(self, payload):
        import jax.numpy as jnp

        from repro.kernels import ops
        values, scales = payload
        return ops.dequantize_int8(jnp.asarray(values), jnp.asarray(scales),
                                   use_pallas=self.use_pallas)

    def bytes_per_scalar(self, hidden: int) -> float:
        return 1.0 + 4.0 / hidden          # int8 row + one fp32 scale


_CODECS = {
    "fp32": Fp32Codec,
    "fp16": Fp16Codec,
    "int8": Int8Codec,
}


def available_codecs() -> list[str]:
    return sorted(_CODECS)


def get_codec(name: str | WireCodec) -> WireCodec:
    """Resolve a codec by name (Strategy.codec) or pass one through."""
    if isinstance(name, WireCodec):
        return name
    try:
        return _CODECS[name]()
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; available: {available_codecs()}"
        ) from None


# -- leaf-pytree form (the weight wire) ---------------------------------------
#
# The row-oriented codecs above operate on (n, hidden) embedding blocks.
# The federated *weight* plane moves flat leaf lists (a params pytree's
# tree_flatten order) whose shapes vary per leaf, so each leaf is
# flattened to a single (1, size) row and run through the same codec —
# for int8 that makes the quantization grain one scale per leaf, the
# natural model-delta analogue of per-row embedding scales.  Encoding
# yields plain numpy arrays that ride the control plane's
# ``wire.build_tensors`` framing, so an int8-encoded leaf really costs
# 1 B/scalar on the socket, not just in the modelled ledger.

def encode_leaves(codec: str | WireCodec, leaves) -> tuple[list, list]:
    """fp32 leaf list → (wire tensors, shapes).

    ``shapes`` must travel alongside the tensors (the JSON header of a
    control-plane RPC) so :func:`decode_leaves` can restore the leaf
    shapes; the tensor list holds ``codec.wire_arrays`` arrays per leaf
    in leaf order."""
    codec = get_codec(codec)
    tensors: list[np.ndarray] = []
    shapes: list[list[int]] = []
    for leaf in leaves:
        leaf = np.asarray(leaf, np.float32)
        shapes.append([int(d) for d in leaf.shape])
        payload = codec.encode(leaf.reshape(1, -1))
        if isinstance(payload, tuple):
            tensors.extend(np.asarray(p) for p in payload)
        else:
            tensors.append(np.asarray(payload))
    return tensors, shapes


def decode_leaves(codec: str | WireCodec, tensors, shapes) -> list[np.ndarray]:
    """Inverse of :func:`encode_leaves`: the fp32 leaves the receiver
    reconstructs (bit-identical to the sender's local
    ``codec.roundtrip`` — codecs are deterministic)."""
    codec = get_codec(codec)
    per = codec.wire_arrays
    if len(tensors) != per * len(shapes):
        raise ValueError(
            f"{codec.name} leaf payload carries {len(tensors)} arrays "
            f"for {len(shapes)} leaves (expected {per} per leaf)")
    out = []
    for i, shape in enumerate(shapes):
        block = tensors[per * i: per * (i + 1)]
        payload = tuple(block) if per > 1 else block[0]
        out.append(np.asarray(codec.decode(payload), np.float32)
                   .reshape(shape))
    return out
