"""Transports: where remote-embedding bytes actually travel.

A :class:`Transport` separates the *storage* of shared embeddings (the
EmbeddingServer tables) from the *wire model* that charges for moving
them.  Two implementations:

  InProcessTransport — one embedding server behind one NetworkModel;
      exactly the seed topology (§5.1's single Redis instance).
  ShardedTransport   — vertex ids hashed across S embedding-server
      shards, each with its own NetworkModel (heterogeneous links are a
      list of models) and its own TransferLog.  Shards serve in
      parallel, so modelled wall time is the max over shards while
      bytes/RPCs accumulate per shard.

Time accounting is split into pure ``*_time`` queries (used when a push
is planned during training but applied later — §4.2 overlap keeps the
server static within a round) and ``account_*`` calls that also record
into the shard TransferLogs.
"""

from __future__ import annotations

import abc
import heapq

import numpy as np

from repro.core.cost_model import NetworkModel, TransferLog
from repro.core.embedding_server import EmbeddingServer


class Transport(abc.ABC):
    """Storage + modelled wire for one federated deployment."""

    num_layers: int
    hidden: int

    #: True when :meth:`gather`/:meth:`write` already move codec bytes
    #: across a real wire (TcpTransport).  ExchangeClient then skips its
    #: simulated codec roundtrip on pull — the crossing actually
    #: happened — keeping numerics bit-identical to modelled transports.
    wire_is_real: bool = False

    #: True when the backing EmbeddingServer(s) hold device-resident
    #: tables, which makes the fused quantized surface below the cheap
    #: path (ExchangeClient routes int8 pulls/pushes through it).
    device_tables: bool = False

    def gather_quantized(self, global_ids: np.ndarray,
                         layers: list[int] | None = None) -> list[tuple]:
        """Fused pull response: per selected layer, (values int8
        (n, hidden), scales fp32 (n, 1)) in original id order —
        bit-identical to int8-encoding :meth:`gather`'s rows (the codec
        is row-independent, so shard splits can't change the values)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no fused quantized surface")

    def write_quantized(self, global_ids: np.ndarray,
                        layer_payloads: list[tuple]) -> None:
        """Fused push apply: store int8 payload rows via
        decode+scatter — bit-identical to ``write(decode(payload))``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no fused quantized surface")

    # -- storage -----------------------------------------------------------

    @abc.abstractmethod
    def register(self, global_ids: np.ndarray) -> None: ...

    @abc.abstractmethod
    def write(self, global_ids: np.ndarray,
              layer_values: list[np.ndarray]) -> None:
        """Raw store of decoded fp32 rows (no accounting)."""

    @abc.abstractmethod
    def gather(self, global_ids: np.ndarray,
               layers: list[int] | None = None) -> list[np.ndarray]:
        """Raw read (no accounting), original id order."""

    @abc.abstractmethod
    def gather_versioned(
        self, global_ids: np.ndarray, have_versions: np.ndarray,
        layers: list[int] | None = None,
    ) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
        """Conditional gather for serving-side caches (no accounting).

        ``have_versions[i]`` is the caller's cached row version for
        ``global_ids[i]`` (-1 = never seen).  Returns ``(versions,
        stale_pos, layer_values)``: current versions for every id,
        positions whose rows were out of date, and the selected layers'
        rows for exactly those positions in ``stale_pos`` order."""

    # -- modelled wire -----------------------------------------------------

    @abc.abstractmethod
    def transfer_time(self, global_ids: np.ndarray, layers: int,
                      bytes_per_scalar: float) -> float:
        """Pure time query for one batched transfer (no logging)."""

    @abc.abstractmethod
    def account(self, global_ids: np.ndarray, layers: int,
                bytes_per_scalar: float) -> float:
        """Record one batched transfer in the shard logs, return time."""

    # -- telemetry ---------------------------------------------------------

    @property
    @abc.abstractmethod
    def shard_logs(self) -> list[TransferLog]: ...

    @property
    def log(self) -> TransferLog:
        """Read-only aggregate over all shard logs — a fresh snapshot
        each access, so writes to it are discarded.  Record traffic via
        :meth:`account`; per-shard state lives in :attr:`shard_logs`."""
        total = TransferLog()
        for lg in self.shard_logs:
            total.add(bytes=lg.bytes, rpcs=lg.rpcs,
                      embeddings=lg.embeddings, seconds=lg.seconds,
                      measured_seconds=lg.measured_seconds)
        return total

    @property
    @abc.abstractmethod
    def num_embeddings_stored(self) -> int: ...

    @abc.abstractmethod
    def memory_bytes(self) -> int: ...


class InProcessTransport(Transport):
    """Single embedding server behind a single link (seed behavior)."""

    num_shards = 1

    def __init__(self, num_layers: int, hidden: int,
                 net: NetworkModel | None = None, *,
                 device_tables: bool = False):
        self.num_layers = num_layers
        self.hidden = hidden
        self.net = net or NetworkModel()
        self.device_tables = bool(device_tables)
        self.server = EmbeddingServer(num_layers, hidden, self.net,
                                      device_tables=device_tables)
        self._log = TransferLog()

    def register(self, global_ids):
        self.server.register(global_ids)

    def write(self, global_ids, layer_values):
        self.server.write(global_ids, layer_values)

    def gather(self, global_ids, layers=None):
        return self.server.gather(global_ids, layers)

    def gather_quantized(self, global_ids, layers=None):
        return self.server.gather_quantized(global_ids, layers)

    def write_quantized(self, global_ids, layer_payloads):
        self.server.write_quantized(global_ids, layer_payloads)

    def gather_versioned(self, global_ids, have_versions, layers=None):
        return self.server.gather_if_stale(global_ids, have_versions, layers)

    def transfer_time(self, global_ids, layers, bytes_per_scalar):
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        return self.net.transfer_time(len(global_ids), self.hidden, layers,
                                      bytes_per_scalar=bytes_per_scalar)

    def account(self, global_ids, layers, bytes_per_scalar):
        t = self.transfer_time(global_ids, layers, bytes_per_scalar)
        if t == 0.0:
            return 0.0
        self._log.add(
            bytes=self.net.embedding_bytes(len(global_ids), self.hidden,
                                           layers,
                                           bytes_per_scalar=bytes_per_scalar),
            rpcs=1, embeddings=len(global_ids) * layers, seconds=t)
        return t

    @property
    def shard_logs(self):
        return [self._log]

    @property
    def num_embeddings_stored(self):
        return self.server.num_embeddings_stored

    def memory_bytes(self):
        return self.server.memory_bytes()


class HashShardedWire:
    """Hash placement + per-shard modelled accounting, shared by every
    multi-shard transport (ShardedTransport, TcpTransport) so placement
    and pricing can never diverge between the modelled and real wires.

    Expects ``num_shards``, ``hidden``, ``nets`` (one NetworkModel per
    shard) and ``_logs`` (one TransferLog per shard) on the instance."""

    num_shards: int
    hidden: int
    nets: list[NetworkModel]
    _logs: list[TransferLog]
    #: optional gid → shard override (pull-frequency rebalancing);
    #: ids beyond the map, or mapped to -1, fall back to hashing
    _placement: np.ndarray | None = None

    def shard_of(self, global_ids: np.ndarray) -> np.ndarray:
        """Vertex id → shard: the placement map where one exists
        (Strategy.shard_placement='pull_frequency'), else ``gid % S``."""
        gids = np.asarray(global_ids, np.int64)
        owner = gids % self.num_shards
        pl = self._placement
        if pl is not None and len(pl):
            inb = gids < len(pl)
            mapped = np.where(inb, pl[np.minimum(gids, len(pl) - 1)], -1)
            owner = np.where(mapped >= 0, mapped, owner)
        return owner

    def _split(self, global_ids: np.ndarray):
        """→ [(shard, positions-into-global_ids)] for non-empty shards."""
        global_ids = np.asarray(global_ids)
        owner = self.shard_of(global_ids)
        return [(s, np.nonzero(owner == s)[0])
                for s in range(self.num_shards)
                if np.any(owner == s)]

    def _shard_times(self, global_ids, layers, bytes_per_scalar):
        """[(shard, positions, modelled time)] — the single source both
        transfer_time and account price from."""
        return [(s, pos,
                 self.nets[s].transfer_time(len(pos), self.hidden, layers,
                                            bytes_per_scalar=bytes_per_scalar))
                for s, pos in self._split(global_ids)]

    def transfer_time(self, global_ids, layers, bytes_per_scalar):
        """Shards serve concurrently: wall time is the slowest shard."""
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        return max(t for _, _, t in
                   self._shard_times(global_ids, layers, bytes_per_scalar))

    def account(self, global_ids, layers, bytes_per_scalar):
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        t_max = 0.0
        for s, pos, t in self._shard_times(global_ids, layers,
                                           bytes_per_scalar):
            self._logs[s].add(
                bytes=self.nets[s].embedding_bytes(
                    len(pos), self.hidden, layers,
                    bytes_per_scalar=bytes_per_scalar),
                rpcs=1, embeddings=len(pos) * layers, seconds=t)
            t_max = max(t_max, t)
        return t_max

    @property
    def shard_logs(self):
        return list(self._logs)


class ShardedTransport(HashShardedWire, Transport):
    """Vertex ids hashed across S embedding-server shards.

    ``nets`` gives one NetworkModel per shard (heterogeneous bandwidth);
    a single model (or None) is replicated.  Because every codec is
    row-independent, splitting rows across shards never changes the
    reconstructed values — sharding affects only time/bytes accounting,
    never numerics."""

    def __init__(self, num_layers: int, hidden: int, num_shards: int,
                 nets: list[NetworkModel] | NetworkModel | None = None, *,
                 device_tables: bool = False):
        assert num_shards >= 1
        self.num_layers = num_layers
        self.hidden = hidden
        self.num_shards = num_shards
        self.device_tables = bool(device_tables)
        if nets is None or isinstance(nets, NetworkModel):
            nets = [nets or NetworkModel()] * num_shards
        assert len(nets) == num_shards, "one NetworkModel per shard"
        self.nets = list(nets)
        self.shards = [EmbeddingServer(num_layers, hidden, net,
                                       device_tables=device_tables)
                       for net in self.nets]
        self._logs = [TransferLog() for _ in range(num_shards)]
        #: per-gid gather tally, fed to rebalance_by_pulls.  Off by
        #: default — the trainer flips it on for
        #: Strategy.shard_placement='pull_frequency', so hash-placed
        #: runs never pay the scatter on the gather hot path.
        self.track_pulls = False
        self._pull_counts = np.zeros(0, np.int64)

    def _count_pulls(self, global_ids) -> None:
        if not self.track_pulls:
            return
        gids = np.asarray(global_ids, np.int64)
        if len(gids) == 0:
            return
        need = int(gids.max()) + 1
        if need > len(self._pull_counts):
            grown = np.zeros(max(need, 2 * len(self._pull_counts)),
                             np.int64)
            grown[: len(self._pull_counts)] = self._pull_counts
            self._pull_counts = grown
        np.add.at(self._pull_counts, gids, 1)

    def rebalance_by_pulls(self) -> np.ndarray | None:
        """Re-place rows by observed pull frequency (ROADMAP item).

        Greedy LPT: hottest gid onto the least-loaded shard, load being
        the pull mass already placed there — so two hot boundary
        vertices that hash together stop serializing on one link.
        Rows physically migrate between the shard servers; values are
        untouched (codecs are row-independent), so numerics can never
        change — only the per-shard byte/time ledgers.  Returns the new
        placement map, or None (hash placement stays) when no pulls
        were ever logged."""
        counts = self._pull_counts
        hot = np.nonzero(counts > 0)[0]
        if len(hot) == 0:
            return None
        order = hot[np.argsort(-counts[hot], kind="stable")]
        old_owner = self.shard_of(order)
        placement = np.full(len(counts), -1, np.int32)
        # LPT via a k-element heap: (load, shard) pops break ties on the
        # lowest shard index, matching argmin semantics at O(log k)/gid
        heap = [(0, s) for s in range(self.num_shards)]
        for gid in order:
            load, s = heapq.heappop(heap)
            placement[gid] = s
            heapq.heappush(heap, (load + int(counts[gid]), s))
        new_owner = placement[order]
        self._placement = placement
        for s_old in range(self.num_shards):
            moved = order[(old_owner == s_old) & (new_owner != s_old)]
            if len(moved) == 0:
                continue
            vals = self.shards[s_old].gather(moved)
            self.shards[s_old].forget(moved)
            for s_new, pos in self._split(moved):
                self.shards[s_new].register(moved[pos])
                self.shards[s_new].write(moved[pos],
                                         [v[pos] for v in vals])
        return placement

    def register(self, global_ids):
        for s, pos in self._split(global_ids):
            self.shards[s].register(np.asarray(global_ids)[pos])

    def write(self, global_ids, layer_values):
        global_ids = np.asarray(global_ids)
        for s, pos in self._split(global_ids):
            self.shards[s].write(global_ids[pos],
                                 [np.asarray(v)[pos] for v in layer_values])

    def gather(self, global_ids, layers=None):
        self._count_pulls(global_ids)
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        out = [np.zeros((len(global_ids), self.hidden), np.float32)
               for _ in sel]
        for s, pos in self._split(global_ids):
            part = self.shards[s].gather(global_ids[pos], sel)
            for o, p in zip(out, part):
                o[pos] = p
        return out

    def gather_quantized(self, global_ids, layers=None):
        """Per-shard fused gather+encode, recombined in id order.  The
        codec is row-independent, so quantize-then-combine equals
        combine-then-quantize — sharding can't change the wire values."""
        self._count_pulls(global_ids)
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        n = len(global_ids)
        parts = self._split(global_ids)
        if self.device_tables:
            import jax.numpy as jnp
            vs = [jnp.zeros((n, self.hidden), jnp.int8) for _ in sel]
            ss = [jnp.zeros((n, 1), jnp.float32) for _ in sel]
            for s, pos in parts:
                pj = jnp.asarray(pos)
                for j, (v, sc) in enumerate(
                        self.shards[s].gather_quantized(global_ids[pos],
                                                        sel)):
                    vs[j] = vs[j].at[pj].set(v)
                    ss[j] = ss[j].at[pj].set(sc)
            return list(zip(vs, ss))
        vs = [np.zeros((n, self.hidden), np.int8) for _ in sel]
        ss = [np.zeros((n, 1), np.float32) for _ in sel]
        for s, pos in parts:
            for j, (v, sc) in enumerate(
                    self.shards[s].gather_quantized(global_ids[pos], sel)):
                vs[j][pos] = np.asarray(v)
                ss[j][pos] = np.asarray(sc)
        return list(zip(vs, ss))

    def write_quantized(self, global_ids, layer_payloads):
        global_ids = np.asarray(global_ids)
        for s, pos in self._split(global_ids):
            self.shards[s].write_quantized(
                global_ids[pos],
                [(np.asarray(v)[pos], np.asarray(sc)[pos])
                 for v, sc in layer_payloads])

    def gather_versioned(self, global_ids, have_versions, layers=None):
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        have = np.asarray(have_versions, np.int64)
        ver = np.zeros(len(global_ids), np.int64)
        stale_parts, val_parts = [], []
        for s, pos in self._split(global_ids):
            v, st, vals = self.shards[s].gather_if_stale(
                global_ids[pos], have[pos], sel)
            ver[pos] = v
            stale_parts.append(pos[st])
            val_parts.append(vals)
        if not stale_parts:
            return (ver, np.zeros(0, np.int64),
                    [np.zeros((0, self.hidden), np.float32) for _ in sel])
        stale = np.concatenate(stale_parts).astype(np.int64)
        order = np.argsort(stale, kind="stable")
        vals = [np.concatenate([vp[j] for vp in val_parts], axis=0)[order]
                for j in range(len(sel))]
        return ver, stale[order], vals

    @property
    def num_embeddings_stored(self):
        return sum(s.num_embeddings_stored for s in self.shards)

    def memory_bytes(self):
        return sum(s.memory_bytes() for s in self.shards)


def make_transport(num_layers: int, hidden: int, *, kind: str = "auto",
                   num_shards: int = 1,
                   nets: list[NetworkModel] | NetworkModel | None = None,
                   addrs=None, codec: str = "fp32",
                   device_tables: bool = False) -> Transport:
    """Factory the trainer uses.

    ``kind`` selects the wire: ``"inprocess"`` (single modelled link,
    seed topology), ``"sharded"`` (hashed in-process shards with
    per-shard modelled links), or ``"tcp"`` (live embedding-server
    shards at ``addrs``, speaking the repro.exchange.wire protocol with
    ``codec`` payloads).  The default ``"auto"`` keeps the historical
    inference: addresses given → tcp, ``num_shards`` > 1 → sharded,
    else in-process.

    ``device_tables=True`` puts the in-process servers' tables on
    device (jax Arrays) and routes int8 pulls/pushes through the fused
    kernels — bit-identical values, no host staging.  A TCP server
    opts in on its own side (``embed_server --device-tables``), so the
    flag is rejected for ``kind='tcp'``.
    """
    if kind == "auto":
        kind = "tcp" if addrs else \
            ("sharded" if num_shards > 1 else "inprocess")
    if kind == "tcp":
        from .socket_transport import TcpTransport   # lazy: socket machinery
        if device_tables:
            raise ValueError("device_tables is a server-side choice for "
                             "kind='tcp' — start the listener with "
                             "embed_server --device-tables instead")
        if not addrs:
            raise ValueError("kind='tcp' needs addrs=[(host, port), ...] "
                             "— one embed_server listener per shard")
        if num_shards > 1 and len(addrs) != num_shards:
            raise ValueError(f"num_shards={num_shards} but {len(addrs)} "
                             "tcp addresses given")
        return TcpTransport(num_layers, hidden, addrs, codec=codec,
                            nets=nets)
    if addrs:
        raise ValueError(f"addrs only apply to kind='tcp', got {kind!r}")
    if kind == "inprocess":
        if num_shards > 1:
            raise ValueError("kind='inprocess' is single-shard; use "
                             "kind='sharded' for num_shards > 1")
        if isinstance(nets, list):
            assert len(nets) == 1, \
                f"{len(nets)} NetworkModels for a single-shard transport"
            nets = nets[0]
        return InProcessTransport(num_layers, hidden, nets,
                                  device_tables=device_tables)
    if kind == "sharded":
        return ShardedTransport(num_layers, hidden, num_shards, nets,
                                device_tables=device_tables)
    raise ValueError(f"unknown transport kind {kind!r}; "
                     "expected inprocess | sharded | tcp")
