"""Transports: where remote-embedding bytes actually travel.

A :class:`Transport` separates the *storage* of shared embeddings (the
EmbeddingServer tables) from the *wire model* that charges for moving
them.  Two implementations:

  InProcessTransport — one embedding server behind one NetworkModel;
      exactly the seed topology (§5.1's single Redis instance).
  ShardedTransport   — vertex ids hashed across S embedding-server
      shards, each with its own NetworkModel (heterogeneous links are a
      list of models) and its own TransferLog.  Shards serve in
      parallel, so modelled wall time is the max over shards while
      bytes/RPCs accumulate per shard.

Time accounting is split into pure ``*_time`` queries (used when a push
is planned during training but applied later — §4.2 overlap keeps the
server static within a round) and ``account_*`` calls that also record
into the shard TransferLogs.
"""

from __future__ import annotations

import abc

import numpy as np

from repro.core.cost_model import NetworkModel, TransferLog
from repro.core.embedding_server import EmbeddingServer


class Transport(abc.ABC):
    """Storage + modelled wire for one federated deployment."""

    num_layers: int
    hidden: int

    # -- storage -----------------------------------------------------------

    @abc.abstractmethod
    def register(self, global_ids: np.ndarray) -> None: ...

    @abc.abstractmethod
    def write(self, global_ids: np.ndarray,
              layer_values: list[np.ndarray]) -> None:
        """Raw store of decoded fp32 rows (no accounting)."""

    @abc.abstractmethod
    def gather(self, global_ids: np.ndarray,
               layers: list[int] | None = None) -> list[np.ndarray]:
        """Raw read (no accounting), original id order."""

    # -- modelled wire -----------------------------------------------------

    @abc.abstractmethod
    def transfer_time(self, global_ids: np.ndarray, layers: int,
                      bytes_per_scalar: float) -> float:
        """Pure time query for one batched transfer (no logging)."""

    @abc.abstractmethod
    def account(self, global_ids: np.ndarray, layers: int,
                bytes_per_scalar: float) -> float:
        """Record one batched transfer in the shard logs, return time."""

    # -- telemetry ---------------------------------------------------------

    @property
    @abc.abstractmethod
    def shard_logs(self) -> list[TransferLog]: ...

    @property
    def log(self) -> TransferLog:
        """Read-only aggregate over all shard logs — a fresh snapshot
        each access, so writes to it are discarded.  Record traffic via
        :meth:`account`; per-shard state lives in :attr:`shard_logs`."""
        total = TransferLog()
        for lg in self.shard_logs:
            total.add(bytes=lg.bytes, rpcs=lg.rpcs,
                      embeddings=lg.embeddings, seconds=lg.seconds)
        return total

    @property
    @abc.abstractmethod
    def num_embeddings_stored(self) -> int: ...

    @abc.abstractmethod
    def memory_bytes(self) -> int: ...


class InProcessTransport(Transport):
    """Single embedding server behind a single link (seed behavior)."""

    num_shards = 1

    def __init__(self, num_layers: int, hidden: int,
                 net: NetworkModel | None = None):
        self.num_layers = num_layers
        self.hidden = hidden
        self.net = net or NetworkModel()
        self.server = EmbeddingServer(num_layers, hidden, self.net)
        self._log = TransferLog()

    def register(self, global_ids):
        self.server.register(global_ids)

    def write(self, global_ids, layer_values):
        self.server.write(global_ids, layer_values)

    def gather(self, global_ids, layers=None):
        return self.server.gather(global_ids, layers)

    def transfer_time(self, global_ids, layers, bytes_per_scalar):
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        return self.net.transfer_time(len(global_ids), self.hidden, layers,
                                      bytes_per_scalar=bytes_per_scalar)

    def account(self, global_ids, layers, bytes_per_scalar):
        t = self.transfer_time(global_ids, layers, bytes_per_scalar)
        if t == 0.0:
            return 0.0
        self._log.add(
            bytes=self.net.embedding_bytes(len(global_ids), self.hidden,
                                           layers,
                                           bytes_per_scalar=bytes_per_scalar),
            rpcs=1, embeddings=len(global_ids) * layers, seconds=t)
        return t

    @property
    def shard_logs(self):
        return [self._log]

    @property
    def num_embeddings_stored(self):
        return self.server.num_embeddings_stored

    def memory_bytes(self):
        return self.server.memory_bytes()


class ShardedTransport(Transport):
    """Vertex ids hashed across S embedding-server shards.

    ``nets`` gives one NetworkModel per shard (heterogeneous bandwidth);
    a single model (or None) is replicated.  Because every codec is
    row-independent, splitting rows across shards never changes the
    reconstructed values — sharding affects only time/bytes accounting,
    never numerics."""

    def __init__(self, num_layers: int, hidden: int, num_shards: int,
                 nets: list[NetworkModel] | NetworkModel | None = None):
        assert num_shards >= 1
        self.num_layers = num_layers
        self.hidden = hidden
        self.num_shards = num_shards
        if nets is None or isinstance(nets, NetworkModel):
            nets = [nets or NetworkModel()] * num_shards
        assert len(nets) == num_shards, "one NetworkModel per shard"
        self.nets = list(nets)
        self.shards = [EmbeddingServer(num_layers, hidden, net)
                       for net in self.nets]
        self._logs = [TransferLog() for _ in range(num_shards)]

    def shard_of(self, global_ids: np.ndarray) -> np.ndarray:
        """Hash placement: vertex id → shard."""
        return np.asarray(global_ids, np.int64) % self.num_shards

    def _split(self, global_ids: np.ndarray):
        """→ [(shard, positions-into-global_ids)] for non-empty shards."""
        global_ids = np.asarray(global_ids)
        owner = self.shard_of(global_ids)
        return [(s, np.nonzero(owner == s)[0])
                for s in range(self.num_shards)
                if np.any(owner == s)]

    def register(self, global_ids):
        for s, pos in self._split(global_ids):
            self.shards[s].register(np.asarray(global_ids)[pos])

    def write(self, global_ids, layer_values):
        global_ids = np.asarray(global_ids)
        for s, pos in self._split(global_ids):
            self.shards[s].write(global_ids[pos],
                                 [np.asarray(v)[pos] for v in layer_values])

    def gather(self, global_ids, layers=None):
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        out = [np.zeros((len(global_ids), self.hidden), np.float32)
               for _ in sel]
        for s, pos in self._split(global_ids):
            part = self.shards[s].gather(global_ids[pos], sel)
            for o, p in zip(out, part):
                o[pos] = p
        return out

    def _shard_times(self, global_ids, layers, bytes_per_scalar):
        """[(shard, positions, modelled time)] — the single source both
        transfer_time and account price from."""
        return [(s, pos,
                 self.nets[s].transfer_time(len(pos), self.hidden, layers,
                                            bytes_per_scalar=bytes_per_scalar))
                for s, pos in self._split(global_ids)]

    def transfer_time(self, global_ids, layers, bytes_per_scalar):
        """Shards serve concurrently: wall time is the slowest shard."""
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        return max(t for _, _, t in
                   self._shard_times(global_ids, layers, bytes_per_scalar))

    def account(self, global_ids, layers, bytes_per_scalar):
        if len(global_ids) == 0 or layers == 0:
            return 0.0
        t_max = 0.0
        for s, pos, t in self._shard_times(global_ids, layers,
                                           bytes_per_scalar):
            self._logs[s].add(
                bytes=self.nets[s].embedding_bytes(
                    len(pos), self.hidden, layers,
                    bytes_per_scalar=bytes_per_scalar),
                rpcs=1, embeddings=len(pos) * layers, seconds=t)
            t_max = max(t_max, t)
        return t_max

    @property
    def shard_logs(self):
        return list(self._logs)

    @property
    def num_embeddings_stored(self):
        return sum(s.num_embeddings_stored for s in self.shards)

    def memory_bytes(self):
        return sum(s.memory_bytes() for s in self.shards)


def make_transport(num_layers: int, hidden: int, *, num_shards: int = 1,
                   nets: list[NetworkModel] | NetworkModel | None = None
                   ) -> Transport:
    """Factory the trainer uses: 1 shard → seed topology, else hashed."""
    if num_shards <= 1:
        if isinstance(nets, list):
            assert len(nets) == 1, \
                f"{len(nets)} NetworkModels for a single-shard transport"
            nets = nets[0]
        return InProcessTransport(num_layers, hidden, nets)
    return ShardedTransport(num_layers, hidden, num_shards, nets)
