"""Remote-embedding exchange subsystem.

OptimES's central observation (§4, §5.4) is that remote-embedding
traffic dominates federated GNN round time.  The seed hard-wired the
trainer to one in-process embedding server speaking one wire format
(fp32, full-table push); this package makes the exchange pluggable along
the three axes communication-layer systems win or lose on:

  codec.py     — wire codecs (fp32 / fp16 / per-row symmetric int8 via
                 the Pallas quantize kernel).  Extends §5.1's "get/set of
                 raw embedding vectors" with lossy wire formats whose
                 byte accounting flows into the §5.4 cost model.
  delta.py     — τ-thresholded delta pushes: clients shadow their last
                 pushed rows and re-push only rows that moved.  A
                 convergence-aware sharpening of the §3.2.2 push phase
                 (and orthogonal to §4.1 pruning, which shrinks the push
                 *set* rather than the per-round *selection*).
  transport.py — Transport ABC with InProcessTransport (the paper's
                 single Redis instance, §5.1) and ShardedTransport
                 (vertex ids hashed across S embedding-server shards
                 with per-shard NetworkModels and TransferLogs — the
                 scale-out topology §6's future work gestures at).
  wire.py      — length-prefixed binary protocol: codec payload blocks
                 (fp32/fp16/int8+scales) framed exactly as the bytes
                 NetworkModel.embedding_bytes charges for.
  socket_transport.py — TcpTransport: the wire protocol over live
                 repro.launch.embed_server shards, with connection
                 pooling, pipelined multi-shard RPCs, and per-RPC
                 measured-vs-modelled samples for calibration
                 (benchmarks/bench_wire.py).
  client.py    — ExchangeClient: the per-client facade composing the
                 three axes; every pull / push / prefetch / dynamic-pull
                 of the trainer (§3.2.2, §4.2, §4.3) routes through it.

Knobs surface on :class:`repro.core.strategies.Strategy` as ``codec``,
``delta_threshold``, and ``num_server_shards``; benchmarks/bench_exchange.py
sweeps the cross-product against the fp32 full-push baseline.
"""

from .codec import (Fp16Codec, Fp32Codec, Int8Codec, WireCodec,
                    available_codecs, decode_leaves, encode_leaves,
                    get_codec)
from .client import ExchangeClient, PushPlan
from .delta import DeltaTracker, ErrorFeedback, LeafErrorFeedback
from .transport import (InProcessTransport, ShardedTransport, Transport,
                        make_transport)

# socket machinery resolves lazily (PEP 562), matching make_transport's
# lazy import: a modelled-only run never pays for it.
_SOCKET_EXPORTS = ("TcpTransport", "RpcSample", "parse_address")

__all__ = [
    "WireCodec", "Fp32Codec", "Fp16Codec", "Int8Codec", "get_codec",
    "available_codecs", "encode_leaves", "decode_leaves",
    "DeltaTracker", "ErrorFeedback", "LeafErrorFeedback", "Transport",
    "InProcessTransport",
    "ShardedTransport", "TcpTransport", "RpcSample", "parse_address",
    "make_transport", "ExchangeClient", "PushPlan",
]


def __getattr__(name):
    if name in _SOCKET_EXPORTS:
        from . import socket_transport
        return getattr(socket_transport, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
