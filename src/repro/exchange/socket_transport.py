"""TcpTransport: a Transport whose bytes actually cross a socket.

Speaks the :mod:`repro.exchange.wire` protocol against one
``repro.launch.embed_server`` listener per shard.  Vertex ids hash
across shards exactly like :class:`ShardedTransport` (``gid % S``), and
every codec is row-independent, so the stored state — and therefore
training numerics — is bit-identical to the in-process transports.

Connection pooling: one persistent socket per shard, opened lazily and
reopened on failure.  Multi-shard RPCs are *pipelined*: all shard
request frames are written before any response is read, so shards serve
concurrently just like the modelled ``max``-over-shards wall time
assumes.

Two ledgers per shard, deliberately separate:

  ``shard_logs``  — the *modelled* ledger, written by :meth:`account`
      with NetworkModel prices.  Identical semantics to the in-process
      transports, so trainer timelines stay comparable across
      transports.
  ``wire_logs``   — the *measured* ledger: every real RPC records its
      payload bytes plus both its measured wall time
      (``measured_seconds``) and the NetworkModel's modelled time for
      the same payload (``seconds``).

Per-RPC granularity lands in :attr:`rpc_samples`
(:class:`RpcSample`), which ``benchmarks/bench_wire.py`` feeds to
:func:`repro.core.cost_model.fit_network_model` to calibrate
(bandwidth, RPC overhead, per-embedding overhead) on live loopback
measurements.  Only ``fanout == 1`` samples carry clean per-RPC
timing — see :class:`RpcSample`.
"""

from __future__ import annotations

import dataclasses
import socket
import time

import numpy as np

from repro.core.cost_model import NetworkModel, TransferLog
from repro.obsv.metrics import SampleWindow

from . import wire
from .codec import WireCodec, get_codec
from .transport import HashShardedWire, Transport


@dataclasses.dataclass(frozen=True)
class RpcSample:
    """One real RPC: what moved, what it cost, what the model says.

    ``measured_s`` is clean per-RPC time only when ``fanout == 1``: in
    a pipelined multi-shard fan-out, responses are read in shard order,
    so a later shard's clock includes earlier shards' send/read time.
    Calibration fits (benchmarks/bench_wire.py) must use fanout-1
    samples; multi-shard samples still bound the fan-out wall time."""
    op: str                    # register | write | gather
    shard: int
    fanout: int                # shards in this RPC's pipelined fan-out
    n_rows: int
    layers: int
    payload_bytes: int         # codec payload only (== embedding_bytes)
    frame_bytes: int           # full frames incl. headers/gids, both ways
    measured_s: float          # wall time, send-start → response-read
    modelled_s: float          # NetworkModel.transfer_time for the payload


def parse_address(addr) -> tuple[str, int]:
    """('host', port) | 'host:port' | ':port' → ('host', port)."""
    if isinstance(addr, (tuple, list)):
        host, port = addr
        return (host or "127.0.0.1", int(port))
    host, _, port = str(addr).rpartition(":")
    return (host or "127.0.0.1", int(port))


#: rpc_samples window: enough for any calibration sweep, bounded so a
#: long training run cannot grow memory linearly with rounds.
MAX_RPC_SAMPLES = 65536


class TcpTransport(HashShardedWire, Transport):
    """Embedding storage behind live TCP embedding-server shards."""

    wire_is_real = True

    def __init__(self, num_layers: int, hidden: int, addrs,
                 *, codec: WireCodec | str = "fp32",
                 nets: list[NetworkModel] | NetworkModel | None = None,
                 connect_timeout: float = 5.0):
        if not addrs:
            raise ValueError("TcpTransport needs at least one "
                             "(host, port) shard address")
        self.num_layers = num_layers
        self.hidden = hidden
        self.addrs = [parse_address(a) for a in addrs]
        self.num_shards = len(self.addrs)
        self.codec = get_codec(codec)
        if nets is None or isinstance(nets, NetworkModel):
            nets = [nets or NetworkModel()] * self.num_shards
        assert len(nets) == self.num_shards, "one NetworkModel per shard"
        self.nets = list(nets)
        self.connect_timeout = connect_timeout
        self._socks: list[socket.socket | None] = [None] * self.num_shards
        self._logs = [TransferLog() for _ in range(self.num_shards)]
        self._wire_logs = [TransferLog() for _ in range(self.num_shards)]
        # per-transport sample window whose observe() also lands each
        # sample's latency/bytes in the process-global per-op metrics
        # histograms (exchange.latency_s.<op> / exchange.bytes.<op>):
        # fit_network_model calibration iterates the window, OP_METRICS
        # scrapes read the histograms — one bookkeeping point for both
        self.rpc_samples: SampleWindow = SampleWindow(
            "exchange", MAX_RPC_SAMPLES)
        self._validate_servers()

    def _validate_servers(self) -> None:
        """Fail fast on a (num_layers, hidden) mismatch instead of a
        confusing payload-size error mid-round."""
        for s, st in enumerate(self._stats()):
            if (st["num_layers"], st["hidden"]) != (self.num_layers,
                                                    self.hidden):
                raise ValueError(
                    f"embed-server shard {s} at "
                    f"{self.addrs[s][0]}:{self.addrs[s][1]} serves "
                    f"L={st['num_layers']}, hidden={st['hidden']} but "
                    f"this transport expects L={self.num_layers}, "
                    f"hidden={self.hidden} — relaunch the server with "
                    "matching --num-layers/--hidden")

    # -- connection pool ---------------------------------------------------

    def _conn(self, s: int) -> socket.socket:
        sock = self._socks[s]
        if sock is not None:
            return sock
        sock = socket.create_connection(self.addrs[s],
                                        timeout=self.connect_timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)
        self._socks[s] = sock
        return sock

    def _drop(self, s: int) -> None:
        if self._socks[s] is not None:
            try:
                self._socks[s].close()
            except OSError:
                pass
            self._socks[s] = None

    def close(self) -> None:
        for s in range(self.num_shards):
            self._drop(s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def shutdown_servers(self) -> None:
        """Ask every shard listener to exit (tests / bench teardown)."""
        for s in range(self.num_shards):
            try:
                wire.parse_response(self._roundtrip(s, wire.build_shutdown()))
            except (ConnectionError, OSError, RuntimeError):
                pass
        self.close()

    # -- framing -----------------------------------------------------------

    def _roundtrip(self, s: int, body: bytes) -> bytes:
        """Single-shard RPC with one transparent reconnect: a pooled
        socket may have died since the last round."""
        for attempt in (0, 1):
            try:
                sock = self._conn(s)
                wire.send_frame(sock, body)
                resp = wire.recv_frame(sock)
                if resp is None:
                    raise ConnectionError("server closed connection")
                return resp
            except (ConnectionError, OSError):
                self._drop(s)
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def _rpc_many(self, reqs: list[tuple[int, bytes]]
                  ) -> list[tuple[bytes, float]]:
        """Pipelined fan-out: write every shard's request frame, then
        read responses in order.  Returns [(response body, measured s)]
        where each shard's clock runs send-start → its response read.

        Failure discipline: on ANY send/recv error, every socket in
        this fan-out is dropped — a pooled socket with an unread
        in-flight response would satisfy the *next* RPC with stale
        bytes.  The whole fan-out is then retried once from scratch:
        register/write/gather are idempotent, so a shard that already
        served the first attempt just serves it again."""
        for attempt in (0, 1):
            try:
                return self._rpc_many_once(reqs)
            except (ConnectionError, OSError):
                for s, _ in reqs:
                    self._drop(s)
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def _rpc_many_once(self, reqs: list[tuple[int, bytes]]
                       ) -> list[tuple[bytes, float]]:
        t0: dict[int, float] = {}
        for s, body in reqs:
            t0[s] = time.perf_counter()
            wire.send_frame(self._conn(s), body)
        out = []
        for s, body in reqs:
            resp = wire.recv_frame(self._socks[s])
            if resp is None:
                raise ConnectionError(
                    f"embed-server shard {s} {self.addrs[s]} closed "
                    "connection")
            out.append((resp, time.perf_counter() - t0[s]))
        return out

    # shard placement + modelled transfer_time/account/shard_logs are
    # inherited from HashShardedWire — identical by construction to
    # ShardedTransport, which is what keeps TCP bit-compatible.

    # -- ledgers -----------------------------------------------------------

    def _record(self, op: str, s: int, n: int, layers: int,
                payload_bytes: int, frame_bytes: int,
                measured_s: float, fanout: int = 1) -> None:
        if op == "register":
            # ids only, no embedding payload: the model folds this into
            # per-RPC overhead plus raw id bytes on the wire.
            modelled = self.nets[s].rpc_overhead_s \
                + 8 * n / self.nets[s].bandwidth_bytes_per_s
        else:
            modelled = self.nets[s].transfer_time(
                n, self.hidden, layers,
                bytes_per_scalar=self.codec.bytes_per_scalar(self.hidden))
        self._wire_logs[s].add(bytes=payload_bytes, rpcs=1,
                               embeddings=n * layers, seconds=modelled,
                               measured_seconds=measured_s)
        self.rpc_samples.observe(RpcSample(
            op=op, shard=s, fanout=fanout, n_rows=n, layers=layers,
            payload_bytes=payload_bytes, frame_bytes=frame_bytes,
            measured_s=measured_s, modelled_s=modelled))

    # -- storage surface ---------------------------------------------------

    def register(self, global_ids):
        global_ids = np.asarray(global_ids)
        if len(global_ids) == 0:
            return
        parts = self._split(global_ids)
        reqs = [(s, wire.build_register(global_ids[pos])) for s, pos in parts]
        resps = self._rpc_many(reqs)
        for (s, pos), (_, body), (resp, dt) in zip(parts, reqs, resps):
            wire.parse_response(resp)
            self._record("register", s, len(pos), 0, 0,
                         wire.frame_nbytes(len(body))
                         + wire.frame_nbytes(len(resp)), dt,
                         fanout=len(parts))

    def write(self, global_ids, layer_values):
        global_ids = np.asarray(global_ids)
        if len(global_ids) == 0:
            return
        name = self.codec.name
        parts = self._split(global_ids)
        reqs, payloads = [], []
        for s, pos in parts:
            blocks = [wire.encode_block(
                name, self.codec.encode(np.asarray(v, np.float32)[pos]))
                for v in layer_values]
            payloads.append(sum(len(b) for b in blocks))
            reqs.append((s, wire.build_write(name, global_ids[pos], blocks)))
        resps = self._rpc_many(reqs)
        for (s, pos), pay, (_, body), (resp, dt) in zip(parts, payloads,
                                                        reqs, resps):
            wire.parse_response(resp)
            self._record("write", s, len(pos), len(layer_values), pay,
                         wire.frame_nbytes(len(body))
                         + wire.frame_nbytes(len(resp)), dt,
                         fanout=len(parts))

    def gather(self, global_ids, layers=None):
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        out = [np.zeros((len(global_ids), self.hidden), np.float32)
               for _ in sel]
        if len(global_ids) == 0 or not sel:
            return out
        name = self.codec.name
        parts = self._split(global_ids)
        reqs = [(s, wire.build_gather(name, global_ids[pos], sel))
                for s, pos in parts]
        resps = self._rpc_many(reqs)
        for (s, pos), (_, body), (resp, dt) in zip(parts, reqs, resps):
            payload = wire.parse_response(resp)
            n = len(pos)
            block = wire.payload_nbytes(name, n, self.hidden)
            if len(payload) != block * len(sel):
                raise ConnectionError(
                    f"gather reply from shard {s} is {len(payload)} B, "
                    f"expected {block * len(sel)} B")
            for i in range(len(sel)):
                part = self.codec.decode(wire.decode_block(
                    name, payload[i * block:(i + 1) * block],
                    n, self.hidden))
                out[i][pos] = np.asarray(part, np.float32)
            self._record("gather", s, n, len(sel), len(payload),
                         wire.frame_nbytes(len(body))
                         + wire.frame_nbytes(len(resp)), dt,
                         fanout=len(parts))
        return out

    def gather_versioned(self, global_ids, have_versions, layers=None):
        sel = list(range(1, self.num_layers)) if layers is None \
            else list(layers)
        global_ids = np.asarray(global_ids)
        have = np.asarray(have_versions, np.int64)
        empty = [np.zeros((0, self.hidden), np.float32) for _ in sel]
        if len(global_ids) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64), empty
        name = self.codec.name
        parts = self._split(global_ids)
        reqs = [(s, wire.build_vgather(name, global_ids[pos], have[pos], sel))
                for s, pos in parts]
        resps = self._rpc_many(reqs)
        ver = np.zeros(len(global_ids), np.int64)
        stale_parts, val_parts = [], []
        for (s, pos), (_, body), (resp, dt) in zip(parts, reqs, resps):
            payload = wire.parse_response(resp)
            n = len(pos)
            v = np.frombuffer(payload, np.int64, n).copy()
            ver[pos] = v
            # both ends recompute the stale set from the version vectors
            st = np.nonzero(v != have[pos])[0]
            block = wire.payload_nbytes(name, len(st), self.hidden)
            blob = payload[n * 8:]
            if len(blob) != block * len(sel):
                raise ConnectionError(
                    f"vgather reply from shard {s} carries {len(blob)} B "
                    f"of rows, expected {block * len(sel)} B "
                    f"({len(st)} stale rows × {len(sel)} layers)")
            vals = [np.asarray(self.codec.decode(wire.decode_block(
                        name, blob[i * block:(i + 1) * block],
                        len(st), self.hidden)), np.float32)
                    for i in range(len(sel))]
            stale_parts.append(pos[st])
            val_parts.append(vals)
            self._record("vgather", s, len(st), len(sel), len(blob),
                         wire.frame_nbytes(len(body))
                         + wire.frame_nbytes(len(resp)), dt,
                         fanout=len(parts))
        stale = np.concatenate(stale_parts).astype(np.int64)
        order = np.argsort(stale, kind="stable")
        vals = [np.concatenate([vp[j] for vp in val_parts], axis=0)[order]
                for j in range(len(sel))]
        return ver, stale[order], vals

    # -- telemetry ---------------------------------------------------------

    @property
    def wire_logs(self) -> list[TransferLog]:
        """Measured per-shard ledgers (real RPCs; payload bytes only)."""
        return list(self._wire_logs)

    @property
    def wire_log(self) -> TransferLog:
        total = TransferLog()
        for lg in self._wire_logs:
            total.add(bytes=lg.bytes, rpcs=lg.rpcs,
                      embeddings=lg.embeddings, seconds=lg.seconds,
                      measured_seconds=lg.measured_seconds)
        return total

    def _stats(self) -> list[dict]:
        out = []
        for s in range(self.num_shards):
            payload = wire.parse_response(
                self._roundtrip(s, wire.build_stats()))
            out.append(wire.parse_stats_payload(bytes(payload)))
        return out

    @property
    def num_embeddings_stored(self) -> int:
        return sum(st["rows"] * (st["num_layers"] - 1)
                   for st in self._stats())

    def memory_bytes(self) -> int:
        return sum(st["memory_bytes"] for st in self._stats())
