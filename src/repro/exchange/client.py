"""ExchangeClient: the per-client facade over codec × delta × transport.

Every remote-embedding interaction of the federated trainer routes
through here:

  peek          — cache-fill numerics: the values this client would see
                  after one wire crossing (codec roundtrip, no charge)
  pull_cost     — charge one batched upfront GET (§3.2.2 pull phase)
  dynamic_pull  — charge one on-demand per-minibatch GET (§4.3)
  plan_push     — delta-filter + encode the push rows and price the SET
                  without applying it (the server stays static within a
                  round; §4.2 overlap plans the push mid-round)
  apply_push    — commit a planned push: store decoded rows, record log

The split between plan and apply mirrors the seed's two-phase push (all
clients pull before anyone's push lands) while letting the plan's
modelled transfer time feed the §4.2 overlap timeline.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .codec import WireCodec, get_codec
from .delta import DeltaTracker, ErrorFeedback
from .transport import Transport


@dataclasses.dataclass
class PushPlan:
    """A priced, not-yet-applied push.  Abandoning a plan has no side
    effects: the delta shadow and error-feedback residuals are only
    refreshed when the plan is applied."""
    global_ids: np.ndarray            # delta-selected rows
    layer_values: list[np.ndarray]    # decoded fp32 (post codec roundtrip)
    raw_values: list[np.ndarray]      # pre-codec fp32 (shadow refresh);
                                      # EF-compensated when EF is on
    transfer_time: float
    n_selected: int
    n_total: int
    # real-wire plans carry raw rows in layer_values (the socket does
    # the encoding), so the decoded view EF needs rides separately
    ef_decoded: list[np.ndarray] | None = None
    # device-table transports apply the push in wire form (fused
    # decode+scatter): the encoded payload rides the plan so apply_push
    # never re-encodes; decoding it equals layer_values bit-exactly
    payloads: list | None = None


class ExchangeClient:
    def __init__(self, transport: Transport, codec: WireCodec | str = "fp32",
                 *, delta_threshold: float | None = None,
                 error_feedback: bool = False):
        self.transport = transport
        self.codec = get_codec(codec)
        if transport.wire_is_real:
            t_codec = getattr(transport, "codec", None)
            if t_codec is not None and t_codec.name != self.codec.name:
                raise ValueError(
                    f"client codec {self.codec.name!r} != real-wire "
                    f"transport codec {t_codec.name!r}: the wire would "
                    "carry different bytes than the client accounts for")
        self.hidden = transport.hidden
        self.shared_layers = transport.num_layers - 1
        self.delta = None if delta_threshold is None else DeltaTracker(
            delta_threshold, self.shared_layers, self.hidden)
        self.ef = ErrorFeedback(self.shared_layers, self.hidden) \
            if error_feedback else None

    @property
    def bytes_per_scalar(self) -> float:
        return self.codec.bytes_per_scalar(self.hidden)

    def register(self, global_ids: np.ndarray) -> None:
        self.transport.register(global_ids)

    def _fused_int8(self) -> bool:
        """True when pulls/pushes should ride the fused quantized
        surface: int8 codec over a modelled transport whose tables live
        on device (gather+encode / decode+scatter as one program)."""
        return (self.codec.name == "int8"
                and not self.transport.wire_is_real
                and self.transport.device_tables)

    # -- pull side ---------------------------------------------------------

    def peek(self, global_ids: np.ndarray,
             layers: list[int] | None = None) -> list[np.ndarray]:
        """Table rows as seen after one wire crossing, no wire charge
        (timing is accounted per-strategy by pull_cost/dynamic_pull).
        Modelled transports return raw table rows, so the crossing is
        simulated with a codec roundtrip here; a real-wire transport
        (TcpTransport) already codec-encoded the gather on the socket,
        and a second roundtrip would double-quantize.  Device-table
        transports with an int8 codec serve the crossing fused
        (gather+encode on the resident table, decode on device) —
        bit-identical values, converted to host exactly once here."""
        if self._fused_int8():
            payloads = self.transport.gather_quantized(global_ids, layers)
            return [np.asarray(self.codec.decode_dev(p), np.float32)
                    for p in payloads]
        raw = self.transport.gather(global_ids, layers)
        if self.transport.wire_is_real:
            return [np.asarray(v, np.float32) for v in raw]
        return [self.codec.roundtrip(v) for v in raw]

    def pull(self, global_ids: np.ndarray, layers: list[int] | None = None
             ) -> tuple[list[np.ndarray], float]:
        """Batched GET: values after the wire + modelled time."""
        vals = self.peek(global_ids, layers)
        return vals, self.pull_cost(global_ids, len(vals))

    def pull_cost(self, global_ids: np.ndarray,
                  layers: int | None = None) -> float:
        """Charge one batched GET of ``layers`` tables (default all)."""
        layers = self.shared_layers if layers is None else layers
        return self.transport.account(global_ids, layers,
                                      self.bytes_per_scalar)

    def dynamic_pull(self, global_ids: np.ndarray) -> float:
        """Charge one on-demand miss RPC (one table row per id — ids may
        repeat across layers)."""
        return self.transport.account(global_ids, 1, self.bytes_per_scalar)

    def pull_versioned(
        self, global_ids: np.ndarray, have_versions: np.ndarray,
        layers: list[int] | None = None,
    ) -> tuple[np.ndarray, np.ndarray, list[np.ndarray], float]:
        """Conditional GET for serving-side caches: row values cross the
        wire only where the server's version differs from
        ``have_versions`` (-1 = never seen).  Only those rows are
        charged.  Returns ``(versions, stale_pos, stale_values, time)``
        with stale_values post-wire (codec roundtrip on modelled
        transports, same discipline as :meth:`peek`)."""
        ver, stale, vals = self.transport.gather_versioned(
            global_ids, have_versions, layers)
        if not self.transport.wire_is_real:
            vals = [self.codec.roundtrip(v) for v in vals]
        else:
            vals = [np.asarray(v, np.float32) for v in vals]
        n_layers = len(vals) if layers is None else len(list(layers))
        t = self.transport.account(np.asarray(global_ids)[stale], n_layers,
                                   self.bytes_per_scalar)
        return ver, stale, vals, t

    # -- push side ---------------------------------------------------------

    def plan_push(self, global_ids: np.ndarray,
                  layer_values: list[np.ndarray]) -> PushPlan:
        """Delta-filter, codec-encode, and price a push of
        h^1..h^{L-1} rows without touching the server."""
        n_total = len(global_ids)
        raw = [np.asarray(v, np.float32) for v in layer_values]
        # EF folds the carried residual in *before* delta selection, so
        # the τ rule and the shadow both see the compensated values the
        # wire will actually carry.
        if self.ef is not None:
            raw = self.ef.compensate(np.asarray(global_ids), raw)
        if self.delta is not None:
            sel = self.delta.select(global_ids, raw)
            global_ids = np.asarray(global_ids)[sel]
            raw = [v[sel] for v in raw]
        # A real-wire transport codec-encodes the write on the socket —
        # the server decodes the actual payload bytes; roundtripping here
        # too would cross the (lossy) wire twice.  EF still needs the
        # decoded view locally (codecs are deterministic, so this local
        # roundtrip equals what the server stores from the socket bytes).
        ef_decoded = None
        payloads = None
        if self.transport.wire_is_real:
            decoded = raw
            if self.ef is not None:
                ef_decoded = [self.codec.roundtrip(v) for v in raw]
        elif self._fused_int8():
            # encode once here; apply_push ships the wire form to the
            # fused decode+scatter (decoding it == `decoded` bit-exactly)
            payloads = [self.codec.encode(v) for v in raw]
            decoded = [self.codec.decode(p) for p in payloads]
        else:
            decoded = [self.codec.roundtrip(v) for v in raw]
        t = self.transport.transfer_time(global_ids, self.shared_layers,
                                         self.bytes_per_scalar) \
            if len(global_ids) else 0.0
        return PushPlan(global_ids=np.asarray(global_ids),
                        layer_values=decoded, raw_values=raw,
                        transfer_time=t,
                        n_selected=len(global_ids), n_total=n_total,
                        ef_decoded=ef_decoded, payloads=payloads)

    def apply_push(self, plan: PushPlan) -> float:
        """Commit a planned push: store what the server decodes, refresh
        the delta shadow, record the transfer in the shard logs."""
        if plan.n_selected == 0:
            return 0.0
        if plan.payloads is not None:
            self.transport.write_quantized(plan.global_ids, plan.payloads)
        else:
            self.transport.write(plan.global_ids, plan.layer_values)
        if self.delta is not None:
            self.delta.commit(plan.global_ids, plan.raw_values)
        if self.ef is not None:
            self.ef.commit(plan.global_ids, plan.raw_values,
                           plan.ef_decoded if plan.ef_decoded is not None
                           else plan.layer_values)
        return self.transport.account(plan.global_ids, self.shared_layers,
                                      self.bytes_per_scalar)

    def push(self, global_ids: np.ndarray,
             layer_values: list[np.ndarray]) -> float:
        """Immediate push (pre-training bootstrap, §3.2.1)."""
        return self.apply_push(self.plan_push(global_ids, layer_values))
