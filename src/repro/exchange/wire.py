"""Length-prefixed binary wire protocol for the embedding server.

One frame per RPC, in both directions::

    uint32 LE body length | body

Request body: ``uint8 opcode`` + opcode-specific payload.  Response
body: ``uint8 status`` (0 ok / 1 error) + payload (UTF-8 message on
error).  All integers little-endian; all arrays C-order raw bytes.

The embedding payload blocks are the *codec wire format itself* — the
exact bytes :meth:`NetworkModel.embedding_bytes` charges for:

    fp32 — n·hidden·4 B            (raw float32 rows)
    fp16 — n·hidden·2 B            (raw float16 rows)
    int8 — n·hidden·1 B + n·4 B    (int8 rows + per-row fp32 scales)

so for every codec ``sum(block bytes) == embedding_bytes(n, hidden,
layers, bytes_per_scalar=codec.bytes_per_scalar(hidden))`` exactly.
Frame headers, opcodes and vertex-id vectors are *not* payload — the
analytic model folds them into ``rpc_overhead_s``, and the transport
reports them separately as ``frame_bytes``.

Both the client (:class:`repro.exchange.socket_transport.TcpTransport`)
and the server (``repro.launch.embed_server``) build and parse frames
through this module, so the two ends cannot drift.

Opcodes 1–15 belong to this plane (14/15 reserved for telemetry
scrapes); repro-lint (``python -m repro.launch.lint``, family WP)
cross-checks every builder/parser byte layout and the pinned opcode
registry in :mod:`repro.analysis.rules_wire` — renumbering an opcode
here requires the matching registry edit.
"""

from __future__ import annotations

import struct

import numpy as np

# -- opcodes / status ---------------------------------------------------------

OP_REGISTER = 1
OP_WRITE = 2
OP_GATHER = 3
OP_EMBED_STATS = 4
OP_EMBED_SHUTDOWN = 5
OP_VGATHER = 6       # conditional gather: versions always, rows if stale

# Shared telemetry opcodes, answered by EVERY TCP plane (embed shards
# own opcodes 1..15, the fedsvc control plane 16..31, gnnserve 32+;
# 14/15 are carved out of the embedding range and reserved across all
# planes so one scraper speaks to any endpoint).  Handled by
# repro.obsv.teleserve.handle_telemetry before plane-specific dispatch.
OP_METRICS = 14      # → JSON metrics-registry snapshot + clock handshake
OP_TRACE = 15        # → JSON trace-ring snapshot + clock handshake

STATUS_OK = 0
STATUS_ERR = 1

CODEC_IDS = {"fp32": 0, "fp16": 1, "int8": 2}
CODEC_NAMES = {v: k for k, v in CODEC_IDS.items()}

_LEN = struct.Struct("<I")
_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U64 = struct.Struct("<Q")
_STATS = struct.Struct("<IIQQ")        # num_layers, hidden, rows, mem_bytes

MAX_FRAME = 1 << 30                    # 1 GiB sanity bound per frame


# -- codec payload blocks -----------------------------------------------------

def payload_nbytes(codec: str, n: int, hidden: int) -> int:
    """Wire bytes of one (n, hidden) layer block for ``codec``."""
    if codec == "fp32":
        return n * hidden * 4
    if codec == "fp16":
        return n * hidden * 2
    if codec == "int8":
        return n * hidden + n * 4
    raise ValueError(f"unknown wire codec {codec!r}")


def encode_block(codec: str, payload) -> bytes:
    """Codec payload (``WireCodec.encode`` output) → wire bytes."""
    if codec == "fp32":
        return np.ascontiguousarray(payload, np.float32).tobytes()
    if codec == "fp16":
        return np.ascontiguousarray(payload, np.float16).tobytes()
    if codec == "int8":
        values, scales = payload
        return (np.ascontiguousarray(values, np.int8).tobytes()
                + np.ascontiguousarray(scales, np.float32).tobytes())
    raise ValueError(f"unknown wire codec {codec!r}")


def decode_block(codec: str, buf: memoryview, n: int, hidden: int):
    """Wire bytes → codec payload (``WireCodec.decode`` input)."""
    if codec == "fp32":
        return np.frombuffer(buf, np.float32, n * hidden).reshape(n, hidden)
    if codec == "fp16":
        return np.frombuffer(buf, np.float16, n * hidden).reshape(n, hidden)
    if codec == "int8":
        values = np.frombuffer(buf, np.int8, n * hidden).reshape(n, hidden)
        scales = np.frombuffer(buf[n * hidden:], np.float32, n).reshape(n, 1)
        return values, scales
    raise ValueError(f"unknown wire codec {codec!r}")


# -- framing ------------------------------------------------------------------

def recv_exact(sock, n: int) -> bytes:
    """Read exactly n bytes; raises ConnectionError on EOF mid-message."""
    chunks, got = [], 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ConnectionError(
                f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock, body: bytes) -> None:
    sock.sendall(_LEN.pack(len(body)) + body)


def recv_frame(sock) -> bytes | None:
    """One framed body, or None on a clean EOF at a frame boundary."""
    hdr = b""
    while len(hdr) < _LEN.size:
        chunk = sock.recv(_LEN.size - len(hdr))
        if not chunk:
            if hdr:
                raise ConnectionError("peer closed mid-header")
            return None
        hdr += chunk
    (length,) = _LEN.unpack(hdr)
    if length > MAX_FRAME:
        raise ConnectionError(f"frame of {length} bytes exceeds MAX_FRAME")
    return recv_exact(sock, length)


def frame_nbytes(body_len: int) -> int:
    return _LEN.size + body_len


# -- tensor lists -------------------------------------------------------------
#
# Generic dtype/shape-tagged array framing, used by the federated
# control plane (repro.fedsvc.protocol) to move model leaves byte-
# exactly.  Unlike the embedding payload blocks above, tensors carry
# their own headers: the coordinator is model-agnostic and cannot infer
# shapes from an out-of-band (num_layers, hidden) contract.

def build_tensors(arrays) -> bytes:
    """[np.ndarray] → self-describing wire bytes (dtype, shape, raw)."""
    out = [_U16.pack(len(arrays))]
    for a in arrays:
        a = np.asarray(a)
        if a.ndim:                 # ascontiguousarray promotes 0-d to 1-d
            a = np.ascontiguousarray(a)
        dt = a.dtype.str.encode("ascii")            # e.g. b'<f4'
        out.append(_U8.pack(len(dt)) + dt)
        out.append(_U8.pack(a.ndim))
        out.extend(_U64.pack(d) for d in a.shape)
        out.append(a.tobytes())
    return b"".join(out)


def parse_tensors(view: memoryview, offset: int = 0
                  ) -> tuple[list[np.ndarray], int]:
    """Wire bytes → ([arrays], next offset).  Arrays are copies — they
    must outlive the frame buffer."""
    (count,) = _U16.unpack_from(view, offset)
    offset += _U16.size
    out = []
    for _ in range(count):
        (dlen,) = _U8.unpack_from(view, offset)
        offset += _U8.size
        dtype = np.dtype(bytes(view[offset:offset + dlen]).decode("ascii"))
        offset += dlen
        (ndim,) = _U8.unpack_from(view, offset)
        offset += _U8.size
        shape = []
        for _ in range(ndim):
            (d,) = _U64.unpack_from(view, offset)
            shape.append(d)
            offset += _U64.size
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize \
            if shape else dtype.itemsize
        a = np.frombuffer(view, dtype, nbytes // dtype.itemsize,
                          offset=offset).reshape(shape).copy()
        offset += nbytes
        out.append(a)
    return out, offset


def tensors_nbytes(arrays) -> int:
    """Wire size of :func:`build_tensors` output (headers included)."""
    total = _U16.size
    for a in arrays:
        a = np.asarray(a)
        total += _U8.size + len(a.dtype.str) + _U8.size \
            + _U64.size * a.ndim + a.nbytes
    return total


# -- request builders ---------------------------------------------------------

def _gid_bytes(global_ids: np.ndarray) -> bytes:
    return np.ascontiguousarray(global_ids, np.int64).tobytes()


def build_register(global_ids: np.ndarray) -> bytes:
    return (_U8.pack(OP_REGISTER) + _U64.pack(len(global_ids))
            + _gid_bytes(global_ids))


def build_write(codec: str, global_ids: np.ndarray,
                blocks: list[bytes]) -> bytes:
    head = (_U8.pack(OP_WRITE) + _U8.pack(CODEC_IDS[codec])
            + _U16.pack(len(blocks)) + _U64.pack(len(global_ids))
            + _gid_bytes(global_ids))
    return head + b"".join(blocks)


def build_gather(codec: str, global_ids: np.ndarray,
                 layers: list[int]) -> bytes:
    return (_U8.pack(OP_GATHER) + _U8.pack(CODEC_IDS[codec])
            + _U16.pack(len(layers))
            + b"".join(_U16.pack(l) for l in layers)
            + _U64.pack(len(global_ids)) + _gid_bytes(global_ids))


def build_vgather(codec: str, global_ids: np.ndarray,
                  have_versions: np.ndarray, layers: list[int]) -> bytes:
    """Conditional gather: ``have_versions[i]`` is the client's cached
    version for ``global_ids[i]`` (-1 = never seen).  The response is
    ``n×int64`` current versions followed by codec blocks holding rows
    only for positions whose version differs — both ends recompute the
    stale set from the version vectors, so it is never sent."""
    assert len(have_versions) == len(global_ids)
    return (_U8.pack(OP_VGATHER) + _U8.pack(CODEC_IDS[codec])
            + _U16.pack(len(layers))
            + b"".join(_U16.pack(l) for l in layers)
            + _U64.pack(len(global_ids)) + _gid_bytes(global_ids)
            + np.ascontiguousarray(have_versions, np.int64).tobytes())


def build_stats() -> bytes:
    return _U8.pack(OP_EMBED_STATS)


def build_shutdown() -> bytes:
    return _U8.pack(OP_EMBED_SHUTDOWN)


# -- request parsing (server side) --------------------------------------------

def parse_request(body: bytes) -> tuple[int, dict]:
    """→ (opcode, fields).  Payload blocks stay as a memoryview tail so
    the server can decode them against its own (num_layers, hidden)."""
    view = memoryview(body)
    (op,) = _U8.unpack_from(view, 0)
    if op == OP_REGISTER:
        (n,) = _U64.unpack_from(view, 1)
        gids = np.frombuffer(view, np.int64, n, offset=1 + _U64.size)
        return op, {"global_ids": gids}
    if op == OP_WRITE:
        (codec_id,) = _U8.unpack_from(view, 1)
        (layers,) = _U16.unpack_from(view, 2)
        (n,) = _U64.unpack_from(view, 4)
        off = 4 + _U64.size
        gids = np.frombuffer(view, np.int64, n, offset=off)
        off += n * 8
        return op, {"codec": CODEC_NAMES[codec_id], "num_blocks": layers,
                    "global_ids": gids, "payload": view[off:]}
    if op == OP_GATHER:
        (codec_id,) = _U8.unpack_from(view, 1)
        (nsel,) = _U16.unpack_from(view, 2)
        off = 4
        layers = [_U16.unpack_from(view, off + 2 * i)[0]
                  for i in range(nsel)]
        off += 2 * nsel
        (n,) = _U64.unpack_from(view, off)
        off += _U64.size
        gids = np.frombuffer(view, np.int64, n, offset=off)
        return op, {"codec": CODEC_NAMES[codec_id], "layers": layers,
                    "global_ids": gids}
    if op == OP_VGATHER:
        (codec_id,) = _U8.unpack_from(view, 1)
        (nsel,) = _U16.unpack_from(view, 2)
        off = 4
        layers = [_U16.unpack_from(view, off + 2 * i)[0]
                  for i in range(nsel)]
        off += 2 * nsel
        (n,) = _U64.unpack_from(view, off)
        off += _U64.size
        gids = np.frombuffer(view, np.int64, n, offset=off)
        off += n * 8
        have = np.frombuffer(view, np.int64, n, offset=off)
        return op, {"codec": CODEC_NAMES[codec_id], "layers": layers,
                    "global_ids": gids, "have_versions": have}
    if op in (OP_EMBED_STATS, OP_EMBED_SHUTDOWN):
        return op, {}
    raise ValueError(f"unknown opcode {op}")


# -- responses ----------------------------------------------------------------

def build_ok(payload: bytes = b"") -> bytes:
    return _U8.pack(STATUS_OK) + payload


def build_err(message: str) -> bytes:
    return _U8.pack(STATUS_ERR) + message.encode("utf-8", "replace")


def build_stats_payload(num_layers: int, hidden: int, rows: int,
                        memory_bytes: int) -> bytes:
    return _STATS.pack(num_layers, hidden, rows, memory_bytes)


def parse_stats_payload(payload: bytes) -> dict:
    num_layers, hidden, rows, mem = _STATS.unpack(payload)
    return {"num_layers": num_layers, "hidden": hidden,
            "rows": rows, "memory_bytes": mem}


def parse_response(body: bytes) -> memoryview:
    """→ response payload; raises RuntimeError on an error status."""
    view = memoryview(body)
    (status,) = _U8.unpack_from(view, 0)
    if status == STATUS_OK:
        return view[1:]
    raise RuntimeError(bytes(view[1:]).decode("utf-8", "replace"))
