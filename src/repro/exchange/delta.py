"""Delta pushes: only ship rows that moved since the last push (τ rule).

As federated training converges, most push-node embeddings barely change
round-over-round, yet the seed pushes the full table every round.  Each
client keeps a *shadow* of the raw fp32 values it last pushed; a row is
re-pushed only when its relative L2 change across all shared layers
exceeds a threshold τ:

    ||new_row − shadow_row||₂  >  τ · max(||shadow_row||₂, ε)

τ = 0 keeps full-push numerics bit-exactly (rows with literally zero
change are skipped, and a deterministic codec re-encodes an unchanged
row to the identical wire value, so the server state is identical);
τ > 0 trades a bounded staleness for push bytes that shrink as training
converges.  Rows never pushed before are always selected.
"""

from __future__ import annotations

import numpy as np

_EPS = 1e-12


class DeltaTracker:
    """Per-client shadow of last-pushed rows, keyed by global vertex id."""

    def __init__(self, threshold: float, num_layers_shared: int, hidden: int):
        assert threshold >= 0.0
        self.tau = float(threshold)
        self.layers = num_layers_shared
        self.hidden = hidden
        self._slot: dict[int, int] = {}             # gid -> shadow row
        self._buf = np.zeros((0, num_layers_shared, hidden), np.float32)
        # telemetry: (selected, total) row counts per select() call
        self.history: list[tuple[int, int]] = []

    @property
    def _shadow(self) -> np.ndarray:
        return self._buf[: len(self._slot)]

    def _ensure_slots(self, gids: np.ndarray) -> np.ndarray:
        """Shadow rows for gids, allocating slots for unseen ids.
        Capacity-doubling growth, like EmbeddingServer.register —
        amortized O(1) per new id."""
        new = [int(g) for g in gids if int(g) not in self._slot]
        if new:
            base = len(self._slot)
            if base + len(new) > len(self._buf):
                cap = max(16, len(self._buf))
                while cap < base + len(new):
                    cap *= 2
                buf = np.zeros((cap, self.layers, self.hidden), np.float32)
                buf[:base] = self._buf[:base]
                self._buf = buf
            for i, g in enumerate(new):
                self._slot[g] = base + i
        return np.fromiter((self._slot[int(g)] for g in gids),
                           np.int64, count=len(gids))

    def select(self, gids: np.ndarray, layer_values: list[np.ndarray]
               ) -> np.ndarray:
        """Selection only: boolean mask of rows worth pushing.  Allocates
        no shadow slots and never mutates row state — call :meth:`commit`
        when the push lands, so an abandoned plan leaves unseen rows
        still "never pushed" (and therefore still always selected).
        ``history`` records one (selected, total) entry per planning
        pass, applied or not.

        ``layer_values[l]`` is (n, hidden) fp32 aligned with ``gids``."""
        assert len(layer_values) == self.layers
        if len(gids) == 0:
            return np.zeros(0, bool)
        known = np.fromiter((int(g) in self._slot for g in gids),
                            bool, count=len(gids))
        sel = ~known                       # never-pushed rows always go
        if known.any():
            stacked = np.stack(
                [np.asarray(v, np.float32)[known] for v in layer_values],
                axis=1)                    # (n_known, layers, hidden)
            rows = np.fromiter((self._slot[int(g)] for g in gids[known]),
                               np.int64, count=int(known.sum()))
            old = self._shadow[rows]
            n = len(rows)
            delta = np.linalg.norm((stacked - old).reshape(n, -1), axis=1)
            ref = np.linalg.norm(old.reshape(n, -1), axis=1)
            sel[known] = delta > self.tau * np.maximum(ref, _EPS)
        self.history.append((int(sel.sum()), len(gids)))
        return sel

    def commit(self, gids: np.ndarray,
               layer_values: list[np.ndarray]) -> None:
        """Refresh the shadow for rows that actually reached the server
        (raw pre-codec values, aligned with ``gids``)."""
        if len(gids) == 0:
            return
        stacked = np.stack([np.asarray(v, np.float32) for v in layer_values],
                           axis=1)
        rows = self._ensure_slots(gids)
        self._shadow[rows] = stacked

    @property
    def total_selected(self) -> int:
        return sum(s for s, _ in self.history)

    @property
    def total_rows(self) -> int:
        return sum(n for _, n in self.history)
