"""Delta pushes + error feedback: client-side state that shapes pushes.

As federated training converges, most push-node embeddings barely change
round-over-round, yet the seed pushes the full table every round.  Each
client keeps a *shadow* of the raw fp32 values it last pushed; a row is
re-pushed only when its relative L2 change across all shared layers
exceeds a threshold τ:

    ||new_row − shadow_row||₂  >  τ · max(||shadow_row||₂, ε)

τ = 0 keeps full-push numerics bit-exactly (rows with literally zero
change are skipped, and a deterministic codec re-encodes an unchanged
row to the identical wire value, so the server state is identical);
τ > 0 trades a bounded staleness for push bytes that shrink as training
converges.  Rows never pushed before are always selected.
"""

from __future__ import annotations

import numpy as np

_EPS = 1e-12


class GidRowTable:
    """Per-gid (layers, hidden) fp32 row storage with capacity-doubling
    growth (amortized O(1) per new id, like EmbeddingServer.register).
    The shared substrate of :class:`DeltaTracker` (shadow rows) and
    :class:`ErrorFeedback` (residual rows)."""

    def __init__(self, num_layers_shared: int, hidden: int):
        self.layers = num_layers_shared
        self.hidden = hidden
        self._slot: dict[int, int] = {}             # gid -> row
        self._buf = np.zeros((0, num_layers_shared, hidden), np.float32)

    @property
    def _live(self) -> np.ndarray:
        """View of the allocated (non-headroom) rows."""
        return self._buf[: len(self._slot)]

    def _rows(self, gids: np.ndarray, *, create: bool) -> np.ndarray:
        """Row indices for ``gids``; unseen ids get fresh zero rows when
        ``create``, else -1."""
        if create:
            new = [int(g) for g in gids if int(g) not in self._slot]
            if new:
                base = len(self._slot)
                if base + len(new) > len(self._buf):
                    cap = max(16, len(self._buf))
                    while cap < base + len(new):
                        cap *= 2
                    buf = np.zeros((cap, self.layers, self.hidden),
                                   np.float32)
                    buf[:base] = self._buf[:base]
                    self._buf = buf
                for i, g in enumerate(new):
                    self._slot[g] = base + i
        return np.fromiter((self._slot.get(int(g), -1) for g in gids),
                           np.int64, count=len(gids))


class DeltaTracker(GidRowTable):
    """Per-client shadow of last-pushed rows, keyed by global vertex id."""

    def __init__(self, threshold: float, num_layers_shared: int, hidden: int):
        assert threshold >= 0.0
        super().__init__(num_layers_shared, hidden)
        self.tau = float(threshold)
        # telemetry: (selected, total) row counts per select() call
        self.history: list[tuple[int, int]] = []

    @property
    def _shadow(self) -> np.ndarray:
        return self._live

    def select(self, gids: np.ndarray, layer_values: list[np.ndarray]
               ) -> np.ndarray:
        """Selection only: boolean mask of rows worth pushing.  Allocates
        no shadow slots and never mutates row state — call :meth:`commit`
        when the push lands, so an abandoned plan leaves unseen rows
        still "never pushed" (and therefore still always selected).
        ``history`` records one (selected, total) entry per planning
        pass, applied or not.

        ``layer_values[l]`` is (n, hidden) fp32 aligned with ``gids``."""
        assert len(layer_values) == self.layers
        if len(gids) == 0:
            return np.zeros(0, bool)
        rows_all = self._rows(gids, create=False)
        known = rows_all >= 0
        sel = ~known                       # never-pushed rows always go
        if known.any():
            stacked = np.stack(
                [np.asarray(v, np.float32)[known] for v in layer_values],
                axis=1)                    # (n_known, layers, hidden)
            old = self._shadow[rows_all[known]]
            n = len(old)
            delta = np.linalg.norm((stacked - old).reshape(n, -1), axis=1)
            ref = np.linalg.norm(old.reshape(n, -1), axis=1)
            sel[known] = delta > self.tau * np.maximum(ref, _EPS)
        self.history.append((int(sel.sum()), len(gids)))
        return sel

    def commit(self, gids: np.ndarray,
               layer_values: list[np.ndarray]) -> None:
        """Refresh the shadow for rows that actually reached the server
        (raw pre-codec values, aligned with ``gids``)."""
        if len(gids) == 0:
            return
        stacked = np.stack([np.asarray(v, np.float32) for v in layer_values],
                           axis=1)
        rows = self._rows(gids, create=True)   # may grow/rebind _buf
        self._buf[rows] = stacked

    @property
    def total_selected(self) -> int:
        return sum(s for s, _ in self.history)

    @property
    def total_rows(self) -> int:
        return sum(n for _, n in self.history)


class ErrorFeedback(GidRowTable):
    """EF-SGD-style residual accumulator for lossy wire codecs.

    A lossy codec (fp16/int8) rounds every pushed row; without
    correction the rounding error is *re-applied* every round and the
    server's converged embeddings stay biased by up to one quantization
    step.  Error feedback folds the previous push's residual into the
    next push before encoding:

        compensated = raw + residual
        wire        = encode(compensated)
        residual'   = compensated − decode(wire)

    so the error is carried forward instead of dropped, and the
    *time-averaged* server value tracks the true fp32 embedding."""

    def compensate(self, gids: np.ndarray,
                   layer_values: list[np.ndarray]) -> list[np.ndarray]:
        """raw rows + carried residual (unseen ids carry zero).  Pure
        read — residuals change only on :meth:`commit`."""
        if len(gids) == 0:
            return [np.asarray(v, np.float32) for v in layer_values]
        rows = self._rows(gids, create=False)
        known = rows >= 0
        out = []
        for l, v in enumerate(layer_values):
            v = np.array(v, np.float32, copy=True)
            if known.any():
                v[known] += self._buf[rows[known], l]
            out.append(v)
        return out

    def commit(self, gids: np.ndarray, compensated: list[np.ndarray],
               decoded: list[np.ndarray]) -> None:
        """Store ``compensated − decoded`` for rows whose push landed."""
        if len(gids) == 0:
            return
        rows = self._rows(gids, create=True)
        for l in range(self.layers):
            self._buf[rows, l] = (np.asarray(compensated[l], np.float32)
                                  - np.asarray(decoded[l], np.float32))

    @property
    def max_abs_residual(self) -> float:
        return float(np.abs(self._live).max()) if len(self._slot) else 0.0


class LeafErrorFeedback:
    """:class:`ErrorFeedback`, leaf-pytree form — the weight wire's EF.

    The embedding plane keys residuals by vertex id; the weight plane's
    unit of exchange is a whole leaf list (one model delta per client
    per round), so the residual is simply a parallel list of arrays.
    Same contract as the row form:

        compensated = delta + residual
        wire        = encode(compensated)
        residual'   = compensated − decode(wire)

    so repeated lossy pushes of a converged model stop biasing the
    aggregate by a persistent quantization step."""

    def __init__(self):
        self._res: list[np.ndarray] | None = None

    def compensate(self, leaves) -> list[np.ndarray]:
        """delta leaves + carried residual (zero before the first
        commit).  Pure read — residuals change only on :meth:`commit`."""
        if self._res is None:
            return [np.asarray(l, np.float32) for l in leaves]
        return [np.asarray(l, np.float32) + r
                for l, r in zip(leaves, self._res)]

    def commit(self, compensated, decoded) -> None:
        """Store ``compensated − decoded`` once the push landed."""
        self._res = [np.asarray(c, np.float32) - np.asarray(d, np.float32)
                     for c, d in zip(compensated, decoded)]

    def reset(self) -> None:
        """Drop the carry (worker re-join starts from a fresh model, so
        the old residual no longer corresponds to anything shipped)."""
        self._res = None

    @property
    def max_abs_residual(self) -> float:
        if not self._res:
            return 0.0
        return max(float(np.abs(r).max()) if r.size else 0.0
                   for r in self._res)
