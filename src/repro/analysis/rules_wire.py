"""WP0xx — wire-protocol conformance across the four TCP planes.

The embedding exchange (``exchange/wire.py``), the federated control
plane (``fedsvc/protocol.py``), the scoring frontend
(``gnnserve/wire.py``) and the dynamic-graph barrier
(``dyngraph/wire.py``) share one length-prefixed framing but own
disjoint opcode ranges.  Nothing at runtime checks that the three
dispatch tables stay disjoint, that every opcode has exactly one
builder and one handler branch, or that a builder's ``struct`` pack
sequence still matches its parser's unpack sequence — this module
does, symbolically, from the AST.

Rules:

    WP001  opcode value collides with another plane's opcode
    WP002  opcode value outside its plane's reserved range
    WP003  opcode without exactly one request builder / parser branch
    WP004  opcode without exactly one server dispatch branch
    WP005  builder byte layout != parser byte layout (field-for-field)
    WP006  OP_* constant name defined in more than one module
    WP007  opcode value differs from the pinned registry below
    WP008  builder/parser construct the checker cannot verify

The pinned registry (also the README reservation table) is what makes
WP007 catch *any* opcode renumbering, including to an unused in-range
value that every relative check would accept.

Byte layouts are compared as token sequences extracted symbolically:
``_U16.pack(x)`` ↔ ``_U16.unpack_from(view, off)`` both become a
``u16`` token, ``np.ascontiguousarray(x, np.int64).tobytes()`` ↔
``np.frombuffer(view, np.int64, ...)`` both become ``i64[]``, loops
and generator joins become repeat groups, JSON/tensor-block helpers
become opaque-but-typed tokens.  Offsets are *not* modelled — the
invariant checked is the field type sequence, which is exactly what
drifts when someone edits one end of the wire.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from .core import Finding, SourceFile, dotted_name

# -- pinned opcode registry ---------------------------------------------------
#
# One row per plane: reserved range and the name→value table the wire
# module must match exactly.  Editing a wire module's opcode requires
# editing this table in the same PR — which is the point.

@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    name: str
    wire_rel: str                       # module defining opcodes/builders
    parser: str                         # request-parse function name
    handler_rel: str                    # module containing dispatch branches
    lo: int
    hi: int
    opcodes: dict                       # name -> value (pinned)
    reserved: frozenset                 # telemetry names: no builder/branch
    shared_handled: frozenset           # other planes' opcodes it dispatches
    builder_style: str = "functions"    # or "rpc_callsites"
    parent_rel: str = ""                # module it imports framing/structs from


PLANES = (
    PlaneSpec(
        name="exchange",
        wire_rel="src/repro/exchange/wire.py",
        parser="parse_request",
        handler_rel="src/repro/launch/embed_server.py",
        lo=1, hi=15,
        opcodes={"OP_REGISTER": 1, "OP_WRITE": 2, "OP_GATHER": 3,
                 "OP_EMBED_STATS": 4, "OP_EMBED_SHUTDOWN": 5,
                 "OP_VGATHER": 6, "OP_METRICS": 14, "OP_TRACE": 15},
        reserved=frozenset({"OP_METRICS", "OP_TRACE"}),
        shared_handled=frozenset(),
    ),
    PlaneSpec(
        name="fedsvc",
        wire_rel="src/repro/fedsvc/protocol.py",
        parser="parse_body",
        handler_rel="src/repro/fedsvc/coordinator.py",
        lo=16, hi=31,
        opcodes={"OP_HELLO": 16, "OP_GET_MODEL": 17, "OP_PULLED": 18,
                 "OP_WAIT_PULLED": 19, "OP_UPDATE": 20,
                 "OP_COORD_STATS": 21, "OP_COORD_SHUTDOWN": 22},
        reserved=frozenset(),
        shared_handled=frozenset(),
        builder_style="rpc_callsites",
    ),
    PlaneSpec(
        name="gnnserve",
        wire_rel="src/repro/gnnserve/wire.py",
        parser="parse_serve_request",
        handler_rel="src/repro/gnnserve/frontend.py",
        lo=32, hi=47,
        opcodes={"OP_PREDICT": 32, "OP_SSTATS": 33},
        reserved=frozenset(),
        shared_handled=frozenset({"OP_EMBED_SHUTDOWN"}),
        parent_rel="src/repro/exchange/wire.py",
    ),
    PlaneSpec(
        name="dyngraph",
        wire_rel="src/repro/dyngraph/wire.py",
        parser="parse_growth_request",
        # the dispatch branch lives in the wire module's own parser;
        # fedsvc's coordinator routes the whole 48..63 band there by
        # range, without naming individual opcodes
        handler_rel="src/repro/dyngraph/wire.py",
        lo=48, hi=63,
        opcodes={"OP_GROWTH": 48},
        reserved=frozenset(),
        shared_handled=frozenset(),
    ),
)

#: opcode names every plane answers via obsv.teleserve before dispatch
TELEMETRY_OPS = frozenset({"OP_METRICS", "OP_TRACE"})


# -- symbolic byte-layout tokens ----------------------------------------------
#
# tokens:  ('u8'|'u16'|'u32'|'u64')            fixed-width scalar
#          ('arr', dtype)                      raw ndarray bytes ('?' = any)
#          ('op',)                             the leading opcode byte
#          ('bytes',)                          length-delimited byte string
#          ('json',)                           JSON blob
#          ('tensors',)                        build_tensors/parse_tensors
#          ('blocks',)                         opaque payload tail
#          ('rep', [tokens])                   repeated group (loop/genexp)
#          ('opt', [tokens])                   optional tail (if-guarded)
#          ('?', reason)                       unverifiable construct

_FMT_TOK = {"B": "u8", "H": "u16", "I": "u32", "L": "u32",
            "Q": "u64", "q": "u64", "i": "u32", "h": "u16", "b": "u8"}


def render_tokens(tokens) -> str:
    out = []
    for t in tokens:
        if isinstance(t, str):
            out.append(t)
        elif t[0] == "arr":
            out.append(f"{t[1]}[]")
        elif t[0] in ("rep", "opt"):
            out.append(f"{t[0]}({render_tokens(t[1])})")
        elif t[0] == "op":
            out.append("op")
        elif t[0] == "?":
            out.append(f"?<{t[1]}>")
        else:
            out.append(t[0])
    return " ".join(out) if out else "∅"


def tokens_match(a, b) -> bool:
    if len(a) != len(b):
        return False
    for x, y in zip(a, b):
        xs = isinstance(x, str)
        ys = isinstance(y, str)
        if xs != ys:
            return False
        if xs:
            if x != y:
                return False
            continue
        if x[0] != y[0]:
            return False
        if x[0] == "arr":
            if x[1] != "?" and y[1] != "?" and x[1] != y[1]:
                return False
        elif x[0] in ("rep", "opt"):
            if not tokens_match(x[1], y[1]):
                return False
        elif x[0] == "?":
            return False              # unverifiable never matches
    return True


def has_unverifiable(tokens) -> Optional[str]:
    for t in tokens:
        if isinstance(t, str):
            continue
        if t[0] == "?":
            return t[1]
        if t[0] in ("rep", "opt"):
            r = has_unverifiable(t[1])
            if r:
                return r
    return None


class _Module:
    """Symbol tables of one wire module needed for token extraction."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.structs: dict[str, str] = {}      # name -> struct fmt chars
        self.op_consts: dict[str, int] = {}    # module-level OP_* = int
        self.imported_ops: set[str] = set()    # OP_* imported from elsewhere
        self.imported_names: set[str] = set()  # every name imported-from
        self.functions: dict[str, ast.FunctionDef] = {}
        self.np_alias = "np"
        for node in sf.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                v = node.value
                if isinstance(v, ast.Call) \
                        and dotted_name(v.func).endswith("struct.Struct") \
                        and v.args and isinstance(v.args[0], ast.Constant) \
                        and isinstance(v.args[0].value, str):
                    fmt = v.args[0].value.lstrip("<>!=@")
                    self.structs[name] = fmt
                elif name.startswith("OP_") and isinstance(v, ast.Constant) \
                        and isinstance(v.value, int):
                    self.op_consts[name] = v.value
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    self.imported_names.add(a.asname or a.name)
                    if a.name.startswith("OP_"):
                        self.imported_ops.add(a.asname or a.name)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.np_alias = a.asname or "numpy"
            elif isinstance(node, ast.FunctionDef):
                self.functions[node.name] = node

    def struct_tokens(self, name: str) -> Optional[list]:
        fmt = self.structs.get(name)
        if fmt is None:
            return None
        out = []
        for ch in fmt:
            tok = _FMT_TOK.get(ch)
            if tok is None:
                return None
            out.append(tok)
        return out

    def op_name(self, node: ast.AST) -> Optional[str]:
        """The OP_* symbol an expression refers to, if any."""
        if isinstance(node, ast.Name) and node.id.startswith("OP_"):
            return node.id
        if isinstance(node, ast.Attribute) and node.attr.startswith("OP_"):
            return node.attr
        return None


_NP_DTYPES = {"int8", "int16", "int32", "int64", "uint8", "uint16",
              "uint32", "uint64", "float16", "float32", "float64"}


def _np_dtype(mod: _Module, node: ast.AST) -> Optional[str]:
    d = dotted_name(node)
    if d.startswith(mod.np_alias + "."):
        tail = d[len(mod.np_alias) + 1:]
        if tail in _NP_DTYPES:
            return tail
    return None


# -- builder-side extraction --------------------------------------------------

class _BuilderCtx:
    def __init__(self, mod: _Module, depth: int = 0):
        self.mod = mod
        self.env: dict[str, list] = {}   # local name -> tokens
        self.depth = depth


def _builder_expr(node: ast.AST, ctx: _BuilderCtx) -> list:
    """Token sequence a builder expression contributes to the wire."""
    mod = ctx.mod
    if isinstance(node, ast.Constant):
        if node.value == b"":
            return []
        if isinstance(node.value, bytes):
            return [("bytes",)]
        return [("?", f"constant {node.value!r}")]
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return _builder_expr(node.left, ctx) + _builder_expr(node.right, ctx)
    if isinstance(node, ast.Name):
        if node.id in ctx.env:
            return ctx.env[node.id]
        return [("blocks",)]         # parameter: opaque payload tail
    if isinstance(node, ast.IfExp):
        body = _builder_expr(node.body, ctx)
        orelse = _builder_expr(node.orelse, ctx)
        if not orelse:
            return [("opt", body)]
        if not body:
            return [("opt", orelse)]
        return [("?", "two-armed conditional payload")]
    if isinstance(node, ast.Call):
        return _builder_call(node, ctx)
    return [("?", f"builder expr {type(node).__name__}")]


def _builder_call(node: ast.Call, ctx: _BuilderCtx) -> list:
    mod = ctx.mod
    fn = node.func
    # bytes([op])
    if isinstance(fn, ast.Name) and fn.id == "bytes" and node.args:
        a = node.args[0]
        if isinstance(a, (ast.List, ast.Tuple)) and len(a.elts) == 1:
            return [("op",)]
        return [("bytes",)]
    if isinstance(fn, ast.Attribute):
        recv, meth = fn.value, fn.attr
        # <struct>.pack(...)
        if meth == "pack" and isinstance(recv, ast.Name):
            toks = mod.struct_tokens(recv.id)
            if toks is None:
                return [("?", f"unknown struct {recv.id}")]
            if len(toks) == 1 and toks[0] == "u8" and node.args:
                if mod.op_name(node.args[0]):
                    return [("op",)]
            return list(toks)
        # <expr>.tobytes()
        if meth == "tobytes":
            return [_array_token(recv, ctx)]
        # b"".join(X)
        if meth == "join" and isinstance(recv, ast.Constant) \
                and recv.value == b"" and node.args:
            x = node.args[0]
            if isinstance(x, (ast.GeneratorExp, ast.ListComp)):
                return [("rep", _builder_expr(x.elt, ctx))]
            if isinstance(x, ast.Name) and x.id in ctx.env:
                return ctx.env[x.id]
            return [("blocks",)]
        # json.dumps(...).encode(...)
        if meth == "encode":
            if isinstance(recv, ast.Call) \
                    and dotted_name(recv.func) == "json.dumps":
                return [("json",)]
            return [("bytes",)]
        # wire.build_tensors(...) / module-qualified helper
        if meth == "build_tensors":
            return [("tensors",)]
    # local helper call: inline (depth-limited)
    if isinstance(fn, ast.Name) and fn.id in mod.functions:
        if ctx.depth >= 3:
            return [("?", f"helper {fn.id} nests too deep")]
        return function_build_tokens(mod, mod.functions[fn.id],
                                     depth=ctx.depth + 1)
    if isinstance(fn, ast.Name) and fn.id == "build_tensors":
        return [("tensors",)]
    return [("?", f"builder call {dotted_name(fn) or '<expr>'}")]


def _array_token(recv: ast.AST, ctx: _BuilderCtx):
    """Token for ``<recv>.tobytes()``."""
    if isinstance(recv, ast.Call):
        d = dotted_name(recv.func)
        if d in (f"{ctx.mod.np_alias}.ascontiguousarray",
                 f"{ctx.mod.np_alias}.asarray"):
            if len(recv.args) >= 2:
                dt = _np_dtype(ctx.mod, recv.args[1])
                return ("arr", dt or "?")
            return ("arr", "?")
    return ("arr", "?")


def function_build_tokens(mod: _Module, fn: ast.FunctionDef,
                          *, depth: int = 0) -> list:
    """Byte layout a ``build_*`` function emits.

    Two shapes are understood: a single ``return <expr>`` (possibly
    after local assignments), and the accumulator idiom (``out = [...]``
    then ``out.append/extend`` in loops, returned via ``b"".join(out)``).
    """
    ctx = _BuilderCtx(mod, depth)
    acc_name: Optional[str] = None
    acc_tokens: list = []

    def stmt(s: ast.stmt) -> Optional[list]:
        nonlocal acc_name
        if isinstance(s, ast.Assign) and len(s.targets) == 1 \
                and isinstance(s.targets[0], ast.Name):
            name = s.targets[0].id
            if isinstance(s.value, ast.List):
                acc_name = name
                for e in s.value.elts:
                    acc_tokens.extend(_builder_expr(e, ctx))
            else:
                ctx.env[name] = _builder_expr(s.value, ctx)
            return None
        if isinstance(s, ast.Expr) and isinstance(s.value, ast.Call) \
                and isinstance(s.value.func, ast.Attribute) \
                and isinstance(s.value.func.value, ast.Name) \
                and s.value.func.value.id == acc_name:
            meth = s.value.func.attr
            if meth == "append" and s.value.args:
                acc_tokens.extend(_builder_expr(s.value.args[0], ctx))
            elif meth == "extend" and s.value.args:
                a = s.value.args[0]
                if isinstance(a, (ast.GeneratorExp, ast.ListComp)):
                    acc_tokens.append(("rep", _builder_expr(a.elt, ctx)))
                else:
                    acc_tokens.append(("?", "extend of non-comprehension"))
            return None
        if isinstance(s, ast.AugAssign) and isinstance(s.target, ast.Name) \
                and s.target.id in ctx.env:
            ctx.env[s.target.id] = (ctx.env[s.target.id]
                                    + _builder_expr(s.value, ctx))
            return None
        if isinstance(s, ast.For):
            start = len(acc_tokens)
            for inner in s.body:
                r = stmt(inner)
                if r is not None:
                    return r
            loop_toks = acc_tokens[start:]
            del acc_tokens[start:]
            if loop_toks:
                acc_tokens.append(("rep", loop_toks))
            return None
        if isinstance(s, ast.If) and acc_name is not None \
                and _appends_to(s, acc_name):
            acc_tokens.append(("?", "conditional append to accumulator"))
            return None
        if isinstance(s, ast.Return) and s.value is not None:
            v = s.value
            if acc_name is not None and isinstance(v, ast.Call) \
                    and isinstance(v.func, ast.Attribute) \
                    and v.func.attr == "join" \
                    and v.args and isinstance(v.args[0], ast.Name) \
                    and v.args[0].id == acc_name:
                return acc_tokens
            return _builder_expr(v, ctx)
        if isinstance(s, (ast.Assert, ast.Pass, ast.Expr, ast.AugAssign,
                          ast.If, ast.AnnAssign)):
            return None               # docstrings, asserts, guards
        return [("?", f"builder statement {type(s).__name__}")]

    for s in fn.body:
        r = stmt(s)
        if r is not None:
            return r
    return [("?", "builder without return")]


def _appends_to(node: ast.AST, acc_name: str) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr in ("append", "extend") \
                and isinstance(n.func.value, ast.Name) \
                and n.func.value.id == acc_name:
            return True
    return False


# -- parser-side extraction ---------------------------------------------------

class _ParserWalker:
    """Collect wire-read tokens from parser statements, in source order."""

    def __init__(self, mod: _Module, view_names: set[str]):
        self.mod = mod
        self.views = view_names

    def _is_view_slice(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in self.views
                and isinstance(node.slice, ast.Slice))

    def stmts(self, body: list) -> list:
        out: list = []
        for s in body:
            out.extend(self.stmt(s))
        return out

    def stmt(self, s: ast.stmt) -> list:
        if isinstance(s, ast.Assign):
            # `view = memoryview(body)` registers another view name
            if isinstance(s.value, ast.Call) \
                    and isinstance(s.value.func, ast.Name) \
                    and s.value.func.id == "memoryview" \
                    and len(s.targets) == 1 \
                    and isinstance(s.targets[0], ast.Name):
                self.views.add(s.targets[0].id)
                return []
            return self.expr(s.value)
        if isinstance(s, (ast.AugAssign, ast.AnnAssign)):
            return self.expr(s.value) if s.value is not None else []
        if isinstance(s, ast.Expr):
            return self.expr(s.value)
        if isinstance(s, ast.Return):
            return self.expr(s.value) if s.value is not None else []
        if isinstance(s, ast.For):
            inner = self.stmts(s.body)
            return [("rep", inner)] if inner else []
        if isinstance(s, ast.While):
            inner = self.stmts(s.body)
            return [("rep", inner)] if inner else []
        if isinstance(s, ast.If):
            body = self.stmts(s.body)
            orelse = self.stmts(s.orelse)
            if body and orelse:
                return [("?", "two-armed conditional parse")]
            inner = body or orelse
            return [("opt", inner)] if inner else []
        if isinstance(s, (ast.Raise, ast.Pass, ast.Assert)):
            return []
        if isinstance(s, (ast.FunctionDef, ast.ClassDef)):
            return []
        return [("?", f"parser statement {type(s).__name__}")]

    def expr(self, e: ast.AST) -> list:
        mod = self.mod
        if isinstance(e, ast.Call):
            fn = e.func
            d = dotted_name(fn)
            if d == "json.loads":
                return [("json",)]
            if d.endswith("parse_tensors") or d == "parse_tensors":
                return [("tensors",)]
            if isinstance(fn, ast.Attribute):
                # unwrap value-shaping chains: .reshape(...).copy() etc.
                if fn.attr in ("copy", "reshape", "astype", "tolist"):
                    return self.expr(fn.value)
                if fn.attr in ("unpack_from", "unpack") \
                        and isinstance(fn.value, ast.Name):
                    toks = mod.struct_tokens(fn.value.id)
                    if toks is None:
                        return [("?", f"unknown struct {fn.value.id}")]
                    return list(toks)
                if fn.attr == "frombuffer":
                    dt = "?"
                    if len(e.args) >= 2:
                        dt = _np_dtype(mod, e.args[1]) or "?"
                    return [("arr", dt)]
                if fn.attr == "decode":
                    return self.expr(fn.value) or [("bytes",)]
                if fn.attr == "dtype" and d == f"{mod.np_alias}.dtype":
                    pass              # falls through to arg scan
            if isinstance(fn, ast.Name) and fn.id == "bytes" and e.args:
                a = e.args[0]
                if self._is_view_slice(a):
                    return [("bytes",)]
            # generic call: scan arguments in order (e.g. np.dtype(...),
            # int(...), min(...)) but only keep wire reads found inside
            out: list = []
            for a in list(e.args) + [kw.value for kw in e.keywords]:
                out.extend(self.expr(a))
            return out
        if isinstance(e, ast.Subscript):
            if isinstance(e.value, ast.Name) and e.value.id in self.views:
                if isinstance(e.slice, ast.Constant) and e.slice.value == 0:
                    return [("op",)]
                if isinstance(e.slice, ast.Slice):
                    if e.slice.upper is None:
                        return [("blocks",)]
                    return []         # bounded slice: read via bytes()
                return []
            return self.expr(e.value)
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            out = []
            for el in e.elts:
                out.extend(self.expr(el))
            return out
        if isinstance(e, ast.Dict):
            out = []
            for v in e.values:
                out.extend(self.expr(v))
            return out
        if isinstance(e, (ast.ListComp, ast.GeneratorExp)):
            inner = self.expr(e.elt)
            return [("rep", inner)] if inner else []
        if isinstance(e, ast.BinOp):
            return self.expr(e.left) + self.expr(e.right)
        if isinstance(e, ast.IfExp):
            return self.expr(e.body) + self.expr(e.orelse)
        if isinstance(e, (ast.Name, ast.Constant, ast.Attribute,
                          ast.Compare, ast.UnaryOp, ast.BoolOp,
                          ast.Starred, ast.Lambda, ast.JoinedStr)):
            return []
        return []


def parser_branches(mod: _Module, fn: ast.FunctionDef
                    ) -> tuple[list, dict, list]:
    """→ (preamble_tokens, {op_name: branch_tokens}, order of names).

    A parse function is a preamble (memoryview + opcode read) followed
    by a flat ``if op == OP_X: ...`` chain.  ``op in (A, B)`` yields
    one branch entry per name.
    """
    walker = _ParserWalker(mod, _fn_views(fn))
    preamble: list = []
    branches: dict[str, list] = {}
    order: list[str] = []
    op_var: Optional[str] = None

    def branch_ops(test: ast.AST) -> Optional[list[str]]:
        if not isinstance(test, ast.Compare) or len(test.ops) != 1:
            return None
        left = test.left
        if not (isinstance(left, ast.Name)
                and (op_var is None or left.id == op_var)):
            return None
        cmp = test.comparators[0]
        if isinstance(test.ops[0], ast.Eq):
            n = mod.op_name(cmp)
            return [n] if n else None
        if isinstance(test.ops[0], ast.In) \
                and isinstance(cmp, (ast.Tuple, ast.List)):
            names = [mod.op_name(el) for el in cmp.elts]
            return names if all(names) else None
        return None

    for s in fn.body:
        if isinstance(s, ast.If):
            ops = branch_ops(s.test)
            if ops:
                toks = walker.stmts(s.body)
                for n in ops:
                    branches[n] = toks
                    order.append(n)
                continue
        toks = walker.stmt(s)
        # detect the opcode variable: the first single-byte read of the
        # body is the opcode, whichever idiom reads it (``view[0]`` or
        # ``_U8.unpack_from(view, 0)``) — normalize to the 'op' token
        if op_var is None and isinstance(s, ast.Assign) \
                and toks in (["u8"], [("op",)]):
            t = s.targets[0]
            if isinstance(t, ast.Tuple) and len(t.elts) == 1 \
                    and isinstance(t.elts[0], ast.Name):
                op_var = t.elts[0].id
                toks = [("op",)]
            elif isinstance(t, ast.Name):
                op_var = t.id
                toks = [("op",)]
        preamble.extend(toks)
    return preamble, branches, order


def _fn_views(fn: ast.FunctionDef) -> set[str]:
    """Parser params are buffer views (body/payload/view/buf)."""
    return {a.arg for a in fn.args.args}


def parser_flat_tokens(mod: _Module, fn: ast.FunctionDef) -> list:
    """Token sequence of a branch-free parse function (parse_body,
    parse_tensors, parse_*_payload)."""
    toks = _ParserWalker(mod, _fn_views(fn)).stmts(fn.body)
    # normalize a leading raw-u8 opcode/status read to the 'op' token so
    # it pairs with builders that emit ``bytes([op])``
    if toks[:1] == ["u8"]:
        toks = [("op",)] + toks[1:]
    return toks


# -- per-plane conformance ----------------------------------------------------

def builder_functions(mod: _Module) -> dict[str, tuple[str, list, int]]:
    """{op_name: (func_name, tail_tokens, line)} for every request
    builder — a module function whose first emitted token is the
    opcode byte of a known OP_* constant."""
    out: dict[str, tuple[str, list, int]] = {}
    dupes: list[tuple[str, str, int]] = []
    for name, fn in mod.functions.items():
        op = _leading_op(mod, fn)
        if op is None:
            continue
        toks = function_build_tokens(mod, fn)
        tail = toks[1:] if toks and toks[0] == ("op",) else toks
        if op in out:
            dupes.append((op, name, fn.lineno))
        else:
            out[op] = (name, tail, fn.lineno)
    out["__dupes__"] = dupes          # type: ignore[assignment]
    return out


def _leading_op(mod: _Module, fn: ast.FunctionDef) -> Optional[str]:
    """The OP_* name whose byte a builder emits first, if any."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            first = node.value
            while isinstance(first, ast.BinOp) \
                    and isinstance(first.op, ast.Add):
                first = first.left
            if isinstance(first, ast.Call) \
                    and isinstance(first.func, ast.Attribute) \
                    and first.func.attr == "pack" and first.args:
                return mod.op_name(first.args[0])
            if isinstance(first, ast.Name):
                # head assembled into a local first (build_write)
                for n2 in ast.walk(fn):
                    if isinstance(n2, ast.Assign) \
                            and isinstance(n2.targets[0], ast.Name) \
                            and n2.targets[0].id == first.id:
                        v = n2.value
                        while isinstance(v, ast.BinOp) \
                                and isinstance(v.op, ast.Add):
                            v = v.left
                        if isinstance(v, ast.Call) \
                                and isinstance(v.func, ast.Attribute) \
                                and v.func.attr == "pack" and v.args:
                            return mod.op_name(v.args[0])
            return None
    return None


def handler_branch_counts(sf: SourceFile) -> dict[str, int]:
    """How many times each OP_* name appears in a dispatch comparison
    (``op == X`` / ``op in (X, ...)``) anywhere in the handler module."""
    counts: dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        cmp = node.comparators[0]
        names: list[str] = []
        if isinstance(node.ops[0], ast.Eq):
            n = _op_ref(cmp)
            if n:
                names = [n]
        elif isinstance(node.ops[0], ast.In) \
                and isinstance(cmp, (ast.Tuple, ast.List)):
            names = [n for n in (_op_ref(el) for el in cmp.elts) if n]
        for n in names:
            counts[n] = counts.get(n, 0) + 1
    return counts


def _op_ref(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id.startswith("OP_"):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr.startswith("OP_"):
        return node.attr
    return None


def rpc_callsite_counts(sf: SourceFile) -> dict[str, int]:
    """fedsvc builder style: one ``self._rpc(OP_X, ...)`` per opcode."""
    counts: dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "_rpc" and node.args:
            n = _op_ref(node.args[0])
            if n:
                counts[n] = counts.get(n, 0) + 1
    return counts


def check_plane(spec: PlaneSpec, wire_sf: SourceFile,
                handler_sf: Optional[SourceFile],
                stats: Optional[dict] = None,
                parent_sf: Optional[SourceFile] = None) -> list[Finding]:
    """Full conformance check of one plane's wire module (and its
    handler module when available).  This is the API the mutation
    tests drive directly against fixture copies.

    ``parent_sf`` is the module the plane imports its shared framing
    from (spec.parent_rel); its ``struct.Struct`` definitions resolve
    imported struct names like ``_U8``/``_U64``.
    """
    out: list[Finding] = []
    mod = _Module(wire_sf)
    if parent_sf is not None:
        parent = _Module(parent_sf)
        for name, fmt in parent.structs.items():
            if name in mod.imported_names and name not in mod.structs:
                mod.structs[name] = fmt
    rel = wire_sf.rel

    # WP007/WP002: defined constants vs the pinned registry and range
    for name, value in mod.op_consts.items():
        line = _const_line(wire_sf, name)
        pinned = spec.opcodes.get(name)
        if pinned is None:
            out.append(Finding(
                "WP007", rel, line,
                f"opcode {name}={value} is not in the pinned registry "
                f"for plane '{spec.name}'",
                "add it to analysis.rules_wire.PLANES (and the README "
                "reservation table) in the same change"))
        elif pinned != value:
            out.append(Finding(
                "WP007", rel, line,
                f"opcode {name}={value} but the pinned registry says "
                f"{pinned}",
                "opcode renumbering must update the registry in "
                "analysis.rules_wire.PLANES deliberately"))
        if not spec.lo <= value <= spec.hi:
            out.append(Finding(
                "WP002", rel, line,
                f"opcode {name}={value} outside plane '{spec.name}' "
                f"reserved range {spec.lo}..{spec.hi}",
                f"pick a free value in {spec.lo}..{spec.hi}"))
    for name in spec.opcodes:
        if name not in mod.op_consts and name not in mod.imported_ops:
            out.append(Finding(
                "WP007", rel, 1,
                f"registry opcode {name} is not defined in {rel}",
                "define the constant or remove it from the registry"))

    # within-module value uniqueness
    seen: dict[int, str] = {}
    for name, value in mod.op_consts.items():
        if value in seen:
            out.append(Finding(
                "WP001", rel, _const_line(wire_sf, name),
                f"opcode {name}={value} collides with {seen[value]} "
                "in the same module", "opcodes must be unique"))
        else:
            seen[value] = name

    # builders and parser branches
    builders = builder_functions(mod)
    dupes = builders.pop("__dupes__")
    for op, fname, line in dupes:   # type: ignore[misc]
        out.append(Finding(
            "WP003", rel, line,
            f"opcode {op} has more than one request builder "
            f"(second: {fname})", "exactly one builder per opcode"))

    plane_ops = set(mod.op_consts) - TELEMETRY_OPS
    parser_fn = mod.functions.get(spec.parser)

    if spec.builder_style == "rpc_callsites":
        out.extend(_check_rpc_plane(spec, mod, wire_sf, plane_ops,
                                    parser_fn, stats))
    else:
        out.extend(_check_function_plane(spec, mod, wire_sf, plane_ops,
                                         builders, parser_fn, stats))

    # name-matched response payload pairs: build_X_payload/parse_X_payload
    for name, fn in mod.functions.items():
        if not (name.startswith("build_") and name.endswith("_payload")):
            continue
        pname = "parse_" + name[len("build_"):]
        pfn = mod.functions.get(pname)
        if pfn is None:
            continue
        b = function_build_tokens(mod, fn)
        p = parser_flat_tokens(mod, pfn)
        out.extend(_compare(rel, fn.lineno, f"{name}/{pname}", b, p))
        if stats is not None:
            stats.setdefault("pairs_verified", []).append(
                f"{spec.name}:{name}")

    # build_tensors/parse_tensors (exchange's tensor-list framing)
    if "build_tensors" in mod.functions and "parse_tensors" in mod.functions:
        b = function_build_tokens(mod, mod.functions["build_tensors"])
        p = parser_flat_tokens(mod, mod.functions["parse_tensors"])
        out.extend(_compare(rel, mod.functions["build_tensors"].lineno,
                            "build_tensors/parse_tensors", b, p))
        if stats is not None:
            stats.setdefault("pairs_verified", []).append(
                f"{spec.name}:build_tensors")

    # handler dispatch coverage
    if handler_sf is not None:
        counts = handler_branch_counts(handler_sf)
        must_handle = plane_ops | set(spec.shared_handled)
        for op in sorted(must_handle):
            c = counts.get(op, 0)
            if c != 1:
                out.append(Finding(
                    "WP004", handler_sf.rel, 1,
                    f"opcode {op} has {c} dispatch branches in "
                    f"{handler_sf.rel} (want exactly 1)",
                    "every plane opcode needs exactly one handler branch"))
        for op, c in sorted(counts.items()):
            if op not in must_handle and op not in TELEMETRY_OPS:
                out.append(Finding(
                    "WP004", handler_sf.rel, 1,
                    f"dispatch branch for {op} which is not a plane or "
                    f"shared opcode of '{spec.name}'",
                    "remove the branch or register the opcode"))
    return out


def _check_function_plane(spec, mod, wire_sf, plane_ops, builders,
                          parser_fn, stats) -> list[Finding]:
    out: list[Finding] = []
    rel = wire_sf.rel
    if parser_fn is None:
        out.append(Finding(
            "WP003", rel, 1,
            f"parser function {spec.parser}() not found",
            "the plane spec names the request-parse entrypoint"))
        return out
    preamble, branches, _ = parser_branches(mod, parser_fn)
    expect_ops = (plane_ops | set(spec.shared_handled)) - spec.reserved
    for op in sorted(expect_ops):
        has_builder = op in builders
        if not has_builder and op in plane_ops:
            out.append(Finding(
                "WP003", rel, 1,
                f"opcode {op} has no request builder in {rel}",
                "add a build_* function emitting the opcode byte first"))
        if op not in branches:
            out.append(Finding(
                "WP003", rel, parser_fn.lineno,
                f"opcode {op} has no branch in {spec.parser}()",
                "add the parser branch"))
        if not has_builder or op not in branches:
            continue
        fname, tail, line = builders[op]
        parser_toks = preamble[1:] + branches[op] if preamble[:1] == [("op",)] \
            else preamble + branches[op]
        out.extend(_compare(rel, line, f"{fname}/{spec.parser}[{op}]",
                            tail, parser_toks))
        if stats is not None:
            stats.setdefault("pairs_verified", []).append(
                f"{spec.name}:{op}")
    for op in sorted(set(builders) & plane_ops - expect_ops):
        out.append(Finding(
            "WP003", rel, builders[op][2],
            f"request builder for reserved opcode {op}",
            "telemetry opcodes are built by obsv.teleserve only"))
    for op in sorted(set(branches) - expect_ops - spec.reserved):
        out.append(Finding(
            "WP003", rel, parser_fn.lineno,
            f"{spec.parser}() has a branch for unknown opcode {op}",
            "register the opcode or drop the branch"))
    return out


def _check_rpc_plane(spec, mod, wire_sf, plane_ops, parser_fn,
                     stats) -> list[Finding]:
    """fedsvc style: uniform body, one _rpc call site per opcode."""
    out: list[Finding] = []
    rel = wire_sf.rel
    counts = rpc_callsite_counts(wire_sf)
    for op in sorted(plane_ops):
        c = counts.get(op, 0)
        if c != 1:
            out.append(Finding(
                "WP003", rel, 1,
                f"opcode {op} has {c} _rpc() call sites (want exactly 1)",
                "one client-stub method per opcode"))
    for op in sorted(set(counts) - plane_ops):
        out.append(Finding(
            "WP003", rel, 1,
            f"_rpc() call site for unknown opcode {op}",
            "register the opcode in the module and the pinned registry"))
    # the uniform body builder/parser pair
    bfn = mod.functions.get("build_body")
    pfn = parser_fn
    if bfn is not None and pfn is not None:
        b = function_build_tokens(mod, bfn)
        p = parser_flat_tokens(mod, pfn)
        out.extend(_compare(rel, bfn.lineno, f"build_body/{spec.parser}",
                            b, p))
        if stats is not None:
            stats.setdefault("pairs_verified", []).append(
                f"{spec.name}:build_body")
    return out


def _compare(rel: str, line: int, what: str, b: list, p: list
             ) -> list[Finding]:
    ub, up = has_unverifiable(b), has_unverifiable(p)
    if ub or up:
        return [Finding(
            "WP008", rel, line,
            f"{what}: cannot verify byte layout ({ub or up})",
            "restructure to a pack/unpack idiom the checker models, "
            "or extend rules_wire")]
    if not tokens_match(b, p):
        return [Finding(
            "WP005", rel, line,
            f"{what}: builder layout [{render_tokens(b)}] != parser "
            f"layout [{render_tokens(p)}]",
            "the pack sequence and the unpack sequence must agree "
            "field-for-field")]
    return []


def _const_line(sf: SourceFile, name: str) -> int:
    for node in sf.tree.body:
        if isinstance(node, ast.Assign) \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            return node.lineno
    return 1


# -- family entrypoint --------------------------------------------------------

def check(files: list[SourceFile], *, repo_mode: bool,
          stats: Optional[dict] = None) -> list[Finding]:
    out: list[Finding] = []
    by_rel = {sf.rel: sf for sf in files}

    if repo_mode:
        # full per-plane conformance against the pinned registry
        defined: dict[str, tuple[str, int, str]] = {}   # name -> plane info
        values: dict[int, tuple[str, str]] = {}         # value -> (plane, name)
        for spec in PLANES:
            wire_sf = by_rel.get(spec.wire_rel)
            if wire_sf is None:
                out.append(Finding(
                    "WP007", spec.wire_rel, 1,
                    f"plane '{spec.name}' wire module missing",
                    "update analysis.rules_wire.PLANES if it moved"))
                continue
            out.extend(check_plane(
                spec, wire_sf, by_rel.get(spec.handler_rel), stats,
                parent_sf=by_rel.get(spec.parent_rel)))
            # WP001 cross-plane value collisions (defined constants only;
            # shared opcodes are imported by reference, never re-defined)
            mod = _Module(wire_sf)
            for name, value in mod.op_consts.items():
                prev = values.get(value)
                if prev and prev[0] != spec.name:
                    out.append(Finding(
                        "WP001", spec.wire_rel,
                        _const_line(wire_sf, name),
                        f"opcode {name}={value} collides with plane "
                        f"'{prev[0]}' opcode {prev[1]}",
                        "opcode values must be unique across all planes "
                        "sharing the framing"))
                else:
                    values[value] = (spec.name, name)

    # WP006 cross-module OP_* name shadowing (all scanned files)
    owners: dict[str, list[tuple[str, int]]] = {}
    for sf in files:
        for node in sf.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id.startswith("OP_") \
                    and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                owners.setdefault(node.targets[0].id, []).append(
                    (sf.rel, node.lineno))
    for name, sites in sorted(owners.items()):
        if len(sites) > 1:
            first = sites[0]
            for rel, line in sites[1:]:
                out.append(Finding(
                    "WP006", rel, line,
                    f"OP_* constant {name} is also defined in "
                    f"{first[0]}:{first[1]} — a wrong import silently "
                    "sends the other plane's opcode",
                    "give each plane's constants a namespaced name "
                    "(e.g. OP_EMBED_*, OP_COORD_*) and import, never "
                    "re-define"))

    if not repo_mode:
        # flat mode (fixture dirs): self-consistency of any file that
        # looks like a wire module — defines OP_* constants and a
        # parse_* request function with opcode branches
        for sf in files:
            mod = _Module(sf)
            if not mod.op_consts:
                continue
            for name, fn in mod.functions.items():
                if not name.startswith("parse_"):
                    continue
                _, branches, _ = parser_branches(mod, fn)
                if not branches:
                    continue
                builders = builder_functions(mod)
                builders.pop("__dupes__")
                for op, (fname, tail, line) in builders.items():
                    if op in branches:
                        out.extend(_compare(
                            sf.rel, line, f"{fname}/{name}[{op}]",
                            tail, branches[op]))
    return out
