"""TL0xx — telemetry naming discipline.

The observability plane (PR 8) fixed a convention: every metric and
span name is a **literal** string of the form ``plane.noun_unit`` —
lowercase dotted segments, e.g. ``coord.round_s``, ``embed.gather_us``,
``gnnserve.queue_depth``.  Literal names make the metric namespace
greppable and let this analyzer verify uniqueness statically; an
f-string name silently fragments a histogram into unbounded series.

Rules:

    TL001  metric/span name is not a string literal
    TL002  literal name does not match ``plane.noun_unit``
           (``^[a-z][a-z0-9_]*(\\.[a-z0-9_]+)+$``)
    TL003  the same metric name is registered from more than one module
           (two call sites mutating one series is almost always an
           aliasing accident; spans are exempt — re-entering a span
           name is normal)
"""

from __future__ import annotations

import ast
import re
from typing import Optional

from .core import Finding, SourceFile, dotted_name

_METRIC_METHODS = {"counter", "gauge", "histogram"}
_SPAN_METHODS = {"span", "instant"}
_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z0-9_]+)+$")


def _is_registry_recv(value: ast.AST) -> bool:
    d = dotted_name(value)
    if not d:
        return False
    tail = d.split(".")[-1]
    return tail in ("REGISTRY", "_reg", "_registry", "registry")


def _is_trace_recv(value: ast.AST) -> bool:
    d = dotted_name(value)
    if not d:
        return False
    tail = d.split(".")[-1]
    return tail in ("TRACE", "_trace", "tracer")


def _telemetry_calls(sf: SourceFile):
    """Yield (kind, call) for metric registrations and span opens."""
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) \
                or not isinstance(node.func, ast.Attribute):
            continue
        meth = node.func.attr
        if meth in _METRIC_METHODS and _is_registry_recv(node.func.value):
            yield "metric", node
        elif meth in _SPAN_METHODS and _is_trace_recv(node.func.value):
            yield "span", node


def check(files: list[SourceFile], *, repo_mode: bool,
          stats: Optional[dict] = None) -> list[Finding]:
    findings: list[Finding] = []
    # metric name -> [(rel, line)]
    registered: dict[str, list[tuple[str, int]]] = {}
    n_names = 0
    for sf in files:
        for kind, call in _telemetry_calls(sf):
            if not call.args:
                continue
            name_arg = call.args[0]
            n_names += 1
            if not (isinstance(name_arg, ast.Constant)
                    and isinstance(name_arg.value, str)):
                findings.append(Finding(
                    "TL001", sf.rel, call.lineno,
                    f"{kind} name passed to .{call.func.attr}() is not a "
                    "string literal — dynamic names fragment the series "
                    "and defeat static uniqueness checking",
                    "use a literal name; if the cardinality is genuinely "
                    "bounded, suppress with a justification"))
                continue
            name = name_arg.value
            if not _NAME_RE.match(name):
                findings.append(Finding(
                    "TL002", sf.rel, call.lineno,
                    f"{kind} name {name!r} does not match the "
                    "plane.noun_unit convention",
                    "lowercase dotted segments, e.g. 'coord.round_s'"))
            if kind == "metric":
                registered.setdefault(name, []).append((sf.rel, call.lineno))
    for name, sites in registered.items():
        mods = {rel for rel, _ in sites}
        if len(mods) > 1:
            for rel, line in sites[1:]:
                findings.append(Finding(
                    "TL003", rel, line,
                    f"metric {name!r} is also registered in "
                    f"{sorted(mods - {rel})[0]} — cross-module aliasing "
                    "of one series",
                    "register each metric from a single owning module "
                    "and import the handle"))
    if stats is not None:
        stats["telemetry_names"] = n_names
    return findings
