"""JX0xx / TM0xx — JAX-Pallas tracing hygiene and timing discipline.

Traced scope is discovered per module: functions decorated with
``@jax.jit`` (bare, ``functools.partial(jax.jit, ...)``), functions
wrapped at call sites (``self._step = jax.jit(_step)``, including
lambdas), and kernels passed to ``pl.pallas_call`` — closed over
same-module calls (a helper called from a jitted function traces too).

Rules:

    JX001  host-numpy call inside traced code (np.* runs at trace time
           or forces a device sync — use jnp)
    JX002  .item() / float()/int()/bool() on a traced value (forces a
           blocking device→host transfer and breaks tracing)
    JX003  shape-derived python scalar captured by a traced closure
           (every new value recompiles — pass it through the
           row_buckets() padded path or as a static argname)
    TM001  time.time() — wall clock is not monotonic; durations must
           use time.perf_counter(), deadlines time.monotonic()
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import Finding, SourceFile, dotted_name, iter_functions


def _np_alias(sf: SourceFile) -> Optional[str]:
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    return a.asname or "numpy"
    return None


def _is_jit_deco(node: ast.AST) -> bool:
    d = dotted_name(node)
    if d.endswith("jax.jit") or d == "jit":
        return True
    if isinstance(node, ast.Call):
        f = dotted_name(node.func)
        if f.endswith("jax.jit") or f == "jit":
            return True
        if f.endswith("partial") and node.args \
                and dotted_name(node.args[0]).endswith("jit"):
            return True
    return False


def traced_functions(sf: SourceFile) -> dict[str, ast.FunctionDef]:
    """{qualname: node} of every function whose body runs under trace."""
    by_name: dict[str, list[tuple[str, ast.FunctionDef]]] = {}
    traced: dict[str, ast.FunctionDef] = {}
    fns = list(iter_functions(sf.tree))
    for qual, node in fns:
        by_name.setdefault(node.name, []).append((qual, node))
        if any(_is_jit_deco(d) for d in node.decorator_list):
            traced[qual] = node
    # call-site forms: jax.jit(<name>), pl.pallas_call(<name>, ...)
    for wrapper in ast.walk(sf.tree):
        if not isinstance(wrapper, ast.Call):
            continue
        d = dotted_name(wrapper.func)
        target = None
        if (d.endswith("jax.jit") or d == "jit") and wrapper.args:
            target = wrapper.args[0]
        elif d.endswith("pallas_call") and wrapper.args:
            target = wrapper.args[0]
        if target is None:
            continue
        if isinstance(target, ast.Name):
            for qual, node in by_name.get(target.id, []):
                traced[qual] = node
        elif isinstance(target, ast.Call):
            # jax.jit(jax.vmap(f)) and friends
            inner = target
            while isinstance(inner, ast.Call) and inner.args:
                cand = inner.args[0]
                if isinstance(cand, ast.Name):
                    for qual, node in by_name.get(cand.id, []):
                        traced[qual] = node
                    break
                inner = cand if isinstance(cand, ast.Call) else None
                if inner is None:
                    break
    # same-module reachability: helpers called from traced functions
    qual_of = {q: n for q, n in fns}
    work = list(traced)
    while work:
        q = work.pop()
        node = traced[q]
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            callee = None
            if isinstance(call.func, ast.Name):
                callee = call.func.id
            elif isinstance(call.func, ast.Attribute) \
                    and isinstance(call.func.value, ast.Name) \
                    and call.func.value.id == "self":
                callee = call.func.attr
            if callee is None:
                continue
            for cq, cn in by_name.get(callee, []):
                if cq not in traced:
                    traced[cq] = cn
                    work.append(cq)
    return traced


def traced_lambdas(sf: SourceFile) -> list[ast.Lambda]:
    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) \
                and (dotted_name(node.func).endswith("jax.jit")
                     or dotted_name(node.func) == "jit") \
                and node.args and isinstance(node.args[0], ast.Lambda):
            out.append(node.args[0])
    return out


_SHAPE_DERIVED = ("len",)


def _is_shape_derived(expr: ast.AST) -> bool:
    """RHS forms that produce a python int from an array's geometry."""
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id in ("len", "int"):
        if expr.func.id == "int" and expr.args:
            return _is_shape_derived(expr.args[0])
        return True
    if isinstance(expr, ast.Subscript):
        return _is_shape_derived(expr.value)
    if isinstance(expr, ast.Attribute) and expr.attr in ("shape", "ndim",
                                                         "size"):
        return True
    if isinstance(expr, ast.BinOp):
        return _is_shape_derived(expr.left) or _is_shape_derived(expr.right)
    return False


def _check_traced_body(sf: SourceFile, qual: str, body: ast.AST,
                       np_alias: Optional[str],
                       findings: list[Finding]) -> None:
    for node in ast.walk(body):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not isinstance(node, ast.Call):
            continue
        d = dotted_name(node.func)
        # JX001: np.something(...) — attribute *reads* like np.int32
        # (dtype literals) are fine, calls are not
        if np_alias and d.startswith(np_alias + "."):
            findings.append(Finding(
                "JX001", sf.rel, node.lineno,
                f"host-numpy call {d}() inside traced function {qual}",
                "use jnp (or hoist the computation out of the traced "
                "scope)"))
        # JX002: .item() / float()/int()/bool() on a non-constant
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            findings.append(Finding(
                "JX002", sf.rel, node.lineno,
                f".item() inside traced function {qual} forces a "
                "device sync",
                "keep the value as a traced array"))
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and node.args and not isinstance(node.args[0], ast.Constant):
            # int(x) on shape attrs is static and fine; anything else
            # concretizes a tracer
            if not _is_shape_derived(node.args[0]):
                findings.append(Finding(
                    "JX002", sf.rel, node.lineno,
                    f"{node.func.id}() on a value inside traced function "
                    f"{qual} concretizes the tracer",
                    "trace it (jnp.asarray) or mark the arg static"))


def _check_closure_captures(sf: SourceFile, qual: str,
                            node: ast.FunctionDef,
                            enclosing: ast.FunctionDef,
                            findings: list[Finding]) -> None:
    """JX003: shape-derived ints captured from the enclosing scope."""
    bound: set[str] = {a.arg for a in node.args.args}
    bound |= {a.arg for a in node.args.kwonlyargs}
    if node.args.vararg:
        bound.add(node.args.vararg.arg)
    if node.args.kwarg:
        bound.add(node.args.kwarg.arg)
    for n in ast.walk(node):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        bound.add(leaf.id)
    free = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id not in bound:
            free.add(n.id)
    # enclosing-scope assignments of free names
    for n in enclosing.body:
        if not isinstance(n, ast.Assign):
            continue
        for t in n.targets:
            if isinstance(t, ast.Name) and t.id in free \
                    and _is_shape_derived(n.value):
                findings.append(Finding(
                    "JX003", sf.rel, node.lineno,
                    f"traced function {qual} closes over shape-derived "
                    f"python scalar {t.id!r} — every new value is a "
                    "fresh compile",
                    "route dynamic sizes through the bucketed pad path "
                    "(kernels.quantize.row_buckets) or a static_argname"))


def check(files: list[SourceFile], *, repo_mode: bool,
          stats: Optional[dict] = None) -> list[Finding]:
    findings: list[Finding] = []
    n_traced = 0
    for sf in files:
        np_alias = _np_alias(sf)
        traced = traced_functions(sf)
        n_traced += len(traced)
        enclosing_of: dict[str, ast.FunctionDef] = {}
        for q, node in iter_functions(sf.tree):
            for cq in traced:
                if cq.startswith(q + ".") and cq.count(".") == q.count(".") + 1:
                    enclosing_of[cq] = node
        for qual, node in traced.items():
            _check_traced_body(sf, qual, node, np_alias, findings)
            if qual in enclosing_of:
                _check_closure_captures(sf, qual, node,
                                        enclosing_of[qual], findings)
        for lam in traced_lambdas(sf):
            _check_traced_body(sf, "<lambda>", lam, np_alias, findings)
    if stats is not None:
        stats["traced_functions"] = n_traced
    return findings


def check_timing(files: list[SourceFile], *, repo_mode: bool,
                 stats: Optional[dict] = None) -> list[Finding]:
    """TM001, repo-wide: no wall-clock time.time()."""
    findings: list[Finding] = []
    for sf in files:
        time_aliases = {"time"}
        from_time = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "time":
                        time_aliases.add(a.asname or "time")
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for a in node.names:
                    if a.name == "time":
                        from_time.add(a.asname or "time")
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            hit = any(d == f"{alias}.time" for alias in time_aliases) \
                or (isinstance(node.func, ast.Name)
                    and node.func.id in from_time)
            if hit:
                findings.append(Finding(
                    "TM001", sf.rel, node.lineno,
                    "time.time() is wall clock — NTP steps it backwards "
                    "mid-measurement",
                    "use time.perf_counter() for durations, "
                    "time.monotonic() for deadlines"))
    return findings
