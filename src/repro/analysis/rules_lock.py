"""LD0xx — lock discipline for the threaded servers.

The convention (documented in README "Static analysis"):

* every ``threading.Lock``/``RLock``/``Condition`` attribute created in
  ``__init__`` is a *lock attr*; ``Condition(self.lock)`` aliases the
  condition to its underlying lock (one canonical lock).
* a mutable shared field is annotated where it is created::

      self.results = {}        # guarded-by: self.cond

  and every read/write of that field elsewhere in the class must be
  lexically inside ``with self.cond:`` (or an aliased lock).
* a helper that is only ever called with the lock held is annotated on
  its ``def`` line with the same comment; its body is then checked with
  the lock assumed held (the *call sites* are the author's contract —
  this checker is lexical, not interprocedural, by design).

Rules:

    LD001  guarded field accessed outside its lock
    LD002  Condition.wait() not wrapped in a predicate loop (while)
    LD003  cross-module lock-acquisition-order cycle
    LD004  guarded-by annotation names an unknown lock attribute
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Optional

from .core import Finding, SourceFile, dotted_name, iter_functions

_GUARDED_RE = re.compile(r"guarded-by:\s*self\.([A-Za-z_][A-Za-z0-9_]*)")

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
_EVENT_CTORS = {"Event"}

#: MetricsRegistry methods that take its internal ``_lock`` — resolved
#: heuristically at call sites on a ``REGISTRY``/``_reg`` receiver.
_REGISTRY_LOCKING = {"counter", "gauge", "histogram", "names", "snapshot",
                     "render_text", "clear", "_get"}
_REGISTRY_LOCK_NODE = "obsv.metrics.MetricsRegistry._lock"


@dataclasses.dataclass
class ClassLocks:
    """Lock topology of one class."""

    sf: SourceFile
    qual: str                              # module-relative class name
    locks: dict = dataclasses.field(default_factory=dict)   # attr -> canon
    events: set = dataclasses.field(default_factory=set)
    conditions: set = dataclasses.field(default_factory=set)
    guarded: dict = dataclasses.field(default_factory=dict)  # field -> canon
    held_methods: dict = dataclasses.field(default_factory=dict)

    def canon(self, attr: str) -> Optional[str]:
        return self.locks.get(attr)


def _collect_class(sf: SourceFile, cls: ast.ClassDef,
                   findings: list[Finding]) -> Optional[ClassLocks]:
    init = next((n for n in cls.body
                 if isinstance(n, ast.FunctionDef) and n.name == "__init__"),
                None)
    if init is None:
        return None
    info = ClassLocks(sf, cls.name)
    aliases: list[tuple[str, str]] = []     # (cond attr, underlying attr)
    for node in ast.walk(init):
        if not isinstance(node, ast.Assign):
            continue
        targets = [t for t in node.targets
                   if isinstance(t, ast.Attribute)
                   and isinstance(t.value, ast.Name)
                   and t.value.id == "self"]
        if not targets:
            continue
        v = node.value
        ctor = dotted_name(v.func).rsplit(".", 1)[-1] \
            if isinstance(v, ast.Call) else ""
        for t in targets:
            if ctor in _LOCK_CTORS:
                info.locks[t.attr] = f"{sf.rel}::{cls.name}.{t.attr}"
                if ctor == "Condition":
                    info.conditions.add(t.attr)
                    if isinstance(v, ast.Call) and v.args \
                            and isinstance(v.args[0], ast.Attribute) \
                            and isinstance(v.args[0].value, ast.Name) \
                            and v.args[0].value.id == "self":
                        aliases.append((t.attr, v.args[0].attr))
            elif ctor in _EVENT_CTORS:
                info.events.add(t.attr)
    for cond_attr, under in aliases:
        if under in info.locks:
            info.locks[cond_attr] = info.locks[under]
    # guarded-field annotations (trailing comment on the assignment line)
    for node in ast.walk(init):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        m = _GUARDED_RE.search(sf.comment_on(node.lineno))
        if not m:
            continue
        lock_attr = m.group(1)
        canon = info.canon(lock_attr)
        if canon is None:
            findings.append(Finding(
                "LD004", sf.rel, node.lineno,
                f"guarded-by names self.{lock_attr}, which is not a lock "
                f"attribute of {cls.name}",
                "annotate with a threading.Lock/Condition attr created "
                "in __init__"))
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, ast.Attribute) \
                    and isinstance(t.value, ast.Name) and t.value.id == "self":
                info.guarded[t.attr] = canon
    # held-method annotations (comment on the def line)
    for node in cls.body:
        if isinstance(node, ast.FunctionDef):
            # decorators shift lineno: scan def line and decorator lines
            for ln in range(node.lineno,
                            node.body[0].lineno if node.body else
                            node.lineno + 1):
                m = _GUARDED_RE.search(sf.comment_on(ln))
                if m:
                    canon = info.canon(m.group(1))
                    if canon is None:
                        findings.append(Finding(
                            "LD004", sf.rel, node.lineno,
                            f"guarded-by on {node.name}() names unknown "
                            f"lock self.{m.group(1)}", ""))
                    else:
                        info.held_methods[node.name] = canon
                    break
    return info


class _MethodChecker(ast.NodeVisitor):
    """LD001 within one method: guarded self.X access vs held locks."""

    def __init__(self, info: ClassLocks, fn: ast.FunctionDef,
                 findings: list[Finding]):
        self.info = info
        self.findings = findings
        self.held: list[str] = []
        if fn.name in info.held_methods:
            self.held.append(info.held_methods[fn.name])

    def _lock_of(self, expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return self.info.canon(expr.attr)
        return None

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            c = self._lock_of(item.context_expr)
            if c is not None:
                acquired.append(c)
        self.held.extend(acquired)
        for s in node.body:
            self.visit(s)
        for _ in acquired:
            self.held.pop()

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            canon = self.info.guarded.get(node.attr)
            if canon is not None and canon not in self.held:
                self.findings.append(Finding(
                    "LD001", self.info.sf.rel, node.lineno,
                    f"self.{node.attr} is guarded-by "
                    f"{canon.rsplit('.', 1)[-1]} but accessed outside "
                    "the lock",
                    "move the access inside `with` on the guarding lock, "
                    "or annotate the enclosing helper as called-with-"
                    "lock-held"))
        self.generic_visit(node)


def _check_wait_loops(sf: SourceFile, cond_attrs: set[str],
                      findings: list[Finding]) -> None:
    """LD002: every ``<cond>.wait(...)`` lexically inside a While."""

    class V(ast.NodeVisitor):
        def __init__(self):
            self.while_depth = 0

        def visit_While(self, node):
            self.while_depth += 1
            self.generic_visit(node)
            self.while_depth -= 1

        def visit_FunctionDef(self, node):
            # a nested function resets the loop context
            saved, self.while_depth = self.while_depth, 0
            self.generic_visit(node)
            self.while_depth = saved

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "wait" \
                    and isinstance(f.value, ast.Attribute) \
                    and f.value.attr in cond_attrs \
                    and self.while_depth == 0:
                findings.append(Finding(
                    "LD002", sf.rel, node.lineno,
                    f"Condition {f.value.attr}.wait() outside a predicate "
                    "loop — wakeups are spurious and broadcast",
                    "wrap in `while not predicate(): cond.wait(...)`"))
            self.generic_visit(node)

    V().visit(sf.tree)


# -- lock acquisition-order graph ---------------------------------------------

def _module_imports(sf: SourceFile) -> dict[str, str]:
    """local name -> imported module tail (e.g. 'teleserve')."""
    out = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


@dataclasses.dataclass
class _FnInfo:
    key: tuple                              # (rel, class or '', name)
    node: ast.FunctionDef
    cls: Optional[ClassLocks]
    sf: SourceFile
    acquires: set = dataclasses.field(default_factory=set)
    calls: set = dataclasses.field(default_factory=set)   # callee keys
    # (held_lock, acquired_lock_or_callee_key, line) resolved in fixpoint
    events: list = dataclasses.field(default_factory=list)


def _build_order_graph(files: list[SourceFile],
                       classes: dict[tuple, ClassLocks]
                       ) -> tuple[dict, list]:
    """→ (edges {(a, b): line_info}, functions) from nested acquisitions
    plus one level of heuristic call resolution, closed via fixpoint."""
    fns: dict[tuple, _FnInfo] = {}
    mod_of_rel = {sf.rel: sf for sf in files}
    # index functions
    for sf in files:
        imports = _module_imports(sf)
        for qual, node in iter_functions(sf.tree):
            parts = qual.split(".")
            cls = classes.get((sf.rel, parts[0])) if len(parts) > 1 else None
            key = (sf.rel, parts[0] if cls else "", parts[-1])
            fi = _FnInfo(key, node, cls, sf)
            fns[key] = fi
            _scan_fn(fi, imports, files)
    # fixpoint: propagate transitive acquisitions through calls
    acq: dict[tuple, set] = {k: set(f.acquires) for k, f in fns.items()}
    changed = True
    while changed:
        changed = False
        for k, f in fns.items():
            for callee in f.calls:
                extra = acq.get(callee, set()) - acq[k]
                if extra:
                    acq[k] |= extra
                    changed = True
    # edges: every (held, acquired) pair
    edges: dict[tuple, tuple] = {}
    for k, f in fns.items():
        for held, target, line in f.events:
            if isinstance(target, tuple):            # a call site
                for lock in acq.get(target, set()):
                    if lock != held:
                        edges.setdefault((held, lock), (f.sf.rel, line))
            elif target != held:
                edges.setdefault((held, target), (f.sf.rel, line))
    return edges, fns


def _scan_fn(fi: _FnInfo, imports: dict[str, str],
             files: list[SourceFile]) -> None:
    """Direct acquisitions, nested-acquisition events and call edges."""
    rel_by_tail = {}
    for sf in files:
        tail = sf.rel.rsplit("/", 1)[-1].removesuffix(".py")
        rel_by_tail.setdefault(tail, sf.rel)

    def lock_of(expr) -> Optional[str]:
        if fi.cls is not None and isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return fi.cls.canon(expr.attr)
        return None

    def callee_key(call: ast.Call) -> Optional[tuple]:
        f = call.func
        if isinstance(f, ast.Attribute):
            if isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and fi.cls is not None:
                return (fi.sf.rel, fi.cls.qual, f.attr)
            # REGISTRY.snapshot(...) / self._reg.histogram(...) /
            # metrics.REGISTRY.counter(...)
            d = dotted_name(f.value)
            if f.attr in _REGISTRY_LOCKING \
                    and (d.endswith("REGISTRY") or d.endswith("_reg")
                         or d.endswith("registry")):
                return ("__registry__",)
            # imported-module function: teleserve.handle_telemetry(...)
            if isinstance(f.value, ast.Name) and f.value.id in imports:
                mod_tail = imports[f.value.id].rsplit(".", 1)[-1]
                rel = rel_by_tail.get(mod_tail)
                if rel:
                    return (rel, "", f.attr)
        elif isinstance(f, ast.Name):
            return (fi.sf.rel, fi.cls.qual if fi.cls else "", f.id)
        return None

    held: list[str] = []
    if fi.cls is not None and fi.node.name in fi.cls.held_methods:
        held.append(fi.cls.held_methods[fi.node.name])
        fi.acquires.add(held[0])

    def walk(node):
        if isinstance(node, ast.With):
            acquired = []
            for item in node.items:
                c = lock_of(item.context_expr)
                if c is not None:
                    fi.acquires.add(c)
                    for h in held:
                        fi.events.append((h, c, item.context_expr.lineno))
                    acquired.append(c)
                else:
                    walk(item.context_expr)
            held.extend(acquired)
            for s in node.body:
                walk(s)
            for _ in acquired:
                held.pop()
            return
        if isinstance(node, ast.Call):
            key = callee_key(node)
            if key == ("__registry__",):
                fi.acquires.add(_REGISTRY_LOCK_NODE)
                for h in held:
                    fi.events.append((h, _REGISTRY_LOCK_NODE, node.lineno))
            elif key is not None:
                fi.calls.add(key)
                for h in held:
                    fi.events.append((h, key, node.lineno))
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue              # nested defs are separate functions
            walk(child)

    for s in fi.node.body:
        walk(s)


def _find_cycles(edges: dict) -> list[list[str]]:
    graph: dict[str, set] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    cycles, seen_cycles = [], set()
    for start in sorted(graph):
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in sorted(graph.get(node, ())):
                if nxt == start and len(path) > 1:
                    key = frozenset(path)
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        cycles.append(path + [start])
                elif nxt not in path and len(path) < 6:
                    stack.append((nxt, path + [nxt]))
    return cycles


# -- family entrypoint --------------------------------------------------------

def check(files: list[SourceFile], *, repo_mode: bool,
          stats: Optional[dict] = None) -> list[Finding]:
    findings: list[Finding] = []
    classes: dict[tuple, ClassLocks] = {}
    cond_attrs: set[str] = set()

    for sf in files:
        for node in sf.tree.body:
            if isinstance(node, ast.ClassDef):
                info = _collect_class(sf, node, findings)
                if info is not None:
                    classes[(sf.rel, node.name)] = info
                    cond_attrs |= info.conditions

    # LD001: guarded access in every method of an annotated class
    for (rel, _), info in classes.items():
        if not info.guarded:
            continue
        cls_node = next(n for n in info.sf.tree.body
                        if isinstance(n, ast.ClassDef) and n.name == info.qual)
        for m in cls_node.body:
            if isinstance(m, ast.FunctionDef) and m.name != "__init__":
                _MethodChecker(info, m, findings).visit(m)

    # LD002: Condition.wait without predicate loop (module-wide — wait
    # on an attr *named* like a known condition counts even across
    # classes, e.g. handle._state.cond.wait)
    for sf in files:
        if cond_attrs:
            _check_wait_loops(sf, cond_attrs, findings)

    # LD003: lock-order cycles
    edges, _ = _build_order_graph(files, classes)
    if stats is not None:
        stats["lock_order_edges"] = sorted(
            f"{a.rsplit('::', 1)[-1]} -> {b.rsplit('::', 1)[-1]}"
            for a, b in edges)
    for cycle in _find_cycles(edges):
        a, b = cycle[0], cycle[1]
        rel, line = edges.get((a, b), ("", 1))
        pretty = " -> ".join(c.rsplit("::", 1)[-1] for c in cycle)
        findings.append(Finding(
            "LD003", rel or files[0].rel, line,
            f"lock-acquisition-order cycle: {pretty}",
            "pick one global order for these locks and release before "
            "acquiring against it"))
    return findings
