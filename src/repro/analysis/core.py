"""repro-lint core: source model, suppressions, findings, driver.

A purpose-built static analyzer for this repo's hand-maintained
invariants — the three wire-protocol opcode spaces sharing one framing,
the ``# guarded-by:`` lock discipline of the threaded servers, the
JAX/Pallas tracing rules, and the telemetry naming convention.  Pure
stdlib ``ast``: linting must not import jax (or anything else heavy),
so it runs in a bare CI job and catches breakage *before* the test
matrix spends minutes installing wheels.

Rule families (each in its own module):

    WP0xx  wire-protocol conformance      rules_wire
    LD0xx  lock discipline                rules_lock
    JX0xx  JAX/Pallas tracing hygiene     rules_jax
    TM0xx  timing discipline              rules_jax
    TL0xx  telemetry naming discipline    rules_telemetry

Suppression: a finding is suppressed by a comment on its line (or the
line directly above)::

    x = self.store.hidden   # repro-lint: disable=LD001

Multiple rules comma-separate (``disable=LD001,TM001``);
``disable-file=RULE`` anywhere in the file suppresses the rule for the
whole file.  Suppressions are deliberate, reviewable markers — every
one should carry a justification comment next to it.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Iterable, Optional

__all__ = ["Finding", "SourceFile", "load_file", "collect_files",
           "run_analysis", "AnalysisResult"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file/line."""

    rule: str
    path: str           # display path (relative to the analysis root)
    line: int
    message: str
    hint: str = ""

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.rule)

    def render(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule} {self.message}"
        if self.hint:
            s += f"  [fix: {self.hint}]"
        return s

    def to_json(self) -> dict:
        return {"rule": self.rule, "file": self.path, "line": self.line,
                "message": self.message, "hint": self.hint}


_DISABLE_RE = re.compile(
    r"repro-lint:\s*disable(?P<file>-file)?\s*=\s*(?P<rules>[A-Z]{2}\d{3}"
    r"(?:\s*,\s*[A-Z]{2}\d{3})*)")


class SourceFile:
    """A parsed module plus its comment map and suppression table."""

    def __init__(self, path: pathlib.Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.tree = ast.parse(text, filename=str(path))
        # comment map: physical line -> comment text (sans leading '#')
        self.comments: dict[int, str] = {}
        self.line_disables: dict[int, set[str]] = {}
        self.file_disables: set[str] = set()
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                line = tok.start[0]
                body = tok.string.lstrip("#").strip()
                self.comments[line] = body
                m = _DISABLE_RE.search(body)
                if m:
                    rules = {r.strip() for r in m.group("rules").split(",")}
                    if m.group("file"):
                        self.file_disables |= rules
                    else:
                        self.line_disables.setdefault(line, set()).update(
                            rules)
        except tokenize.TokenError:
            pass                      # ast.parse succeeded; comments best-effort

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_disables:
            return True
        for ln in (line, line - 1):
            if rule in self.line_disables.get(ln, set()):
                return True
        return False

    def comment_on(self, line: int) -> str:
        return self.comments.get(line, "")


def load_file(path: pathlib.Path, root: pathlib.Path) -> SourceFile:
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return SourceFile(path, rel, path.read_text(encoding="utf-8"))


_SKIP_PARTS = {"__pycache__", ".git", ".venv", "node_modules"}


def collect_files(root: pathlib.Path,
                  *, exclude_fixtures: bool = True) -> list[SourceFile]:
    """Every parseable ``*.py`` under root, excluding caches and (by
    default) the analyzer's own test fixtures — those are deliberately
    broken code."""
    out = []
    for p in sorted(root.rglob("*.py")):
        rel_parts = p.resolve().relative_to(root.resolve()).parts
        if any(part in _SKIP_PARTS for part in rel_parts):
            continue
        if exclude_fixtures and "fixtures" in rel_parts:
            continue
        try:
            out.append(load_file(p, root))
        except (SyntaxError, UnicodeDecodeError):
            continue                  # not this tool's problem
    return out


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]
    stats: dict

    @property
    def clean(self) -> bool:
        return not self.findings


def _in_scope(sf: SourceFile, prefixes: tuple[str, ...],
              repo_mode: bool) -> bool:
    if not repo_mode:
        return True
    return sf.rel.startswith(prefixes)


def run_analysis(root, select: Optional[Iterable[str]] = None,
                 *, exclude_fixtures: bool = True) -> AnalysisResult:
    """Run every rule family over the tree at ``root``.

    When ``root`` looks like this repository (has ``src/repro``), each
    family sees its documented scope (wire/lock/telemetry: ``src``;
    jax: ``src`` + ``benchmarks`` + ``examples``; timing: everything).
    Any other root — e.g. a directory of test fixtures — is scanned
    flat, with every family applied to every file.
    """
    from . import rules_jax, rules_lock, rules_telemetry, rules_wire

    root = pathlib.Path(root)
    files = collect_files(root, exclude_fixtures=exclude_fixtures)
    repo_mode = (root / "src" / "repro").is_dir()
    stats: dict = {"files_scanned": len(files), "repo_mode": repo_mode}

    families = {
        "WP": (rules_wire.check, ("src/",)),
        "LD": (rules_lock.check, ("src/",)),
        "JX": (rules_jax.check, ("src/", "benchmarks/", "examples/")),
        "TM": (rules_jax.check_timing, ()),   # repo-wide
        "TL": (rules_telemetry.check, ("src/",)),
    }
    wanted = None
    if select is not None:
        wanted = {s.strip().upper() for s in select if s.strip()}

    findings: list[Finding] = []
    for fam, (fn, prefixes) in families.items():
        if wanted is not None and fam not in wanted:
            continue
        scoped = [sf for sf in files
                  if not prefixes or _in_scope(sf, prefixes, repo_mode)]
        findings.extend(fn(scoped, repo_mode=repo_mode, stats=stats))

    by_rel = {sf.rel: sf for sf in files}
    kept = [f for f in findings
            if f.path not in by_rel
            or not by_rel[f.path].suppressed(f.rule, f.line)]
    kept.sort(key=Finding.sort_key)
    stats["findings"] = len(kept)
    return AnalysisResult(kept, stats)


# -- shared AST helpers used by several rule modules --------------------------

def dotted_name(node: ast.AST) -> str:
    """'a.b.c' for nested Attribute/Name chains, '' if anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def iter_functions(tree: ast.AST):
    """(qualname, node) for every function/method, depth-first."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")
