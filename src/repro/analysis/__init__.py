"""repro-lint: stdlib-ast static analysis for this repo's invariants.

Entry points: :func:`run_analysis` (library) and
``python -m repro.launch.lint`` (CLI).  See ``core.py`` for the rule
family overview and the suppression-comment syntax.
"""

from .core import (AnalysisResult, Finding, SourceFile, collect_files,
                   load_file, run_analysis)

__all__ = ["AnalysisResult", "Finding", "SourceFile", "collect_files",
           "load_file", "run_analysis"]
