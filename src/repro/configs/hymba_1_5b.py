"""hymba-1.5b — hybrid-head: parallel attention + mamba heads per layer.
[arXiv:2411.13676]

Each layer feeds the same normed input to a GQA attention branch and an
SSD branch; outputs are mean-fused.  The SSM branch keeps long_500k
sub-quadratic; the attention branch uses a sliding window there.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    activation="silu_gated",
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    sliding_window=8192,
    citation="arXiv:2411.13676",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-reduced", family="hybrid", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
        activation="silu_gated", ssm_state=16, ssm_head_dim=32,
        ssm_expand=2, ssm_chunk=64, sliding_window=128,
        param_dtype="float32", citation=CONFIG.citation)
