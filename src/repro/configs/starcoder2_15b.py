"""starcoder2-15b — dense GQA kv=4, RoPE, GELU MLP with biases.
[arXiv:2402.19173]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    use_bias=True,
    rope_theta=100_000.0,
    citation="arXiv:2402.19173",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-reduced", family="dense", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=1024, vocab_size=512,
        activation="gelu", use_bias=True, param_dtype="float32",
        citation=CONFIG.citation)
