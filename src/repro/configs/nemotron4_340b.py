"""nemotron-4-340b — dense GQA kv=8, squared-ReLU MLP. [arXiv:2402.16819]

340B params on 256 chips needs factored optimizer state (adafactor) and
sequence-parallel residual sharding — see DESIGN.md §4 and the sharding
rules in repro.distributed.sharding.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10_000.0,
    optimizer="adafactor",
    citation="arXiv:2402.16819",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-reduced", family="dense", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=1024, vocab_size=512,
        activation="squared_relu", param_dtype="float32",
        optimizer="adafactor", citation=CONFIG.citation)
