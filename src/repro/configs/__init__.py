from .base import SHAPES, InputShape, ModelConfig
from .registry import ARCH_IDS, get_config, get_reduced, list_archs

__all__ = ["ModelConfig", "InputShape", "SHAPES", "get_config",
           "get_reduced", "list_archs", "ARCH_IDS"]
