"""Architecture registry: ``--arch <id>`` → ModelConfig."""

from __future__ import annotations

import importlib

from .base import ModelConfig

# arch id → module name (ids keep the published naming)
ARCH_IDS: dict[str, str] = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "nemotron-4-340b": "nemotron4_340b",
    "smollm-360m": "smollm_360m",
    "command-r-35b": "command_r_35b",
    "starcoder2-15b": "starcoder2_15b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
}


def _module(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)
