"""whisper-tiny — encoder-decoder with conv/mel frontend STUB.
[arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is the allowed stub:
input_specs() provides precomputed frame embeddings (B, encoder_seq,
d_model).  We implement the transformer encoder + causal decoder with
cross-attention (the backbone).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    use_bias=True,
    citation="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced", family="audio", num_layers=2,
        encoder_layers=2, encoder_seq=64, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, activation="gelu",
        use_bias=True, param_dtype="float32", citation=CONFIG.citation)
