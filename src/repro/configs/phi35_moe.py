"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    activation="silu_gated",
    num_experts=16,
    top_k=2,
    moe_d_ff=6400,
    optimizer="adamw",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-reduced", family="moe", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        activation="silu_gated", num_experts=4, top_k=2, moe_d_ff=512,
        param_dtype="float32", citation=CONFIG.citation)
