"""mamba2-1.3b — attention-free SSD (state-space duality). [arXiv:2405.21060]

d_inner = 2·d_model, 64-dim SSD heads, d_state=128.  Decode state is O(1)
per layer, so the long_500k shape runs natively (no window needed).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-reduced", family="ssm", num_layers=2, d_model=256,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=512,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=64,
        tie_embeddings=True, param_dtype="float32", citation=CONFIG.citation)
