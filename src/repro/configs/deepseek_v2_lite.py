"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 64 routed top-6,
2 shared experts, first layer dense.  [arXiv:2405.04434]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,          # MLA: per-head K/V reconstructed from c_kv
    d_ff=10944,               # dense first layer FFN
    vocab_size=102400,
    activation="silu_gated",
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    citation="arXiv:2405.04434",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-reduced", family="moe", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
        activation="silu_gated", num_experts=4, num_shared_experts=1,
        top_k=2, moe_d_ff=128, first_dense_layers=1, kv_lora_rank=64,
        qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
        param_dtype="float32", citation=CONFIG.citation)
