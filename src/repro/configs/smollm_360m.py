"""smollm-360m — llama-architecture small dense model, GQA kv=5.
[hf:HuggingFaceTB/SmolLM-135M (family card; 360M variant numbers)]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    activation="silu_gated",
    tie_embeddings=True,
    citation="hf:HuggingFaceTB/SmolLM-135M",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-reduced", family="dense", num_layers=2, d_model=192,
        num_heads=3, num_kv_heads=1, d_ff=512, vocab_size=512,
        activation="silu_gated", tie_embeddings=True, param_dtype="float32",
        citation=CONFIG.citation)
