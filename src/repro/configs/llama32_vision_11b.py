"""llama-3.2-vision-11b — dense GQA decoder with cross-attention image
layers every 5th layer.  [hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision encoder + projector is the allowed STUB: input_specs()
provides precomputed patch embeddings (B, vision_tokens, vision_dim); the
model owns only the projector into d_model and the language stack.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    activation="silu_gated",
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_tokens=1600,
    vision_dim=1280,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-reduced", family="vlm", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        activation="silu_gated", cross_attn_every=2, vision_tokens=16,
        vision_dim=64, param_dtype="float32", citation=CONFIG.citation)
