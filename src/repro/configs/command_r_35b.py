"""command-r-35b — dense GQA kv=8, no biases anywhere.
[hf:CohereForAI/c4ai-command-r-v01]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    activation="silu_gated",
    use_bias=False,
    rope_theta=8_000_000.0,
    citation="hf:CohereForAI/c4ai-command-r-v01",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="command-r-reduced", family="dense", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=768, vocab_size=512,
        activation="silu_gated", use_bias=False, param_dtype="float32",
        citation=CONFIG.citation)
