"""Model/shape configuration schema for the architecture zoo.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published numbers, cited) plus ``reduced()`` (a
≤2-layer, d_model≤512, ≤4-expert variant of the same family for CPU smoke
tests).  Input shapes are the four assigned workload points.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // num_heads
    activation: str = "silu_gated"   # silu_gated | squared_relu | gelu
    use_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention variant
    sliding_window: Optional[int] = None   # ring-buffer window for long ctx
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: layer 0 is dense FFN
    capacity_factor: float = 1.25
    # 0/1 = one global dispatch group (paper-faithful baseline).  >1 =
    # grouped dispatch: sort/scatter stay local to each (data-sharded)
    # token group and only the expert einsum crosses shards (all-to-all)
    # — the §Perf fix for the MoE collective bottleneck.
    moe_groups: int = 0
    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # VLM
    cross_attn_every: int = 0        # a cross-attn layer every N layers
    vision_tokens: int = 0
    vision_dim: int = 0
    # audio (enc-dec)
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings length
    # numerics / optimizer
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"         # adamw | adafactor (340B-scale)
    remat: bool = True
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        D, F, V, H = self.d_model, self.d_ff, self.vocab_size, self.num_heads
        dh = self.resolved_head_dim
        kvh = self.num_kv_heads
        n = V * D * (1 if self.tie_embeddings else 2)

        def attn_p():
            if self.kv_lora_rank:  # MLA
                qd = self.qk_nope_head_dim + self.qk_rope_head_dim
                return (D * H * qd + D * (self.kv_lora_rank + self.qk_rope_head_dim)
                        + self.kv_lora_rank * H * (self.qk_nope_head_dim
                                                   + self.v_head_dim)
                        + H * self.v_head_dim * D)
            return D * H * dh + 2 * D * kvh * dh + H * dh * D

        def mlp_p(ff):
            mult = 3 if self.activation == "silu_gated" else 2
            return mult * D * ff

        def ssm_p():
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            return (D * (2 * d_in + 2 * self.ssm_state + nh)
                    + d_in * D + 3 * nh + d_in)

        per_layer = 2 * D  # norms
        if self.family == "ssm":
            n += self.num_layers * (ssm_p() + D)
            return n
        if self.family == "hybrid":
            n += self.num_layers * (attn_p() + ssm_p() + mlp_p(F) + 3 * D)
            return n
        moe_layers = max(0, self.num_layers - self.first_dense_layers) \
            if self.num_experts else 0
        dense_layers = self.num_layers - moe_layers
        n += dense_layers * (attn_p() + mlp_p(F) + per_layer)
        if moe_layers:
            expert = mlp_p(self.moe_d_ff)
            n += moe_layers * (attn_p() + D * self.num_experts
                               + self.num_experts * expert
                               + self.num_shared_experts * expert + per_layer)
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            n += n_cross * (attn_p() + D)
        if self.encoder_layers:
            n += self.encoder_layers * (attn_p() + mlp_p(F) + per_layer)
            n += self.num_layers * (attn_p() + D)  # decoder cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.activation == "silu_gated" else 2
        expert = mult * self.d_model * self.moe_d_ff
        moe_layers = self.num_layers - self.first_dense_layers
        inactive = moe_layers * (self.num_experts - self.top_k) * expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}
