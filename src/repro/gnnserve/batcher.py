"""Continuous batcher for GNN vertex queries.

The GNN analog of ``core/serving.py``'s lane-based ContinuousBatcher:
queries arrive one at a time, the batcher coalesces them into
fixed-size forward batches, and — because serving is depth-escalating —
a "lane" here is a (request, pending depth) pair.  Each :meth:`step`
picks the depth with the most waiting requests and runs ONE forward for
up to ``batch_size`` of them: confident requests retire, the rest
re-queue at the next depth in the schedule.  Fresh arrivals therefore
mix freely with escalated survivors, exactly like new sequences joining
in-flight decodes in the LLM batcher.

No request is ever dropped or duplicated: a request id lives in exactly
one depth queue until it lands in ``completed`` (pinned by
tests/test_gnnserve.py's bursty-drain test).
"""

from __future__ import annotations

import collections
import dataclasses
import time

from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

_SERVED = REGISTRY.counter("gnnserve.served")
_QWAIT = REGISTRY.histogram("gnnserve.queue_wait_s")
_OCCUPANCY = REGISTRY.histogram("gnnserve.lane_occupancy",
                                lo=1.0, hi=4096.0, factor=2.0)


@dataclasses.dataclass
class ServedResult:
    rid: int
    vid: int
    pred: int
    conf: float
    depth: int          # depth the request exited at
    t_submit: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class QueryBatcher:
    """Batches queries for ONE shard's engine (route per-shard queries
    here via :class:`repro.gnnserve.engine.ServingPlane`)."""

    def __init__(self, engine, *, batch_size: int | None = None,
                 clock=time.perf_counter):
        self.engine = engine
        self.batch_size = batch_size or engine.batch_size
        assert self.batch_size <= engine.batch_size, \
            "batcher batch_size cannot exceed the engine's padded batch"
        self.clock = clock
        # one FIFO per schedule depth; entries (rid, local_id, vid,
        # threshold, t_submit)
        self._queues = {d: collections.deque()
                        for d in engine.depth_schedule}
        self._next_rid = 0
        self.completed: dict[int, ServedResult] = {}
        self.served = 0
        self.exits_by_depth: dict[int, int] = {}

    def submit(self, vid: int, threshold: float = 1.0, *,
               rid: int | None = None) -> int:
        """Enqueue one query; returns its request id."""
        if rid is None:
            rid = self._next_rid
            self._next_rid = rid + 1
        lid = self.engine.local_id(vid)
        d0 = self.engine.depth_schedule[0]
        self._queues[d0].append((rid, lid, int(vid), float(threshold),
                                 self.clock()))
        return rid

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def step(self) -> list[ServedResult]:
        """One fixed-size forward at the busiest depth.  Returns the
        requests that retired this step (confident, or at full depth)."""
        depth = max(self._queues, key=lambda d: len(self._queues[d]))
        q = self._queues[depth]
        if not q:
            return []
        # depth-lane occupancy at pick time: how full the chosen lane
        # was, and a live per-lane depth gauge for scrapes
        _OCCUPANCY.observe(len(q))
        for d, lane in self._queues.items():
            # bounded by model depth (≤ a handful of lanes)
            REGISTRY.gauge(f"gnnserve.lane_depth.d{d}").set(len(lane))  # repro-lint: disable=TL001
        take = [q.popleft() for _ in range(min(self.batch_size, len(q)))]
        t_step = self.clock()
        for t in take:
            _QWAIT.observe(t_step - t[4])     # submit → batch pick
        seeds = [t[1] for t in take]
        thrs = [t[3] for t in take]
        with TRACE.span("gnnserve.forward_batch",
                        args={"depth": depth, "n": len(take)}):
            preds, confs, depths = self.engine.predict_at_depth(
                seeds, thrs, depth)
        now = self.clock()
        out = []
        sched = self.engine.depth_schedule
        for i, (rid, lid, vid, thr, t0) in enumerate(take):
            if depths[i] >= 0:       # retired at `depth`
                res = ServedResult(rid=rid, vid=vid, pred=int(preds[i]),
                                   conf=float(confs[i]), depth=depth,
                                   t_submit=t0, t_done=now)
                self.completed[rid] = res
                self.served += 1
                self.exits_by_depth[depth] = \
                    self.exits_by_depth.get(depth, 0) + 1
                _SERVED.inc()
                # bounded by model depth (≤ a handful of exit lanes)
                REGISTRY.counter(f"gnnserve.exits.d{depth}").inc()  # repro-lint: disable=TL001
                out.append(res)
            else:                    # escalate to the next schedule depth
                nxt = sched[sched.index(depth) + 1]
                self._queues[nxt].append((rid, lid, vid, thr, t0))
        return out

    def run_to_completion(self) -> list[ServedResult]:
        out = []
        while self.pending():
            out.extend(self.step())
        return out

    def pop_completed(self) -> list[ServedResult]:
        out = list(self.completed.values())
        self.completed.clear()
        return out
