"""gnnserve: the inference-serving plane for a trained federated GNN.

Answers vertex-classification queries against the model + embedding
state a :class:`~repro.core.federated.FederatedGNNTrainer` publishes
via ``export_for_serving()``:

  cache     — HotEmbeddingCache: version-validated LRU over the
              embedding-server rows (τ-delta pushes bump row versions,
              so freshness costs 8 B/row on the wire, not a re-pull)
  engine    — ShardServeEngine: deterministic neighbourhood expansion +
              depth-escalating early-exit forward for one shard;
              build_serving() assembles the multi-shard ServingPlane
  batcher   — QueryBatcher: continuous batching of queries into
              fixed-size forward batches, one depth pass per step
  wire      — PREDICT/STATS opcodes over repro.exchange.wire framing
  frontend  — threaded TCP scoring frontend + GnnServeClient

CLI: ``python -m repro.launch.gnn_serve``; bench:
``python -m benchmarks.bench_gnnserve``.
"""

from .cache import HotEmbeddingCache
from .batcher import QueryBatcher, ServedResult
from .engine import ShardServeEngine, ServingPlane, build_serving

__all__ = [
    "HotEmbeddingCache",
    "QueryBatcher",
    "ServedResult",
    "ShardServeEngine",
    "ServingPlane",
    "build_serving",
]
