"""Threaded TCP scoring frontend over a ServingPlane.

Mirrors ``repro.launch.embed_server``'s topology — one accept loop, one
thread per connection, a lock around shared state — plus one *driver*
thread that continuously steps the shard batchers.  Connection handlers
only enqueue queries and wait on a condition variable for their request
ids to complete, so queries from concurrent connections coalesce into
the same forward batches: that is the continuous-batching contract.

Tests and the bench use :func:`serve_in_thread`; the CLI lives in
``repro.launch.gnn_serve``.
"""

from __future__ import annotations

import socket
import threading

import numpy as np

from repro.obsv import teleserve
from repro.obsv.metrics import REGISTRY
from repro.obsv.trace import TRACE

from . import wire
from .engine import ServingPlane

_PREDICTS = REGISTRY.counter("gnnserve.predict_rpcs")


class _FrontState:
    def __init__(self, plane: ServingPlane, *, poll_s: float = 0.005):
        self.plane = plane                     # guarded-by: self.cond
        self.poll_s = poll_s
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.stop = threading.Event()
        self.results: dict[int, object] = {}   # guarded-by: self.cond
        # (results maps rid -> ServedResult; cond shares self.lock)

    # -- driver --------------------------------------------------------------

    def drive(self) -> None:
        """Step the batchers whenever work is queued; park otherwise."""
        while not self.stop.is_set():
            with self.cond:
                if not self.plane.pending():
                    self.cond.wait(self.poll_s)
                    continue
                done = self.plane.step()
                if done:
                    for r in done:
                        self.results[r.rid] = r
                    self.cond.notify_all()

    # -- per-connection dispatch ---------------------------------------------

    def handle(self, body: bytes) -> bytes:
        telemetry = teleserve.handle_telemetry(body)
        if telemetry is not None:
            return telemetry
        try:
            op, req = wire.parse_serve_request(body)
        except Exception as e:
            return wire.build_err(f"bad request: {type(e).__name__}: {e}")
        try:
            if op == wire.OP_PREDICT:
                _PREDICTS.inc()
                with TRACE.span("gnnserve.predict",
                                args={"n": len(req["vids"])}):
                    return self._handle_predict(req)
            if op == wire.OP_SSTATS:
                # registry-backed stats: the plane's own counts plus the
                # gnnserve.* slice of the process metrics registry — one
                # source feeds both the SSTATS dict and OP_METRICS
                with self.lock:
                    stats = self.plane.stats()
                    stats["metrics"] = REGISTRY.snapshot("gnnserve.")
                    return wire.build_ok(wire.build_stats_payload(stats))
            if op == wire.OP_EMBED_SHUTDOWN:
                self.stop.set()
                with self.cond:
                    self.cond.notify_all()
                return wire.build_ok()
            return wire.build_err(f"unknown opcode {op}")
        except Exception as e:
            return wire.build_err(f"{type(e).__name__}: {e}")

    def _handle_predict(self, req: dict) -> bytes:
        vids = np.asarray(req["vids"], np.int64)
        thr = np.asarray(req["thresholds"], np.float32)
        with self.cond:
            rids = [self.plane.submit(int(v), float(t))
                    for v, t in zip(vids, thr)]
            self.cond.notify_all()          # wake the driver
            want = set(rids)
            while not want.issubset(self.results.keys()):
                if self.stop.is_set():
                    return wire.build_err("server shutting down")
                self.cond.wait(0.05)
            res = [self.results.pop(r) for r in rids]
        return wire.build_ok(wire.build_predict_payload(
            np.array([r.pred for r in res], np.int32),
            np.array([r.conf for r in res], np.float32),
            np.array([r.depth for r in res], np.int32)))


class GnnServeHandle:
    def __init__(self, state: _FrontState, sock: socket.socket,
                 threads: list[threading.Thread]):
        self._state = state
        self._sock = sock
        self._threads = threads
        self.host, self.port = sock.getsockname()[:2]

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    @property
    def plane(self) -> ServingPlane:
        return self._state.plane

    def stop(self, timeout: float = 5.0) -> None:
        self._state.stop.set()
        with self._state.cond:
            self._state.cond.notify_all()
        try:
            self._sock.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


def _client_loop(conn: socket.socket, state: _FrontState) -> None:
    try:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not state.stop.is_set():
            body = wire.recv_frame(conn)
            if body is None:
                break
            wire.send_frame(conn, state.handle(body))
    except (ConnectionError, OSError):
        pass
    finally:
        try:
            conn.close()
        except OSError:
            pass


def _accept_loop(listener: socket.socket, state: _FrontState) -> None:
    listener.settimeout(0.2)
    threads: list[threading.Thread] = []
    while not state.stop.is_set():
        try:
            conn, _ = listener.accept()
        except socket.timeout:
            continue
        except OSError:
            break
        t = threading.Thread(target=_client_loop, args=(conn, state),
                             daemon=True)
        t.start()
        threads.append(t)
    try:
        listener.close()
    except OSError:
        pass
    for t in threads:
        t.join(0.5)


def serve_in_thread(plane: ServingPlane, *, host: str = "127.0.0.1",
                    port: int = 0) -> GnnServeHandle:
    """Start the frontend (driver + accept loop) on background threads;
    ephemeral port by default."""
    state = _FrontState(plane)
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((host, port))
    listener.listen(64)
    driver = threading.Thread(target=state.drive, daemon=True)
    driver.start()
    acceptor = threading.Thread(target=_accept_loop, args=(listener, state),
                                daemon=True)
    acceptor.start()
    return GnnServeHandle(state, listener, [driver, acceptor])


class GnnServeClient:
    """Blocking client for the scoring frontend (one pooled socket)."""

    def __init__(self, addr, *, connect_timeout: float = 5.0):
        from repro.exchange.socket_transport import parse_address
        self.addr = parse_address(addr)
        self.connect_timeout = connect_timeout
        self._sock: socket.socket | None = None

    def _conn(self) -> socket.socket:
        if self._sock is None:
            self._sock = socket.create_connection(
                self.addr, timeout=self.connect_timeout)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock.settimeout(None)
        return self._sock

    def _rpc(self, body: bytes):
        sock = self._conn()
        wire.send_frame(sock, body)
        resp = wire.recv_frame(sock)
        if resp is None:
            raise ConnectionError("serving frontend closed connection")
        return wire.parse_response(resp)

    def predict(self, vids, thresholds=None
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """→ (preds, confidences, exit depths) for global vertex ids."""
        vids = np.asarray(vids, np.int64)
        if thresholds is None:
            thresholds = np.ones(len(vids), np.float32)
        payload = self._rpc(wire.build_predict(
            vids, np.asarray(thresholds, np.float32)))
        return wire.parse_predict_payload(payload)

    def stats(self) -> dict:
        return wire.parse_stats_payload(self._rpc(wire.build_sstats()))

    def shutdown(self) -> None:
        try:
            self._rpc(wire.build_shutdown())
        except (ConnectionError, OSError, RuntimeError):
            pass
        self.close()

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
