"""Per-shard serving engine: deterministic expansion + early exit.

Serving answers "classify vertex v" against the state training
published: the trained parameters, the shard graphs, and the embedding
server holding every vertex's h^1..h^{L-1}
(:meth:`FederatedGNNTrainer.export_for_serving`).

Neighbourhood expansion reuses the training sampler's block shapes
(:class:`repro.graphs.sampler.Block`, same static pads, same federated
boundary rules) but is *deterministic*: each vertex contributes its
first ``serve_fanout`` CSR in-neighbours instead of a random draw, so a
query's answer is a pure function of (params, graph, store state) — the
property the bit-identity tests pin.

Early-exit adaptive depth (the FastBERT idea transplanted to GNNs): a
depth-``d`` pass expands only ``d`` hops and seeds the deepest frontier
with the *stored* h^{L-d} rows pulled through the hot-embedding cache,
then runs the top ``d`` GNN layers.  If the resulting softmax clears
the request's confidence threshold the request retires; otherwise it
escalates to the next depth in the schedule.  The final depth is always
the full ``L``-hop pass over raw features — identical numerics to an
offline forward — so a threshold of 1.0 (confidence is never *strictly*
greater) reproduces exact serving.

Remote destination rows at intermediate layers are served from
per-layer slot tables kept in sync with the hot-embedding cache, the
serving analog of the trainer's ``_fill_cache`` — but on demand, only
the slots a batch touches, and revalidated per access.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.sampler import Block, _pad_to, _round_up
from repro.models import gnn
from repro.obsv.metrics import REGISTRY

_FORWARDS = REGISTRY.counter("gnnserve.forwards")

from .cache import HotEmbeddingCache


@functools.partial(jax.jit, static_argnames=("conv",))
def _logits_full(params, batch, features, caches, *, conv):
    return gnn.forward(params, batch, features, caches, conv=conv)


@functools.partial(jax.jit, static_argnames=("conv", "start", "L"))
def _logits_suffix(layer_params, batch, h_in, caches, *, conv, start, L):
    """Run GNN layers ``start..L`` from a stored h^{start-1} input table.

    ``caches[j]`` is the remote-slot table for layer ``start + j``
    (dst rows of remote vertices are read, never computed)."""
    h = h_in
    for j, (layer, blk) in enumerate(zip(layer_params, batch["blocks"])):
        l = start + j
        out = gnn._layer_forward(layer, conv, h, blk, last=(l == L))
        if l < L:
            cached = caches[j][blk["dst_remote_slot"]]
            out = jnp.where(blk["dst_remote_mask"][:, None], cached, out)
        h = out
    return h


class ShardServeEngine:
    """Query answering for the local vertices of one ClientShard."""

    def __init__(self, params, shard, *, conv: str, cache: HotEmbeddingCache,
                 serve_fanout: int = 10, batch_size: int = 64,
                 depth_schedule: list[int] | None = None):
        self.params = params
        self.shard = shard
        self.conv = conv
        self.cache = cache
        self.fanout = serve_fanout
        self.batch_size = batch_size
        self.L = len(params)
        if depth_schedule is None:
            depth_schedule = list(range(1, self.L + 1))
        assert depth_schedule == sorted(set(depth_schedule)) \
            and depth_schedule[-1] == self.L \
            and all(1 <= d <= self.L for d in depth_schedule), \
            f"depth_schedule must be ascending and end at L={self.L}: " \
            f"{depth_schedule}"
        self.depth_schedule = depth_schedule

        n_total = len(shard.global_ids)
        # static pads per hop, shared with the training sampler so batch
        # shapes (and XLA kernels) match across depths
        self._p_nodes = [
            _round_up(min(batch_size * (serve_fanout + 1) ** h, n_total))
            for h in range(self.L + 1)
        ]
        self._p_edges = [
            _round_up(min(batch_size * (serve_fanout + 1) ** h, n_total)
                      * serve_fanout)
            for h in range(self.L)
        ]
        self.features = jnp.asarray(shard.features, jnp.float32)
        self.hidden = int(params[0]["b"].shape[0]) if self.L > 1 \
            else int(shard.features.shape[1])
        # remote-slot tables (serving analog of trainer._caches): slot i
        # ↔ shard.pull_nodes[i]; _slot_ver mirrors the cache versions so
        # a forward only re-scatters rows a push actually invalidated
        p_rem = max(1, shard.num_remote)
        self._ctbl = [jnp.zeros((p_rem, self.hidden), jnp.float32)
                      for _ in range(self.L - 1)]
        self._slot_ver = [np.full(p_rem, -1, np.int64)
                          for _ in range(self.L - 1)]
        self._g2l = {int(g): i
                     for i, g in enumerate(shard.global_ids[:shard.num_local])}
        # telemetry
        self.forwards = 0
        self.rows_in = 0          # store rows requested for input tables

    # -- planning (deterministic sampler) -----------------------------------

    def local_id(self, vid: int) -> int:
        """Global vertex id → shard-local id; KeyError if not owned."""
        return self._g2l[int(vid)]

    def _neighbors(self, frontier: np.ndarray, *, local_only: bool):
        """First-``fanout`` CSR in-neighbours of each LOCAL frontier
        node (deterministic truncation; remote nodes terminate)."""
        sh = self.shard
        srcs, dsts = [], []
        for u in frontier:
            if u >= sh.num_local:
                continue
            nbrs = sh.indices[sh.indptr[u]: sh.indptr[u + 1]]
            if local_only:
                nbrs = nbrs[nbrs < sh.num_local]
            nbrs = nbrs[: self.fanout]
            if len(nbrs) == 0:
                continue
            srcs.append(nbrs.astype(np.int64))
            dsts.append(np.full(len(nbrs), u, dtype=np.int64))
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(srcs), np.concatenate(dsts)

    def _plan(self, seeds: np.ndarray, depth: int) -> dict:
        """Expand ``depth`` hops and build the padded blocks for GNN
        layers ``L-depth+1 .. L`` (same dst-prefix layout as the
        training sampler; the hop-``h`` pad tables are shared across
        depths so each depth compiles once)."""
        sh, L, d = self.shard, self.L, depth
        assert len(seeds) <= self.batch_size
        layers = [np.asarray(seeds, np.int64)]
        layer_edges = []
        for hop in range(1, d + 1):
            cur = layers[-1]
            # rule 3 applies only to the full-depth pass: its input is
            # raw h^0 features, unavailable for remote vertices.  A
            # shallower pass seeds from *stored* h^{L-d}, which the
            # server has for every vertex.
            e_src, e_dst = self._neighbors(
                cur, local_only=(d == L and hop == L))
            new = np.setdiff1d(np.unique(e_src), cur)
            layers.append(np.concatenate([cur, new]))
            layer_edges.append((e_src, e_dst))

        blocks, remote_used = [], {}
        for j in range(1, d + 1):            # j-th applied block
            l = L - d + j                    # absolute GNN layer
            src_nodes = layers[d - j + 1]
            dst_nodes = layers[d - j]
            e_src, e_dst = layer_edges[d - j]
            pos = {int(u): i for i, u in enumerate(src_nodes)}
            es = np.fromiter((pos[int(u)] for u in e_src), np.int64,
                             len(e_src))
            ed = np.fromiter((pos[int(u)] for u in e_dst), np.int64,
                             len(e_dst))
            p_src = self._p_nodes[d - j + 1]
            p_dst = self._p_nodes[d - j]
            p_e = self._p_edges[d - j]
            remote = dst_nodes >= sh.num_local
            slot = np.where(remote, dst_nodes - sh.num_local, 0)
            blocks.append(Block(
                src_ids=_pad_to(src_nodes, p_src),
                n_src=len(src_nodes),
                n_dst=len(dst_nodes),
                edge_src=_pad_to(es, p_e),
                edge_dst=_pad_to(ed, p_e),
                edge_mask=_pad_to(np.ones(len(es), bool), p_e, False),
                dst_remote_mask=_pad_to(remote, p_dst, False),
                dst_remote_slot=_pad_to(slot.astype(np.int32), p_dst),
                dst_mask=_pad_to(np.ones(len(dst_nodes), bool), p_dst, False),
            ))
            if l < L:
                remote_used[l] = np.unique(slot[remote]).astype(np.int64)
        return {"blocks": blocks, "input_nodes": layers[d],
                "remote_used": remote_used, "n_seeds": len(seeds)}

    # -- cache-backed tables -------------------------------------------------

    def _refresh_slots(self, layer: int, slots: np.ndarray) -> None:
        """Revalidate the remote-slot table rows a batch will read; only
        rows whose server version moved are re-scattered."""
        if len(slots) == 0:
            return
        gids = self.shard.pull_nodes[slots]
        rows, ver = self.cache.get(gids, layer)
        changed = self._slot_ver[layer - 1][slots] != ver
        if np.any(changed):
            idx = slots[changed]
            self._ctbl[layer - 1] = \
                self._ctbl[layer - 1].at[idx].set(jnp.asarray(rows[changed]))
            self._slot_ver[layer - 1][idx] = ver[changed]

    def _batch_arrays(self, plan: dict) -> dict:
        return {
            "blocks": [
                {
                    "edge_src": jnp.asarray(b.edge_src, jnp.int32),
                    "edge_dst": jnp.asarray(b.edge_dst, jnp.int32),
                    "edge_mask": jnp.asarray(b.edge_mask),
                    "dst_remote_mask": jnp.asarray(b.dst_remote_mask),
                    "dst_remote_slot": jnp.asarray(b.dst_remote_slot,
                                                   jnp.int32),
                    "dst_mask": jnp.asarray(b.dst_mask),
                }
                for b in plan["blocks"]
            ],
            "input_ids": jnp.asarray(plan["blocks"][0].src_ids, jnp.int32),
        }

    # -- forward -------------------------------------------------------------

    def forward_depth(self, seeds: np.ndarray, depth: int) -> np.ndarray:
        """Logits for shard-local ``seeds``, one row per seed.

        The forward batch is canonicalized to the sorted unique seed
        set first: the block builder's position maps key by node id (a
        duplicated seed would lose its edges), and a canonical batch
        makes the logits a function of the seed *set* — whichever
        connections' queries coalesced around it."""
        seeds = np.asarray(seeds, np.int64)
        uniq, inv = np.unique(seeds, return_inverse=True)
        return self._forward_unique(uniq, depth)[: len(uniq)][inv]

    def _forward_unique(self, seeds: np.ndarray, depth: int) -> np.ndarray:
        L, d = self.L, depth
        plan = self._plan(seeds, d)
        for l, slots in plan["remote_used"].items():
            self._refresh_slots(l, slots)
        batch = self._batch_arrays(plan)
        self.forwards += 1
        _FORWARDS.inc()
        if d == L:
            caches = list(self._ctbl)
            logits = _logits_full(self.params, batch, self.features,
                                  caches, conv=self.conv)
        else:
            start = L - d + 1
            inp = plan["input_nodes"]
            gids = self.shard.global_ids[inp]
            rows, _ = self.cache.get(gids, L - d)
            self.rows_in += len(gids)
            # stored rows convert host→device exactly once; the pad to
            # the static block shape is a device scatter, not an
            # np.zeros staging buffer re-copied per forward
            h_in = jnp.zeros((self._p_nodes[d], self.hidden), jnp.float32) \
                .at[: len(inp)].set(jnp.asarray(rows, jnp.float32))
            caches = [self._ctbl[l - 1] for l in range(start, L)]
            logits = _logits_suffix(self.params[start - 1:], batch,
                                    h_in, caches,
                                    conv=self.conv, start=start, L=L)
        return np.asarray(logits)

    def predict_at_depth(self, seeds: np.ndarray, thresholds: np.ndarray,
                         depth: int
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One depth pass over a batch: a request retires when its
        max-softmax confidence is *strictly* above its threshold (so a
        threshold of 1.0 disables early exit) or unconditionally at full
        depth.  Returns (preds int32, confidences float32, exit depths
        int32) where a depth of -1 marks a request that must escalate."""
        seeds = np.asarray(seeds, np.int64)
        thr = np.asarray(thresholds, np.float32)
        logits = self.forward_depth(seeds, depth)[: len(seeds)]
        z = logits - logits.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        pred = np.argmax(logits, axis=-1).astype(np.int32)
        conf = p.max(axis=-1).astype(np.float32)
        retire = (conf > thr) | (depth == self.L)
        return pred, conf, np.where(retire, depth, -1).astype(np.int32)

    def predict(self, seeds: np.ndarray, thresholds: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Depth-escalating batch prediction (the whole schedule in one
        call; the batcher drives :meth:`predict_at_depth` instead so
        survivors can re-batch with fresh arrivals).  Returns (preds,
        confidences, exit depths), aligned with ``seeds``."""
        seeds = np.asarray(seeds, np.int64)
        thr = np.asarray(thresholds, np.float32)
        n = len(seeds)
        preds = np.zeros(n, np.int32)
        confs = np.zeros(n, np.float32)
        depths = np.zeros(n, np.int32)
        active = np.arange(n)
        for d in self.depth_schedule:
            if len(active) == 0:
                break
            pred, conf, dd = self.predict_at_depth(seeds[active],
                                                   thr[active], d)
            retire = dd >= 0
            done = active[retire]
            preds[done] = pred[retire]
            confs[done] = conf[retire]
            depths[done] = d
            active = active[~retire]
        return preds, confs, depths

    def offline_predict(self, seeds: np.ndarray) -> np.ndarray:
        """Reference: a direct full-depth forward of the trained model on
        the same deterministic neighbourhoods — no cache, no batcher, no
        early exit.  The bit-identity baseline for serving tests."""
        L = self.L
        seeds = np.asarray(seeds, np.int64)
        uniq, inv = np.unique(seeds, return_inverse=True)
        plan = self._plan(uniq, L)
        caches = []
        for l in range(1, L):
            slots = plan["remote_used"].get(l, np.zeros(0, np.int64))
            tbl = np.zeros((max(1, self.shard.num_remote), self.hidden),
                           np.float32)
            if len(slots):
                vals = self.cache.ex.peek(self.shard.pull_nodes[slots], [l])
                tbl[slots] = vals[0]
            caches.append(jnp.asarray(tbl))
        batch = self._batch_arrays(plan)
        logits = _logits_full(self.params, batch, self.features, caches,
                              conv=self.conv)
        return np.argmax(np.asarray(logits)[: len(uniq)][inv],
                         axis=-1).astype(np.int32)


class ServingPlane:
    """Multi-shard serving: routes a query to its owner shard's engine
    and batcher, one shared hot-embedding cache across engines (boundary
    vertices overlap between shards, so sharing raises hit rates)."""

    def __init__(self, engines: dict, batchers: dict, part: np.ndarray,
                 cache: HotEmbeddingCache):
        self.engines = engines
        self.batchers = batchers
        self.part = part
        self.cache = cache
        self._next_rid = 0

    def submit(self, vid: int, threshold: float = 1.0) -> int:
        owner = int(self.part[int(vid)])
        if owner not in self.batchers:
            raise KeyError(f"vertex {vid} lives on client {owner}, which "
                           "this serving plane does not host")
        rid = self._next_rid
        self._next_rid += 1
        self.batchers[owner].submit(vid, threshold, rid=rid)
        return rid

    def pending(self) -> int:
        return sum(b.pending() for b in self.batchers.values())

    def step(self) -> list:
        """One forward per non-idle shard batcher; returns newly
        completed results."""
        out = []
        for b in self.batchers.values():
            if b.pending():
                out.extend(b.step())
        return out

    def drain(self) -> list:
        out = []
        while self.pending():
            out.extend(self.step())
        return out

    def stats(self) -> dict:
        per_depth: dict[int, int] = {}
        served = 0
        for b in self.batchers.values():
            served += b.served
            for d, c in b.exits_by_depth.items():
                per_depth[d] = per_depth.get(d, 0) + c
        return {
            "served": served,
            "exits_by_depth": {str(k): v
                               for k, v in sorted(per_depth.items())},
            "forwards": sum(e.forwards for e in self.engines.values()),
            "cache": self.cache.stats(),
            "cache_hit_rate": self.cache.hit_rate,
        }


def build_serving(bundle: dict, *, cache_rows: int = 100_000,
                  serve_fanout: int = 10, batch_size: int = 64,
                  depth_schedule: list[int] | None = None) -> ServingPlane:
    """Assemble a ServingPlane from a trainer's ``export_for_serving``
    bundle (params + shards + the live embedding exchange)."""
    from repro.exchange import ExchangeClient

    from .batcher import QueryBatcher
    ex = ExchangeClient(bundle["transport"], bundle["codec"])
    cache = HotEmbeddingCache(ex, capacity_rows=cache_rows)
    engines, batchers = {}, {}
    for ci, shard in bundle["shards"].items():
        eng = ShardServeEngine(
            bundle["params"], shard, conv=bundle["conv"], cache=cache,
            serve_fanout=serve_fanout, batch_size=batch_size,
            depth_schedule=depth_schedule)
        engines[ci] = eng
        batchers[ci] = QueryBatcher(eng)
    return ServingPlane(engines, batchers, bundle["part"], cache)
