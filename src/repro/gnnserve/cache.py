"""Hot-embedding cache with a staleness bound tied to τ-delta pushes.

A cached row is valid exactly while the embedding server hasn't
accepted a delta for it: every :meth:`EmbeddingServer.write` bumps the
row's version counter, and every cache access revalidates its held
versions through one conditional pull
(:meth:`ExchangeClient.pull_versioned`).  A fresh row therefore costs 8
version bytes on the wire instead of ``hidden × bytes_per_scalar`` row
bytes; a row invalidated by a training push is re-pulled in the same
RPC.  There is no TTL and no guessing — the version check *is* the
invalidation path.

Eviction is LRU over (layer, gid) row entries, bounded by
``capacity_rows``.
"""

from __future__ import annotations

import collections

import numpy as np

from repro.exchange.client import ExchangeClient
from repro.obsv.metrics import REGISTRY

# process-wide mirrors of the per-cache attribute counters: OP_METRICS
# scrapes read these; tests that build several caches keep reading the
# exact per-instance attributes
_HITS = REGISTRY.counter("gnnserve.cache.hits")
_MISSES = REGISTRY.counter("gnnserve.cache.misses")
_STALE = REGISTRY.counter("gnnserve.cache.stale_refreshes")
_EVICTIONS = REGISTRY.counter("gnnserve.cache.evictions")


class HotEmbeddingCache:
    def __init__(self, exchange: ExchangeClient, *,
                 capacity_rows: int = 100_000):
        assert capacity_rows >= 1
        self.ex = exchange
        self.capacity_rows = capacity_rows
        # (layer, gid) -> [version, row]; insertion order = LRU order
        self._rows: collections.OrderedDict[tuple[int, int], list] = \
            collections.OrderedDict()
        # stats
        self.hits = 0            # rows served without row bytes on the wire
        self.misses = 0          # rows never seen before
        self.stale_refreshes = 0  # held rows invalidated by a push
        self.pull_time = 0.0     # modelled seconds spent on row bytes
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses + self.stale_refreshes
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        """Zero the counters without touching cached rows — benches use
        this to separate warm-fill transients from steady state."""
        self.hits = self.misses = self.stale_refreshes = 0
        self.evictions = 0
        self.pull_time = 0.0

    def stats(self) -> dict:
        return {
            "rows": len(self._rows),
            "capacity_rows": self.capacity_rows,
            "hits": self.hits,
            "misses": self.misses,
            "stale_refreshes": self.stale_refreshes,
            "hit_rate": self.hit_rate,
            "evictions": self.evictions,
            "pull_time_s": self.pull_time,
        }

    def get(self, global_ids: np.ndarray, layer: int
            ) -> tuple[np.ndarray, np.ndarray]:
        """The h^``layer`` rows for ``global_ids`` plus their (post-
        validation) versions.  Every call revalidates: the returned rows
        are guaranteed current as of this call's server round-trip."""
        gids = np.asarray(global_ids, np.int64)
        n = len(gids)
        hidden = self.ex.hidden
        if n == 0:
            return np.zeros((0, hidden), np.float32), np.zeros(0, np.int64)
        keys = [(layer, int(g)) for g in gids]
        have = np.fromiter(
            (self._rows[k][0] if k in self._rows else -1 for k in keys),
            np.int64, n)
        ver, stale, vals, t = self.ex.pull_versioned(gids, have, [layer])
        self.pull_time += t
        out = np.empty((n, hidden), np.float32)
        fresh = np.ones(n, bool)
        fresh[stale] = False
        for i in np.nonzero(fresh)[0]:
            out[i] = self._rows[keys[i]][1]
        rows = vals[0]
        for j, i in enumerate(stale):
            out[i] = rows[j]
        # account + refresh under one pass: stale entries get the new
        # (version, row); every touched key moves to the LRU tail
        n_hits = int(fresh.sum())
        n_miss = int((have[stale] < 0).sum())
        n_stale = int((have[stale] >= 0).sum())
        self.hits += n_hits
        self.misses += n_miss
        self.stale_refreshes += n_stale
        _HITS.inc(n_hits)
        _MISSES.inc(n_miss)
        _STALE.inc(n_stale)
        for j, i in enumerate(stale):
            self._rows[keys[i]] = [int(ver[i]), rows[j].copy()]
        for k in keys:
            self._rows.move_to_end(k)
        while len(self._rows) > self.capacity_rows:
            self._rows.popitem(last=False)
            self.evictions += 1
            _EVICTIONS.inc()
        return out, ver
