"""Scoring-frontend wire format, on repro.exchange.wire framing.

Reuses the exchange plane's length-prefixed frames, status bytes and
struct helpers; the serving opcodes live at 32+ so the two dispatch
tables can never collide (the embedding plane owns 1..15, the federated
control plane 16..31).  ``OP_EMBED_SHUTDOWN`` is shared with the exchange
plane — same semantics, same byte.

    OP_PREDICT  request:  u8 op | u64 n | n×i64 vids | n×f32 thresholds
                response: ok | u64 n | n×i32 preds | n×f32 confs
                               | n×i32 exit depths
    OP_SSTATS   request:  u8 op
                response: ok | UTF-8 JSON stats blob

Opcodes 32–47 belong to this plane; repro-lint (family WP) verifies the
payload layouts against their parsers and the pinned registry in
:mod:`repro.analysis.rules_wire`.
"""

from __future__ import annotations

import json

import numpy as np

from repro.exchange.wire import (  # noqa: F401  (re-exported for frontend)
    _U8, _U64, OP_EMBED_SHUTDOWN, build_err, build_ok, parse_response,
    recv_frame, send_frame,
)

OP_PREDICT = 32
OP_SSTATS = 33


def build_predict(vids: np.ndarray, thresholds: np.ndarray) -> bytes:
    assert len(vids) == len(thresholds)
    return (_U8.pack(OP_PREDICT) + _U64.pack(len(vids))
            + np.ascontiguousarray(vids, np.int64).tobytes()
            + np.ascontiguousarray(thresholds, np.float32).tobytes())


def build_sstats() -> bytes:
    return _U8.pack(OP_SSTATS)


def build_shutdown() -> bytes:
    return _U8.pack(OP_EMBED_SHUTDOWN)


def parse_serve_request(body: bytes) -> tuple[int, dict]:
    view = memoryview(body)
    (op,) = _U8.unpack_from(view, 0)
    if op == OP_PREDICT:
        (n,) = _U64.unpack_from(view, 1)
        off = 1 + _U64.size
        vids = np.frombuffer(view, np.int64, n, offset=off)
        thr = np.frombuffer(view, np.float32, n, offset=off + 8 * n)
        return op, {"vids": vids, "thresholds": thr}
    if op in (OP_SSTATS, OP_EMBED_SHUTDOWN):
        return op, {}
    raise ValueError(f"unknown serving opcode {op}")


def build_predict_payload(preds: np.ndarray, confs: np.ndarray,
                          depths: np.ndarray) -> bytes:
    return (_U64.pack(len(preds))
            + np.ascontiguousarray(preds, np.int32).tobytes()
            + np.ascontiguousarray(confs, np.float32).tobytes()
            + np.ascontiguousarray(depths, np.int32).tobytes())


def parse_predict_payload(payload) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
    view = memoryview(payload)
    (n,) = _U64.unpack_from(view, 0)
    off = _U64.size
    preds = np.frombuffer(view, np.int32, n, offset=off).copy()
    confs = np.frombuffer(view, np.float32, n, offset=off + 4 * n).copy()
    depths = np.frombuffer(view, np.int32, n, offset=off + 8 * n).copy()
    return preds, confs, depths


def build_stats_payload(stats: dict) -> bytes:
    return json.dumps(stats).encode("utf-8")


def parse_stats_payload(payload) -> dict:
    return json.loads(bytes(payload).decode("utf-8"))
