"""Observability plane: trace spans, metrics registry, wire telemetry.

Three small modules, one discipline:

  :mod:`~repro.obsv.trace`     — low-overhead span recorder (Chrome
      trace-event export; Perfetto renders a whole federated round as
      one timeline).
  :mod:`~repro.obsv.metrics`   — named registry of counters, gauges
      and log-bucketed histograms with snapshot/delta semantics.
  :mod:`~repro.obsv.teleserve` — the shared ``OP_METRICS``/``OP_TRACE``
      wire opcodes every TCP plane (embed shards, fedsvc coordinator,
      gnnserve frontend) answers, plus the scrape client and the
      cross-process trace merge used by ``launch/obs_dump.py``.

Everything is in-process and dependency-free: instrumented code calls
module-level singletons (:data:`repro.obsv.trace.TRACE`,
:data:`repro.obsv.metrics.REGISTRY`); disabled tracing is a
zero-allocation no-op, and metrics are always on (a counter bump is a
dict-free attribute add).
"""

from . import metrics, trace  # noqa: F401
