"""Named metrics registry: counters, gauges, log-bucketed histograms.

Design goals, in order:

1. *Cheap on the hot path.*  Instrumented modules bind their metric
   objects once at import (``_REQS = REGISTRY.counter("embed.requests")``)
   so a hot-path tick is one attribute add — no name lookup, no lock.
   CPython's GIL makes the occasional lost increment under thread races
   possible in principle; telemetry tolerates that, ledgers that must be
   exact (TransferLog, coordinator history) stay where they are.
2. *Snapshot/delta semantics.*  ``REGISTRY.snapshot()`` is a plain
   JSON-able dict; ``REGISTRY.delta(prev)`` subtracts counter values and
   histogram counts so benchmarks can charge one phase (one round, one
   deployment) without resetting global state out from under everyone
   else.
3. *Text exposition.*  ``render_text()`` emits a Prometheus-style flat
   text form — one line per scalar, ``_bucket{le="…"}`` lines per
   histogram — which is what ``launch/obs_dump.py`` prints as the merged
   metrics table.

Histograms are log-bucketed: bucket upper bounds are ``lo·factor^k``
up to ``hi`` plus a ``+Inf`` overflow, and a value lands in the first
bucket whose upper bound is ≥ the value (computed by bisection on the
precomputed bounds, so boundary behaviour is exact, not
floating-log-rounded).
"""

from __future__ import annotations

import bisect
import collections
import math
import threading
from typing import Callable, Iterator, Optional


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins level; optionally backed by a callable (read at
    snapshot time — e.g. a jit cache size or a queue length)."""

    __slots__ = ("name", "_value", "fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        if self.fn is not None:
            try:
                return float(self.fn())
            except Exception:
                return float("nan")
        return self._value


def log_bounds(lo: float, hi: float, factor: float) -> list[float]:
    """Bucket upper bounds ``lo·factor^k`` for k = 0.. until ≥ hi.
    The implicit final bucket is +Inf (overflow)."""
    assert lo > 0 and hi > lo and factor > 1
    out, b = [], lo
    # the epsilon keeps float drift (b = lo·factor^k accumulated by
    # multiplication) from emitting one bound just past hi
    while b < hi * (1 - 1e-12):
        out.append(b)
        b *= factor
    out.append(b)
    return out


class Histogram:
    """Log-bucketed distribution with count/sum/min/max sidecars."""

    __slots__ = ("name", "bounds", "counts", "count", "sum", "vmin", "vmax")

    def __init__(self, name: str, *, lo: float = 1e-6, hi: float = 100.0,
                 factor: float = 2.0):
        self.name = name
        self.bounds = log_bounds(lo, hi, factor)
        self.counts = [0] * (len(self.bounds) + 1)   # +1: +Inf overflow
        self.count = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        # first bucket whose upper bound is ≥ v; values past the last
        # bound land in the +Inf overflow slot
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the q-quantile (0 < q ≤ 1)."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return self.bounds[i] if i < len(self.bounds) else self.vmax
        return self.vmax

    def snapshot(self) -> dict:
        out = {"count": self.count, "sum": self.sum,
               "buckets": list(self.counts)}
        if self.count:
            out["min"] = self.vmin
            out["max"] = self.vmax
        return out


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Creation takes a lock; reads and updates on the returned objects do
    not.  A name maps to exactly one metric type — asking for the same
    name with a different type is a programming error and raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}   # guarded-by: self._lock

    def _get(self, name: str, kind, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(name, Gauge, lambda: Gauge(name, fn))
        if fn is not None:
            g.fn = fn           # re-registering rebinds the callable
        return g

    def histogram(self, name: str, *, lo: float = 1e-6, hi: float = 100.0,
                  factor: float = 2.0) -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(name, lo=lo, hi=hi,
                                           factor=factor))

    def names(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(n for n in self._metrics if n.startswith(prefix))

    def snapshot(self, prefix: str = "") -> dict:
        """JSON-able {name: scalar | histogram dict}."""
        out = {}
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            if not name.startswith(prefix):
                continue
            if isinstance(m, Histogram):
                out[name] = m.snapshot()
            else:
                out[name] = m.value
        return out

    @staticmethod
    def delta(now: dict, prev: dict) -> dict:
        """Elementwise difference of two snapshots: histogram counts,
        sums and buckets subtract, scalars subtract (a snapshot cannot
        tell a gauge from a counter — consumers of a delta should only
        read names they know are monotonic)."""
        out = {}
        for name, cur in now.items():
            old = prev.get(name)
            if isinstance(cur, dict):                       # histogram
                oldd = old if isinstance(old, dict) else {}
                ob = oldd.get("buckets", [])
                out[name] = {
                    "count": cur["count"] - oldd.get("count", 0),
                    "sum": cur["sum"] - oldd.get("sum", 0.0),
                    "buckets": [c - (ob[i] if i < len(ob) else 0)
                                for i, c in enumerate(cur["buckets"])],
                }
            elif isinstance(old, (int, float)) \
                    and isinstance(cur, (int, float)):
                out[name] = cur - old
            else:
                out[name] = cur
        return out

    def render_text(self, prefix: str = "") -> str:
        """Prometheus-style flat exposition (names keep their dots)."""
        snap = self.snapshot(prefix)
        with self._lock:
            bounds = {n: m.bounds for n, m in self._metrics.items()
                      if isinstance(m, Histogram)}
        lines = []
        for name, val in snap.items():
            if isinstance(val, dict):
                hb = bounds.get(name, ())
                cum = 0
                for i, c in enumerate(val["buckets"]):
                    cum += c
                    le = f"{hb[i]:.6g}" if i < len(hb) \
                        else "+Inf"
                    lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{name}_count {val['count']}")
                lines.append(f"{name}_sum {val['sum']:.9g}")
            else:
                lines.append(f"{name} {val:.9g}" if isinstance(val, float)
                             else f"{name} {val}")
        return "\n".join(lines)

    def clear(self) -> None:
        """Drop every metric (tests only — instrumented modules that
        bound objects at import keep their stale references)."""
        with self._lock:
            self._metrics.clear()


class SampleWindow:
    """Bounded deque of structured samples that feeds per-op registry
    histograms on the same ``observe`` call.

    This is the single bookkeeping point for ``TcpTransport`` RPC
    samples: ``fit_network_model`` calibration iterates the window (it
    needs joint per-sample (bytes, time) rows), while ``OP_METRICS``
    scrapes read the histograms — both views come from the same
    ``observe``, never parallel ledgers.  The deque API that
    benchmarks/tests rely on (clear, iteration, len) is preserved."""

    def __init__(self, prefix: str, maxlen: int, *,
                 registry: MetricsRegistry | None = None):
        self.prefix = prefix
        self._dq: collections.deque = collections.deque(maxlen=maxlen)
        self._reg = registry if registry is not None else REGISTRY
        self._hists: dict[str, tuple[Histogram, Histogram]] = {}

    def observe(self, sample) -> None:
        """Append a sample carrying ``.op``, ``.measured_s`` and
        ``.payload_bytes``; its latency/bytes land in the per-op
        histograms in the same call."""
        self._dq.append(sample)
        op = sample.op
        pair = self._hists.get(op)
        if pair is None:
            # bounded: one series per wire opcode name, a fixed set
            # repro-lint: disable=TL001
            pair = (self._reg.histogram(f"{self.prefix}.latency_s.{op}",
                                        lo=1e-6, hi=100.0, factor=2.0),
                    # repro-lint: disable=TL001
                    self._reg.histogram(f"{self.prefix}.bytes.{op}",
                                        lo=64.0, hi=2.0 ** 31, factor=4.0))
            self._hists[op] = pair
        pair[0].observe(sample.measured_s)
        pair[1].observe(sample.payload_bytes)

    # deque-compatible surface (bench_wire.py / test_wire.py contract)
    append = observe

    def clear(self) -> None:
        self._dq.clear()

    def __iter__(self) -> Iterator:
        return iter(self._dq)

    def __len__(self) -> int:
        return len(self._dq)

    @property
    def maxlen(self) -> int | None:
        return self._dq.maxlen


#: process-global registry — what the wire telemetry opcodes expose.
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
