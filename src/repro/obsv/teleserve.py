"""Wire telemetry: the shared ``OP_METRICS``/``OP_TRACE`` opcode pair.

Server side — :func:`handle_telemetry` answers both opcodes from the
process-global :data:`~repro.obsv.metrics.REGISTRY` and
:data:`~repro.obsv.trace.TRACE`.  Every TCP plane calls it *first* in
its dispatch (the telemetry body layout is just the opcode byte, which
plane-specific parsers would reject), so one scraper speaks to embed
shards, the fedsvc coordinator, the gnnserve frontend, and the
bare :func:`serve_telemetry` listener a worker process runs.

Client side — :class:`TelemetryClient` scrapes one endpoint and
measures the *monotonic-clock offset* per RPC: the response carries the
server's ``perf_counter`` reading at build time, and the client brackets
the RPC with its own clock, estimating::

    offset ≈ (t_send + t_recv) / 2  −  t_server

i.e. the shift that maps the server's private ``perf_counter`` origin
onto the client's, up to half the RPC's flight time (loopback: ~µs).
:func:`scrape_all` + :func:`repro.obsv.trace.merge_snapshots` turn a
whole deployment's per-process rings into one Perfetto timeline.

Frame layout (the :mod:`repro.exchange.wire` framing)::

    request   uint8 opcode (OP_METRICS | OP_TRACE)
    response  uint8 status | UTF-8 JSON payload
"""

from __future__ import annotations

import dataclasses
import json
import os
import socket
import threading
import time
from typing import Optional

from repro.exchange import wire

from . import metrics, trace

_perf = time.perf_counter


# -- server side --------------------------------------------------------------

def build_metrics_body() -> bytes:
    return bytes([wire.OP_METRICS])


def build_trace_body() -> bytes:
    return bytes([wire.OP_TRACE])


def handle_telemetry(body: bytes) -> Optional[bytes]:
    """Answer a telemetry request; ``None`` for any other opcode (the
    caller falls through to its plane-specific dispatch).  Safe to call
    on arbitrary bytes — it only ever inspects ``body[0]``."""
    if not body:
        return None
    op = body[0]
    if op == wire.OP_METRICS:
        payload = {"process": trace.TRACE.process,
                   "pid": os.getpid(),
                   "t_mono": _perf(),
                   "metrics": metrics.REGISTRY.snapshot()}
        return wire.build_ok(json.dumps(payload).encode())
    if op == wire.OP_TRACE:
        snap = trace.TRACE.snapshot()         # includes t_mono handshake
        return wire.build_ok(json.dumps(snap).encode())
    return None


# -- client side --------------------------------------------------------------

@dataclasses.dataclass
class EndpointTelemetry:
    """One scraped endpoint: identity, aligned clock, and both dumps."""
    label: str                 # caller-assigned endpoint label
    process: str               # the endpoint's self-reported process name
    pid: int
    offset_s: float            # add to endpoint timestamps → scraper clock
    metrics: dict              # registry snapshot
    trace: dict                # trace snapshot (raw endpoint clock)


class TelemetryClient:
    """Blocking scraper for one telemetry-speaking endpoint."""

    def __init__(self, addr, *, connect_timeout: float = 5.0):
        from repro.exchange.socket_transport import parse_address
        self.addr = parse_address(addr)
        self.connect_timeout = connect_timeout
        self._sock: socket.socket | None = None

    def _rpc(self, body: bytes) -> tuple[dict, float]:
        """→ (decoded JSON payload, clock offset estimate)."""
        if self._sock is None:
            self._sock = socket.create_connection(
                self.addr, timeout=self.connect_timeout)
            self._sock.setsockopt(socket.IPPROTO_TCP,
                                  socket.TCP_NODELAY, 1)
        t_send = _perf()
        wire.send_frame(self._sock, body)
        resp = wire.recv_frame(self._sock)
        t_recv = _perf()
        if resp is None:
            raise ConnectionError("telemetry endpoint closed connection")
        payload = json.loads(bytes(wire.parse_response(resp)).decode())
        offset = (t_send + t_recv) / 2 - float(payload.get("t_mono", 0.0))
        return payload, offset

    def metrics(self) -> tuple[dict, float]:
        return self._rpc(build_metrics_body())

    def trace(self) -> tuple[dict, float]:
        return self._rpc(build_trace_body())

    def scrape(self, label: str | None = None) -> EndpointTelemetry:
        m, off_m = self.metrics()
        t, off_t = self.trace()
        return EndpointTelemetry(
            label=label or f"{self.addr[0]}:{self.addr[1]}",
            process=str(t.get("process", "proc")),
            pid=int(t.get("pid", 0)),
            # two independent handshakes; average halves the jitter
            offset_s=(off_m + off_t) / 2,
            metrics=m.get("metrics", {}),
            trace=t)

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def scrape_all(endpoints: list[tuple[str, object]]
               ) -> list[EndpointTelemetry]:
    """Scrape ``[(label, addr), …]`` sequentially on one scraper clock."""
    out = []
    for label, addr in endpoints:
        with TelemetryClient(addr) as c:
            out.append(c.scrape(label))
    return out


def merge_scrapes(scrapes: list[EndpointTelemetry]) -> tuple[dict, str]:
    """→ (one Chrome trace over all endpoints, one metrics table).

    Trace timestamps are offset-aligned onto the scraper's clock; the
    metrics table is a flat ``process metric value`` text block grouped
    by endpoint label."""
    trace_doc = trace.merge_snapshots([s.trace for s in scrapes],
                                      [s.offset_s for s in scrapes])
    lines = []
    for s in scrapes:
        lines.append(f"# {s.label} [{s.process} pid={s.pid} "
                     f"offset={s.offset_s:+.6f}s]")
        for name, val in sorted(s.metrics.items()):
            if isinstance(val, dict):      # histogram: count/mean line
                cnt = val.get("count", 0)
                mean = val.get("sum", 0.0) / cnt if cnt else 0.0
                lines.append(f"{name} count={cnt} mean={mean:.6g}")
            else:
                lines.append(f"{name} {val:.9g}"
                             if isinstance(val, float)
                             else f"{name} {val}")
    return trace_doc, "\n".join(lines)


# -- telemetry-only listener --------------------------------------------------

class TelemetryServerHandle:
    def __init__(self, sock: socket.socket, stop: threading.Event,
                 thread: threading.Thread):
        self._sock = sock
        self._stop = stop
        self._thread = thread
        self.host, self.port = sock.getsockname()[:2]

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


def _telemetry_client_loop(conn: socket.socket,
                           stop: threading.Event) -> None:
    try:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not stop.is_set():
            body = wire.recv_frame(conn)
            if body is None:
                break
            resp = handle_telemetry(body)
            if resp is None:
                resp = wire.build_err(
                    f"telemetry-only endpoint: unknown opcode "
                    f"{body[0] if body else '∅'}")
            wire.send_frame(conn, resp)
    except (ConnectionError, OSError):
        pass
    finally:
        try:
            conn.close()
        except OSError:
            pass


def serve_telemetry(*, host: str = "127.0.0.1",
                    port: int = 0) -> TelemetryServerHandle:
    """Minimal listener answering ONLY the telemetry opcodes — how a
    fedsvc *worker* (a pure client otherwise) becomes scrapeable
    (``repro.launch.fed_worker --obs-port``)."""
    stop = threading.Event()
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind((host, port))
    listener.listen(16)

    def accept_loop() -> None:
        listener.settimeout(0.2)
        while not stop.is_set():
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=_telemetry_client_loop,
                             args=(conn, stop), daemon=True).start()
        try:
            listener.close()
        except OSError:
            pass

    t = threading.Thread(target=accept_loop, daemon=True)
    t.start()
    return TelemetryServerHandle(listener, stop, t)
